"""Assemble EXPERIMENTS.md tables from dry-run + roofline artifacts."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")
ROOF = os.path.join(ROOT, "experiments", "roofline")

ARCH_ORDER = ["musicgen-medium", "qwen1.5-4b", "phi3-mini-3.8b",
              "mistral-large-123b", "qwen3-4b", "olmoe-1b-7b",
              "moonshot-v1-16b-a3b", "recurrentgemma-2b", "rwkv6-7b",
              "internvl2-26b", "cumf-als"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "netflix", "hugewiki", "facebook_f100"]


def _load(d):
    recs = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        key = os.path.basename(f)[:-5]
        recs[key] = r
    return recs


def gib(b):
    return f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    recs = _load(DRY)
    lines = [
        "| arch | shape | mesh | status | peak GiB (XLA:CPU) | live-set GiB | fits | HLO GFLOP/dev | coll. wire GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mp, tagsuf in ((False, "sp"), (True, "mp")):
                key = (f"{arch}_{shape}_{tagsuf}" if arch != "cumf-als"
                       else f"als_{shape}_{tagsuf}")
                r = recs.get(key)
                if r is None:
                    continue
                mesh = "2x16x16" if mp else "16x16"
                if r.get("status") == "skip":
                    lines.append(f"| {arch} | {shape} | {mesh} | SKIP"
                                 f" | — | — | — | — | — |")
                    continue
                if r.get("status") != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | ERROR"
                                 f" | — | — | — | — | — |")
                    continue
                m = r["memory"]
                live = m.get("live_set_estimate_bytes",
                             m["peak_estimate_bytes"])
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok "
                    f"| {gib(m['peak_estimate_bytes'])} "
                    f"| {gib(live)} "
                    f"| {'Y' if m.get('fits') else 'N'} "
                    f"| {r['cost']['flops'] / 1e9:.0f} "
                    f"| {r['collectives']['total_bytes'] / 2**30:.2f} |")
    return "\n".join(lines)


def roofline_table(suffix="") -> str:
    recs = _load(ROOF)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops | roofline step s | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get(f"{arch}_{shape}{suffix}")
            if r is None or r.get("status") != "ok":
                if r is not None and r.get("status") == "skip":
                    lines.append(f"| {arch} | {shape} | SKIP | | | | | | |")
                continue
            t = r["terms_s"]
            lines.append(
                f"| {arch} | {shape} "
                f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
                f"| {t['collective_s']:.4f} "
                f"| {r['dominant'].replace('_s', '')} "
                f"| {r['useful_ratio']:.2f} "
                f"| {r['roofline_step_s']:.4f} "
                f"| {r['mfu_upper_bound']:.2f} |")
    return "\n".join(lines)


def summary_stats():
    recs = _load(DRY)
    ok = skip = err = nofit = 0
    for r in recs.values():
        s = r.get("status")
        if s == "skip":
            skip += 1
        elif s == "ok":
            if r["memory"].get("fits"):
                ok += 1
            else:
                nofit += 1
        else:
            err += 1
    return {"ok": ok, "skip": skip, "error": err, "nofit": nofit}


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## Dry-run table\n")
        print(dryrun_table())
        print("\nsummary:", summary_stats())
    if which in ("all", "roofline"):
        print("\n## Roofline table (single-pod)\n")
        print(roofline_table())
