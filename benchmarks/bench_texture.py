"""Paper Fig. 8 analogue: the texture-cache benefit.

cuMF caches Theta^T reads through the read-only texture cache (25-35%
faster).  The TPU analogue measured here: fusing the theta gather into the
hermitian pass (gathered rows stream through fast memory) vs materializing
the gathered [m, K, f] tensor in HBM first (an extra full round trip of the
gathered data — what a gather without locality costs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref

from benchmarks.common import emit, time_fn


def _problem(m=2048, n=4096, K=256, f=64, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (m, K)), jnp.int32)
    cnt = jnp.asarray(rng.integers(K // 2, K + 1, (m,)), jnp.int32)
    val = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
    return theta, idx, val, cnt


@jax.jit
def gather_fused(theta, idx, val, cnt):
    g = jnp.take(theta, idx, axis=0)
    mask = kref.mask_from_cnt(cnt, idx.shape[1], theta.dtype)
    return jnp.einsum("ukf,ukg->ufg", g * mask[..., None], g)


@jax.jit
def gather_materialized(theta, idx, val, cnt):
    g = jax.lax.optimization_barrier(jnp.take(theta, idx, axis=0))
    mask = kref.mask_from_cnt(cnt, idx.shape[1], theta.dtype)
    return jnp.einsum("ukf,ukg->ufg", g * mask[..., None], g)


def run():
    args = _problem()
    us_f = time_fn(gather_fused, *args)
    us_m = time_fn(gather_materialized, *args)
    m, K = args[1].shape
    f = args[0].shape[1]
    extra = m * K * f * 4 * 2  # write + read of the materialized gather
    emit("fig8_texture_fused_gather", us_f, "extra_hbm_bytes=0")
    emit("fig8_texture_materialized", us_m,
         f"extra_hbm_bytes={extra};slowdown={us_m / us_f:.2f}x")


if __name__ == "__main__":
    run()
