"""Paper Fig. 11 + Table 1: very-large-scale per-iteration latency and cost.

The paper reports per-iteration time for SparkALS / Factorbird / Facebook
scale synthetic data on 4 GPUs and the cost ratio vs distributed-CPU
baselines.  Here: roofline-modeled per-iteration time of our SU-ALS on one
TPU v5e pod (256 chips) for every Table 5 data set, plus the cost model.
All numbers are clearly labeled modeled (no TPU in this container); the
model is the same three-term roofline validated against the dry-run.

``measure_outofcore`` is the *measured* companion (ISSUE 2): a real
wave-streaming run on CPU against a capped simulated device, so the
out-of-core path has wall-clock numbers next to the roofline ones."""
from __future__ import annotations

from repro.core.partition import plan_for, plan_partitions
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.sparse.synth import DATASETS

from benchmarks.common import emit

JSON_OUT = "BENCH_outofcore.json"   # run.py serializes run()'s records here
LEDGER_OUT = "LEDGER_outofcore.json"  # plan-vs-actual ledger of the last run


def _write_ledger(tel) -> None:
    """Serialize the run's plan-vs-actual ledger next to the BENCH rows.
    Each streaming run overwrites it, so the file ends up holding the mesh
    run's ledger when the mesh ran and the last single-device run's
    otherwise — CI schema-checks and gates it with ``repro.obs.regress``."""
    import json

    if tel.ledger:
        with open(LEDGER_OUT, "w") as f:
            json.dump(tel.ledger, f, indent=2)
        emit("outofcore_ledger", 0.0,
             f"wrote {len(tel.ledger['records'])} plan-vs-actual records "
             f"to {LEDGER_OUT};ok={tel.ledger['ok']}")

V5E_CHIP_HR_USD = 1.20      # on-demand list-ish price per chip-hour
PAPER_BASELINES = {         # per-iteration seconds + cluster cost, Table 1/§5.5
    "sparkals": (240.0, 50 * 0.53),     # SparkALS: 240 s/iter, 50 x m3.2xlarge
    "factorbird": (563.0, 50 * 0.42),   # Factorbird: 563 s/iter
    "facebook": (None, None),
    "cumf_max": (3.8 * 3600, None),     # cuMF itself: 3.8 h/iter at f=100
    "hugewiki": (None, None),
    "netflix": (None, None),
    "yahoomusic": (None, None),
}


def iteration_time_s(spec, chips=256, f_pad=None):
    f = f_pad or -(-spec.f // 128) * 128    # MXU-padded latent dim
    flops = 2 * (spec.nnz * f * (f + 1) + spec.nnz * f) \
        + (spec.m + spec.n) * f ** 3 / 3
    bytes_ = 2 * (spec.nnz * f * 4) + 2 * (spec.m + spec.n) * f * f * 4
    comp = flops / chips / PEAK_FLOPS_BF16
    mem = bytes_ / chips / HBM_BW
    red = 2 * (spec.m + spec.n) * f * f * 4 / chips / ICI_BW
    return max(comp, mem) + red, comp, mem, red


def measure_outofcore(iters: int = 2, seed: int = 0,
                      scale: float = 0.02,
                      autotune: bool = False) -> list[dict]:
    """Measured streaming path: waves >= 2 on a capped simulated CPU device.

    Runs the real ``repro.outofcore`` driver on a shrunk Netflix recipe with
    a forced multi-wave plan, and reports wall time per iteration, streamed
    bytes, and the peak simulated device footprint vs the plan's budget.
    Returns one record per configuration (also emitted as CSV lines) —
    ``benchmarks/run.py`` serializes them to BENCH_outofcore.json.
    """
    from repro.core import als as als_mod
    from repro.outofcore import (RatingStore, build_schedule,
                                 required_capacity_bytes, run_streaming_als)
    from repro.sparse import synth

    records = []
    facs = {}
    for q, n_data, n_bins in ((4, 2, 1), (8, 2, 1), (4, 2, 4)):
        spec = synth.scaled(DATASETS["netflix"], scale, f=16)
        r, _, _, _ = synth.make_synthetic_ratings(spec, seed=seed)
        store = RatingStore(r, q=q, n_bins=n_bins)
        acc_eps = spec.n * (spec.f * spec.f + 3 * spec.f + 1) * 4
        if n_bins > 1:
            plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=q,
                            n_data=n_data,
                            bin_fills=store.bin_fill_pairs(),
                            eps=acc_eps, buffers=4)
        else:
            plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=q,
                            n_data=n_data, fill=store.worst_fill,
                            eps=acc_eps, buffers=4)
        sched = build_schedule(plan, spec.m, spec.n, n_data=n_data)
        cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=iters,
                                mode="ref")
        fac, _, tel = run_streaming_als(store, sched, cfg)
        # the driver's own obs clock: total of the `driver` phase span
        iter_s = tel.wall_seconds / iters
        suffix = "_binned" if n_bins > 1 else ""
        rec = {
            "name": f"outofcore_q{q}_w{len(sched.waves)}{suffix}",
            "m": spec.m, "n": spec.n, "nnz": r.nnz, "f": spec.f,
            "p": 1, "q": q, "n_data": n_data, "waves": len(sched.waves),
            "iters": iters, "n_bins": n_bins,
            "measured_iter_s": iter_s,
            "wall_seconds": tel.wall_seconds,
            "phase_seconds": {k: round(v, 4)
                              for k, v in tel.phase_seconds.items()},
            "bytes_streamed_per_iter": tel.bytes_streamed // iters,
            "peak_device_bytes": tel.peak_bytes,
            "capacity_bytes": tel.capacity_bytes,
            "required_capacity_bytes": required_capacity_bytes(
                store, sched, spec.f),
            "fits": tel.peak_bytes <= tel.capacity_bytes,
            "padded_slots": tel.padded_slots,
            "nnz_streamed": tel.nnz_streamed,
            "fill_waste_ratio": round(tel.fill_waste_ratio, 6),
            "ledger_ok": tel.ledger.get("ok", False),
        }
        records.append(rec)
        facs[(q, n_bins)] = fac
        _write_ledger(tel)
        emit(rec["name"], iter_s * 1e6,
             f"measured;waves={rec['waves']};peak_MiB="
             f"{tel.peak_bytes / 2**20:.1f};cap_MiB="
             f"{tel.capacity_bytes / 2**20:.1f};streamed_MiB_per_iter="
             f"{rec['bytes_streamed_per_iter'] / 2**20:.1f}")

    # binned-vs-uniform: same data, same (p, q) plan shape, >= 1.5x less
    # fill waste at identical factors (masked padding slots are exact
    # zeros, so the binned run is a layout change only)
    import numpy as np
    uni = next(x for x in records if x["q"] == 4 and x["n_bins"] == 1)
    binned = next(x for x in records if x["n_bins"] > 1)
    ratio = uni["fill_waste_ratio"] / binned["fill_waste_ratio"]
    binned["fill_waste_vs_uniform"] = round(ratio, 4)
    binned["factors_match_uniform"] = bool(
        np.allclose(facs[(4, 4)].x, facs[(4, 1)].x, atol=1e-5)
        and np.allclose(facs[(4, 4)].theta, facs[(4, 1)].theta, atol=1e-5))
    assert ratio >= 1.5, (uni["fill_waste_ratio"],
                          binned["fill_waste_ratio"])
    assert binned["factors_match_uniform"], "binned factors drifted"
    emit("outofcore_binned_fill_win", 0.0,
         f"fill_waste {uni['fill_waste_ratio']:.3f} -> "
         f"{binned['fill_waste_ratio']:.3f} ({ratio:.2f}x, n_bins="
         f"{binned['n_bins']})")
    if autotune:
        records += measure_outofcore_autotune(iters=iters, seed=seed,
                                              scale=scale)
    records += measure_outofcore_mesh(iters=iters, seed=seed)
    return records


def measure_outofcore_autotune(iters: int = 2, seed: int = 0,
                               scale: float = 0.02) -> list[dict]:
    """Autotuned streaming row (``--autotune``): the same shrunk-Netflix
    problem with ``n_bins="auto"`` — the cuMF Alg.-2 sweep picks the layout.

    Asserts the sweep's contract end to end: the chosen config's predicted
    streamed bytes are <= EVERY hand-picked ladder rung's, and the driver's
    measured ``bytes_streamed`` per iteration equals the winning score
    exactly (the analytic sweep prices the same integers the meter counts).
    The decision record (config, cache hit/miss, key, score) rides on the
    row under ``autotune`` and in the run's ledger run context.
    """
    from repro.core import als as als_mod
    from repro.outofcore import (RatingStore, build_schedule,
                                 run_streaming_als)
    from repro.sparse import synth

    q, n_data = 4, 2
    spec = synth.scaled(DATASETS["netflix"], scale, f=16)
    r, _, _, _ = synth.make_synthetic_ratings(spec, seed=seed)
    store = RatingStore(r, q=q, n_bins="auto")
    assert store.tune is not None and not store.tune["cache_hit"]
    acc_eps = spec.n * (spec.f * spec.f + 3 * spec.f + 1) * 4
    if store.n_bins > 1:
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=q,
                        n_data=n_data, bin_fills=store.bin_fill_pairs(),
                        eps=acc_eps, buffers=4)
    else:
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=q,
                        n_data=n_data, fill=store.worst_fill,
                        eps=acc_eps, buffers=4)
    sched = build_schedule(plan, spec.m, spec.n, n_data=n_data)
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=iters, mode="ref")
    _, _, tel = run_streaming_als(store, sched, cfg)
    # re-run the sweep (same inputs, no cache) just for the rung table the
    # row carries; the winner must undercut every hand-picked rung
    from repro.core.autotune import tune_als_layout
    sweep = tune_als_layout(r, q=q, p=1, f=spec.f)
    assert sweep.config.to_obj() == store.tune["config"], (sweep, store.tune)
    assert all(sweep.score <= c["score"] for c in sweep.candidates), \
        sweep.candidates
    assert tel.bytes_streamed == sweep.score * iters, \
        (tel.bytes_streamed, sweep.score, iters)
    rec = {
        "name": f"outofcore_q{q}_w{len(sched.waves)}_autotuned",
        "m": spec.m, "n": spec.n, "nnz": r.nnz, "f": spec.f,
        "p": 1, "q": q, "n_data": n_data, "waves": len(sched.waves),
        "iters": iters, "n_bins": store.n_bins,
        "autotune": store.tune,
        "autotune_ladder": [{"config": c["config"], "score": c["score"]}
                            for c in sweep.candidates],
        "measured_iter_s": tel.wall_seconds / iters,
        "wall_seconds": tel.wall_seconds,
        "phase_seconds": {k: round(v, 4)
                          for k, v in tel.phase_seconds.items()},
        "bytes_streamed_per_iter": tel.bytes_streamed // iters,
        "peak_device_bytes": tel.peak_bytes,
        "capacity_bytes": tel.capacity_bytes,
        "fits": tel.peak_bytes <= tel.capacity_bytes,
        "padded_slots": tel.padded_slots,
        "nnz_streamed": tel.nnz_streamed,
        "fill_waste_ratio": round(tel.fill_waste_ratio, 6),
        "ledger_ok": tel.ledger.get("ok", False),
    }
    _write_ledger(tel)
    emit(rec["name"], rec["measured_iter_s"] * 1e6,
         f"measured;auto_n_bins={store.n_bins};"
         f"predicted_bytes_per_iter={sweep.score};"
         f"cache_hit={store.tune['cache_hit']};"
         f"streamed_MiB_per_iter="
         f"{rec['bytes_streamed_per_iter'] / 2**20:.1f}")
    return [rec]


def measure_outofcore_mesh(iters: int = 2, seed: int = 0) -> list[dict]:
    """Measured p > 1 streaming row: the same wave driver on a real
    (data=2, model=2) mesh — theta as p shards, waves shard-mapped, the
    accumulate half combined by the topology-aware reduction, and (new)
    the theta half streamed as batch-uniform stacked degree bins
    (``n_bins > 1`` with ``p > 1``).  Factors are checked against the
    in-core single-device trajectory.  Skipped (with a CSV note) when
    fewer than 4 devices are visible; CI's bench-smoke forces 8 host
    devices so the row is always present there.
    """
    import jax
    import numpy as np

    from repro.core import als as als_mod
    from repro.core.partition import streaming_acc_bytes
    from repro.outofcore import (RatingStore, build_schedule,
                                 required_capacity_bytes, run_streaming_als)
    from repro.launch.mesh import make_mesh
    from repro.sparse import synth

    n_data, p, q, n_bins = 2, 2, 4, 4
    if len(jax.devices()) < n_data * p:
        emit("outofcore_mesh_skipped", 0.0,
             f"needs {n_data * p} devices, have {len(jax.devices())};"
             "run under --xla_force_host_platform_device_count=8")
        return []
    spec = synth.SynthSpec("netflix-mesh", 2048, 512, 80_000, 16, 0.05)
    r, rt, _, _ = synth.make_synthetic_ratings(spec, seed=seed)
    store = RatingStore(r, q=q, p=p, n_bins=n_bins)
    plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=p, q=q, n_data=n_data,
                    bin_fills=store.bin_fill_pairs(), eps=0, buffers=4,
                    acc_bytes=streaming_acc_bytes(spec.n, spec.f))
    sched = build_schedule(plan, spec.m, spec.n, n_data=n_data)
    mesh = make_mesh((n_data, p), ("data", "model"))
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=iters, mode="ref")
    fac, _, tel = run_streaming_als(store, sched, cfg, mesh=mesh)
    # zero-drift: the stacked binned mesh run matches the in-core
    # single-device trajectory (stack padding rows are exact zeros)
    state, _ = als_mod.als_train(als_mod.ell_triplet(r),
                                 als_mod.ell_triplet(rt),
                                 r.m, rt.m, cfg)
    parity = bool(
        np.abs(fac.x[:r.m] - np.asarray(state.x)).max() < 1e-4
        and np.abs(fac.theta - np.asarray(state.theta)).max() < 1e-4)
    assert parity, "stacked mesh factors drifted from in-core"
    iter_s = tel.wall_seconds / iters
    rec = {
        "name": f"outofcore_mesh_p{p}_q{q}_w{len(sched.waves)}_binned",
        "m": spec.m, "n": spec.n, "nnz": r.nnz, "f": spec.f,
        "p": p, "q": q, "n_data": n_data, "waves": len(sched.waves),
        "iters": iters, "n_bins": n_bins,
        "factors_match_incore": parity,
        "measured_iter_s": iter_s,
        "wall_seconds": tel.wall_seconds,
        "phase_seconds": {k: round(v, 4)
                          for k, v in tel.phase_seconds.items()},
        "bytes_streamed_per_iter": tel.bytes_streamed // iters,
        "peak_device_bytes": tel.peak_bytes,
        "capacity_bytes": tel.capacity_bytes,
        "required_capacity_bytes": required_capacity_bytes(
            store, sched, spec.f),
        "fits": tel.peak_bytes <= tel.capacity_bytes,
        "reduce_fast_bytes": tel.reduce_fast_bytes,
        "reduce_slow_bytes": tel.reduce_slow_bytes,
        "topology": tel.topology,
        "padded_slots": tel.padded_slots,
        "nnz_streamed": tel.nnz_streamed,
        "fill_waste_ratio": round(tel.fill_waste_ratio, 6),
        "ledger_ok": tel.ledger.get("ok", False),
    }
    _write_ledger(tel)
    emit(rec["name"], iter_s * 1e6,
         f"measured;mesh=data{n_data}xmodel{p};n_bins={n_bins};peak_MiB="
         f"{tel.peak_bytes / 2**20:.1f};cap_MiB="
         f"{tel.capacity_bytes / 2**20:.1f};reduce={tel.topology}")
    return [rec]


def run(quick: bool = False, autotune: bool = False):
    for name, spec in DATASETS.items():
        t, comp, mem, red = iteration_time_s(spec)
        plan = plan_partitions(spec.m, spec.n, spec.nnz, spec.f)
        cost_per_iter = t / 3600 * 256 * V5E_CHIP_HR_USD
        base = PAPER_BASELINES.get(name, (None, None))
        if base[0]:
            speedup = base[0] / t
            derived = (f"modeled_iter_s={t:.1f};speedup_vs_baseline={speedup:.0f}x;"
                       f"usd_per_iter={cost_per_iter:.2f};plan=p{plan.p}q{plan.q}")
        else:
            derived = (f"modeled_iter_s={t:.1f};usd_per_iter={cost_per_iter:.2f};"
                       f"plan=p{plan.p}q{plan.q};fits={plan.fits}")
        emit(f"fig11_huge_{name}", t * 1e6, derived)
    # quick (CI smoke): fewer iterations on a smaller shrink factor
    return measure_outofcore(iters=1 if quick else 2,
                             scale=0.008 if quick else 0.02,
                             autotune=autotune)


if __name__ == "__main__":
    run()
