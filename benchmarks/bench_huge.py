"""Paper Fig. 11 + Table 1: very-large-scale per-iteration latency and cost.

The paper reports per-iteration time for SparkALS / Factorbird / Facebook
scale synthetic data on 4 GPUs and the cost ratio vs distributed-CPU
baselines.  Here: roofline-modeled per-iteration time of our SU-ALS on one
TPU v5e pod (256 chips) for every Table 5 data set, plus the cost model.
All numbers are clearly labeled modeled (no TPU in this container); the
model is the same three-term roofline validated against the dry-run."""
from __future__ import annotations

from repro.core.partition import plan_partitions
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.sparse.synth import DATASETS

from benchmarks.common import emit

V5E_CHIP_HR_USD = 1.20      # on-demand list-ish price per chip-hour
PAPER_BASELINES = {         # per-iteration seconds + cluster cost, Table 1/§5.5
    "sparkals": (240.0, 50 * 0.53),     # SparkALS: 240 s/iter, 50 x m3.2xlarge
    "factorbird": (563.0, 50 * 0.42),   # Factorbird: 563 s/iter
    "facebook": (None, None),
    "cumf_max": (3.8 * 3600, None),     # cuMF itself: 3.8 h/iter at f=100
    "hugewiki": (None, None),
    "netflix": (None, None),
    "yahoomusic": (None, None),
}


def iteration_time_s(spec, chips=256, f_pad=None):
    f = f_pad or -(-spec.f // 128) * 128    # MXU-padded latent dim
    flops = 2 * (spec.nnz * f * (f + 1) + spec.nnz * f) \
        + (spec.m + spec.n) * f ** 3 / 3
    bytes_ = 2 * (spec.nnz * f * 4) + 2 * (spec.m + spec.n) * f * f * 4
    comp = flops / chips / PEAK_FLOPS_BF16
    mem = bytes_ / chips / HBM_BW
    red = 2 * (spec.m + spec.n) * f * f * 4 / chips / ICI_BW
    return max(comp, mem) + red, comp, mem, red


def run():
    for name, spec in DATASETS.items():
        t, comp, mem, red = iteration_time_s(spec)
        plan = plan_partitions(spec.m, spec.n, spec.nnz, spec.f)
        cost_per_iter = t / 3600 * 256 * V5E_CHIP_HR_USD
        base = PAPER_BASELINES.get(name, (None, None))
        if base[0]:
            speedup = base[0] / t
            derived = (f"modeled_iter_s={t:.1f};speedup_vs_baseline={speedup:.0f}x;"
                       f"usd_per_iter={cost_per_iter:.2f};plan=p{plan.p}q{plan.q}")
        else:
            derived = (f"modeled_iter_s={t:.1f};usd_per_iter={cost_per_iter:.2f};"
                       f"plan=p{plan.p}q{plan.q};fits={plan.fits}")
        emit(f"fig11_huge_{name}", t * 1e6, derived)


if __name__ == "__main__":
    run()
