"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Any module that declares
a ``JSON_OUT`` filename has its ``run()`` return value serialized there —
one generic path, so BENCH_outofcore.json (bench_huge) and BENCH_sgd.json
(bench_sgd) flow identically and new JSON emitters need no run.py edits.

Selection::

    python benchmarks/run.py                      # everything
    python benchmarks/run.py --quick              # fast subset
    python benchmarks/run.py --only convergence --only sgd
    python benchmarks/run.py --only sgd --quick   # sgd at smoke scale

``--only`` takes the short names below (repeatable); unknown names fail
loudly rather than silently skipping (the old ``--quick`` truncated the
module list and never reached the JSON-emitting modules).  ``--quick``
without ``--only`` selects the fast subset; combined with ``--only`` it
keeps the explicit selection and is instead passed through to any module
whose ``run`` accepts a ``quick`` keyword (scaled-down problem sizes for
the CI smoke lane).
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys

# make ``python benchmarks/run.py`` work from anywhere: the repo root (the
# parent of this file's directory) must be importable for ``benchmarks.*``
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

# short name -> module; order is the full-run execution order
MODULES = [
    ("convergence", "bench_convergence"),            # Fig. 6
    ("register_ablation", "bench_register_ablation"),  # Fig. 7
    ("texture", "bench_texture"),                    # Fig. 8
    ("scaling", "bench_scaling"),                    # Fig. 9/10
    ("huge", "bench_huge"),                          # Fig. 11 + out-of-core
    ("reduction", "bench_reduction"),                # Fig. 5
    ("kernels", "bench_kernels"),                    # kernel-level fusion
    ("lm_substrate", "bench_lm_substrate"),          # LM substrate overhead
    ("sgd", "bench_sgd"),                            # ALS vs SGD vs hybrid
]
QUICK = ("convergence", "register_ablation")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help=f"run only the fast subset: {', '.join(QUICK)}")
    ap.add_argument("--only", action="append", default=[], metavar="NAME",
                    help="run only the named benchmark (repeatable); "
                         f"names: {', '.join(n for n, _ in MODULES)}")
    ap.add_argument("--autotune", action="store_true",
                    help="let benchmarks that take an `autotune` keyword "
                         "add autotuned-layout rows (cuMF Alg.-2 sweep via "
                         "repro.core.autotune; see TUNING.md)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record obs spans across every selected benchmark "
                         "and write one Chrome-trace/Perfetto JSON file")
    ap.add_argument("--history", default="BENCH_HISTORY.jsonl",
                    metavar="JSONL",
                    help="append every JSON emission here with provenance "
                         "(git sha, backend, device count, quick flag); "
                         "gate with `python -m repro.obs.regress --history`")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the history append (one-off local runs)")
    args = ap.parse_args(argv)

    known = {n for n, _ in MODULES}
    unknown = [n for n in args.only if n not in known]
    if unknown:
        ap.error(f"unknown benchmark name(s) {unknown}; "
                 f"choose from {sorted(known)}")
    if args.only:
        selected = set(args.only)
    elif args.quick:
        selected = set(QUICK)
    else:
        selected = known

    tracer = None
    if args.trace:
        from repro.obs import Tracer, set_tracer
        tracer = Tracer()
        set_tracer(tracer)      # streaming drivers pick it up themselves

    from benchmarks.history import append_history, provenance, stamp
    prov = provenance(quick=args.quick)

    print("name,us_per_call,derived")
    try:
        for name, modname in MODULES:
            if name not in selected:
                continue
            mod = importlib.import_module(f"benchmarks.{modname}")
            kwargs = {}
            params = inspect.signature(mod.run).parameters
            if args.quick and "quick" in params:
                kwargs["quick"] = True
            if args.autotune and "autotune" in params:
                kwargs["autotune"] = True
            out = mod.run(**kwargs)
            json_out = getattr(mod, "JSON_OUT", None)
            if json_out and out:
                stamp(out, prov)
                with open(json_out, "w") as f:
                    json.dump(out, f, indent=2)
                print(f"# wrote {len(out)} records to {json_out}", flush=True)
                if not args.no_history:
                    append_history(args.history, modname, out, prov)
                    print(f"# history: {modname} -> {args.history}",
                          flush=True)
    finally:
        if tracer is not None:
            from repro.obs import write_trace
            write_trace(args.trace, tracer, process_name="benchmarks")
            print(f"# trace: {len(tracer.events)} events -> {args.trace}",
                  flush=True)


if __name__ == '__main__':
    main()
