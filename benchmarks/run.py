"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; the measured out-of-core
streaming records from bench_huge additionally land in BENCH_outofcore.json.
"""
from __future__ import annotations

import json
import sys

OUTOFCORE_JSON = "BENCH_outofcore.json"


def main() -> None:
    from benchmarks import (bench_convergence, bench_register_ablation,
                            bench_texture, bench_scaling, bench_huge,
                            bench_kernels, bench_reduction,
                            bench_lm_substrate)
    print("name,us_per_call,derived")
    mods = [
        bench_convergence,       # Fig. 6
        bench_register_ablation, # Fig. 7
        bench_texture,           # Fig. 8
        bench_scaling,           # Fig. 9/10
        bench_huge,              # Fig. 11 + Table 1 + measured out-of-core
        bench_reduction,         # Fig. 5
        bench_kernels,           # kernel-level (beyond-paper fusion)
        bench_lm_substrate,      # LM substrate overhead
    ]
    if "--quick" in sys.argv:
        mods = mods[:2]
    for m in mods:
        out = m.run()
        if m is bench_huge and out:
            with open(OUTOFCORE_JSON, "w") as f:
                json.dump(out, f, indent=2)
            print(f"# wrote {len(out)} measured streaming records to "
                  f"{OUTOFCORE_JSON}", flush=True)


if __name__ == '__main__':
    main()
