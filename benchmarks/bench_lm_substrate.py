"""LM-substrate microbenches (framework overhead visibility): one smoke
train step and one decode step per block family, measured on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import lm as lm_mod
from repro.models import transformer as T
from repro.training.optimizer import OptConfig

from benchmarks.common import emit, time_fn


def run():
    for arch in ("phi3-mini-3.8b", "olmoe-1b-7b", "recurrentgemma-2b",
                 "rwkv6-7b"):
        cfg = registry.smoke_config(arch)
        key = jax.random.PRNGKey(0)
        state = lm_mod.init_train_state(cfg, key, OptConfig())
        step = jax.jit(lm_mod.make_train_step(cfg, OptConfig(), remat=False))
        B, S = 4, 32
        batch = {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "mask": jnp.ones((B, S), jnp.float32),
        }
        if cfg.frontend:
            batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
            del batch["tokens"]
        us = time_fn(step, state, batch, iters=3)
        emit(f"lm_train_step_{arch}", us, f"B={B};S={S};smoke")

        params = T.init_params(cfg, key)
        cache = T.init_cache(cfg, B, 64, jnp.float32)
        dec = jax.jit(lm_mod.make_decode_step(cfg))
        tok = jnp.zeros((B,), jnp.int32) if not cfg.frontend \
            else jnp.zeros((B, cfg.d_model), jnp.float32)
        lens = jnp.full((B,), 5, jnp.int32)
        us = time_fn(dec, params, cache, tok, lens, iters=3)
        emit(f"lm_decode_step_{arch}", us, f"B={B};smoke")


if __name__ == "__main__":
    run()
