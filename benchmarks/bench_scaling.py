"""Paper Fig. 9/10: SU-ALS multi-device scaling.

On this single-core container, virtual devices cannot show wall-clock
speedup, so this bench reports (a) the measured single-device per-iteration
time, and (b) the modeled multi-device scaling from the SU-ALS roofline
terms (per-device flops and reduction bytes both shrink ~1/p — the paper's
Fig. 9 close-to-linear claim; its small overhead is the reduce-scatter)."""
from __future__ import annotations

import jax

from repro.core import als as als_mod
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.sparse import synth

from benchmarks.common import emit, time_fn


def run():
    spec = synth.SynthSpec("scaling-mini", m=4096, n=512, nnz=400_000,
                           f=32, lam=0.05)
    r, rt, _, _ = synth.make_synthetic_ratings(spec, seed=1)
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1, mode="ref")
    state = als_mod.als_init(r.m, rt.m, cfg)
    rr, rtt = als_mod.ell_triplet(r), als_mod.ell_triplet(rt)

    it = jax.jit(lambda s: als_mod.als_iteration(s, rr, rtt, cfg))
    us1 = time_fn(it, state, iters=3)
    emit("fig9_scaling_1dev_measured", us1, f"m={r.m};nnz={r.nnz};f={spec.f}")

    # modeled p-device iteration time at paper scale (Netflix, f=100->128)
    s = synth.DATASETS["netflix"]
    f = 128
    flops = 2 * s.nnz * f * f * 2          # both half-iterations, A only
    herm_bytes = 2 * (s.nnz * f * 4 + (s.m + s.n) * f * f * 4)
    t1 = None
    for p in (1, 2, 4, 8, 16):
        comp = flops / p / PEAK_FLOPS_BF16
        mem = herm_bytes / p / HBM_BW
        red = 2 * (s.m + s.n) / p * f * f * 4 * (p - 1) / p / ICI_BW
        t = max(comp, mem) + red
        if t1 is None:
            t1 = t * 1.0
        eff = t1 / (t * p)        # parallel efficiency vs 1 device
        emit(f"fig9_scaling_modeled_p{p}", t * 1e6,
             f"eff={eff:.2f};comp_s={comp:.4f};mem_s={mem:.4f};"
             f"reduce_s={red:.4f}")


if __name__ == "__main__":
    run()
