"""Paper Fig. 7 analogue: accumulator placement ablation.

cuMF's biggest single win (2.5x on Netflix) is keeping A_u in the register
file instead of round-tripping global memory per bin.  The TPU analogue is
the VMEM-scratch accumulator vs an HBM round trip per k-tile.  On this CPU
container we measure the two XLA execution strategies directly (single
fused pass vs per-bin materialize+add) and report both the wall-clock ratio
and the modeled HBM-write ratio (the structural quantity that carries to
TPU: one A write per row vs one per bin)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref

from benchmarks.common import emit, time_fn


def _problem(m=2048, n=4096, K=256, f=64, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (m, K)), jnp.int32)
    cnt = jnp.asarray(rng.integers(K // 2, K + 1, (m,)), jnp.int32)
    val = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
    return theta, idx, val, cnt


@jax.jit
def fused_accum(theta, idx, val, cnt):
    """Register/VMEM strategy: one pass, accumulator never leaves fast mem."""
    g = jnp.take(theta, idx, axis=0)
    mask = kref.mask_from_cnt(cnt, idx.shape[1], theta.dtype)
    diag = jnp.where(cnt > 0, 0.05 * cnt.astype(jnp.float32), 1.0)
    return kref.herm_ref(g, val, mask, diag)


@jax.jit
def binned_hbm_accum(theta, idx, val, cnt):
    """No-register strategy: materialize+add A per bin (paper Fig. 7 'w/o')."""
    m, K = idx.shape
    f = theta.shape[1]
    bins = 8
    kb = K // bins
    acc_a = jnp.zeros((m, f, f), jnp.float32)
    acc_b = jnp.zeros((m, f), jnp.float32)
    for b in range(bins):
        sl = slice(b * kb, (b + 1) * kb)
        g = jnp.take(theta, idx[:, sl], axis=0)
        mask = (jnp.arange(b * kb, (b + 1) * kb)[None, :]
                < cnt[:, None]).astype(theta.dtype)
        gm = g * mask[..., None]
        # optimization barrier forces the per-bin accumulator materialization
        acc_a = jax.lax.optimization_barrier(
            acc_a + jnp.einsum("ukf,ukg->ufg", gm, g))
        acc_b = jax.lax.optimization_barrier(
            acc_b + jnp.einsum("uk,ukf->uf", val[:, sl] * mask, g))
    diag = jnp.where(cnt > 0, 0.05 * cnt.astype(jnp.float32), 1.0)
    return acc_a + diag[:, None, None] * jnp.eye(f), acc_b


def run():
    args = _problem()
    m, K = args[1].shape
    f = args[0].shape[1]
    us_fused = time_fn(fused_accum, *args)
    us_binned = time_fn(binned_hbm_accum, *args)
    bins = 8
    # HBM writes of the accumulator: once per row tile vs once per bin
    write_ratio = bins  # m*f^2*bins vs m*f^2
    emit("fig7_register_fused", us_fused,
         f"A_hbm_writes={m * f * f}")
    emit("fig7_register_hbm_binned", us_binned,
         f"A_hbm_writes={m * f * f * bins};slowdown={us_binned / us_fused:.2f}x;"
         f"modeled_write_ratio={write_ratio}x")


if __name__ == "__main__":
    run()
