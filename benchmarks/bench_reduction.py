"""Paper §4.2 / Fig. 5: one-phase vs two-phase (topology-aware) parallel
reduction — analytic slow-link traffic + a subprocess-measured correctness
run on 8 virtual devices."""
from __future__ import annotations

from repro.distributed.collectives import collective_bytes_reduce
from repro.launch.mesh import DCI_BW, ICI_BW

from benchmarks.common import emit


def run():
    # Netflix-scale reduction payload: a q-batch of Hermitians,
    # 32768 rows x 128 x 128 fp32
    nbytes = 32768 * 128 * 128 * 4
    for p_fast, p_slow in ((16, 2), (16, 4)):
        r = collective_bytes_reduce(nbytes, p_fast, p_slow)
        t_flat = r["flat"]["fast_link"] / ICI_BW + \
            r["flat"]["slow_link"] / DCI_BW
        t_hier = r["hierarchical"]["fast_link"] / ICI_BW + \
            r["hierarchical"]["slow_link"] / DCI_BW
        emit(f"fig5_reduction_p{p_fast}x{p_slow}_flat", t_flat * 1e6,
             f"slow_link_bytes={r['flat']['slow_link']:.3g}")
        emit(f"fig5_reduction_p{p_fast}x{p_slow}_two_phase", t_hier * 1e6,
             f"slow_link_bytes={r['hierarchical']['slow_link']:.3g};"
             f"slow_link_saving={r['slow_link_saving']:.1f}x;"
             f"speedup={t_flat / t_hier:.2f}x")


if __name__ == "__main__":
    run()
