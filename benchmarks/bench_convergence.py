"""Paper Fig. 6: test-RMSE convergence vs iterations (Netflix/YahooMusic
protocol) on planted synthetic data at CPU-feasible scale."""
from __future__ import annotations

import time

from repro.core import als as als_mod
from repro.sparse import synth

from benchmarks.common import emit


def run():
    # yahoomusic's lambda=1.4 targets 0-100-scale ratings; the planted
    # model emits ~N(0,1) ratings, so the scale-equivalent lambda is /10
    for name, lam in (("netflix", 0.05), ("yahoomusic", 0.14)):
        spec = synth.SynthSpec(f"{name}-mini", m=1536, n=256, nnz=90_000,
                               f=16, lam=lam)
        r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=3, noise=0.1)
        cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=8, mode="ref")
        t0 = time.perf_counter()
        _, hist = als_mod.als_train(
            als_mod.ell_triplet(r), als_mod.ell_triplet(rt), r.m, rt.m, cfg,
            test=als_mod.ell_triplet(rte))
        dt = (time.perf_counter() - t0) / cfg.iters * 1e6
        curve = ";".join(f"{h['test_rmse']:.3f}" for h in hist)
        emit(f"fig6_convergence_{name}", dt, f"rmse_curve={curve}")


if __name__ == "__main__":
    run()
