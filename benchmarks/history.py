"""Bench history: append every emission to a provenance-stamped JSONL.

``benchmarks/run.py`` calls :func:`append_history` for every module whose
``run()`` returned JSON records, so ``BENCH_HISTORY.jsonl`` accumulates one
line per (bench, run) with enough provenance to compare like with like::

    {"schema": "repro.obs/bench-history-v1",
     "provenance": {"git_sha": ..., "timestamp": ..., "backend": ...,
                    "device_count": ..., "jax": ..., "quick": ...},
     "bench": "bench_huge", "records": [...]}

``python -m repro.obs.regress --history BENCH_HISTORY.jsonl`` is the
consumer: newest entry vs a rolling baseline of prior entries with the
same (bench, quick, backend, device_count) configuration — deterministic
byte/count metrics exact, time metrics warn-only.  CI's bench-smoke lane
caches the file across runs so the baseline is real lineage, not a
same-run echo.

The same provenance dict is also stamped INTO each emitted JSON record
(``record["provenance"]``) so a BENCH_*.json file downloaded as an
artifact is self-describing without its history line.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess

HISTORY_SCHEMA = "repro.obs/bench-history-v1"   # mirror of repro.obs.regress


def provenance(quick: bool = False) -> dict:
    """Where/when/what of this bench process.  Every field degrades to a
    sentinel rather than raising — benches must run in a bare checkout
    (no git) and in environments where jax fails to initialize."""
    here = os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=here, capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except OSError:
        sha = "unknown"
    prov = {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "quick": bool(quick),
        "backend": "unknown",
        "device_count": 0,
        "jax": "unknown",
    }
    try:
        import jax
        prov["backend"] = jax.default_backend()
        prov["device_count"] = len(jax.devices())
        prov["jax"] = jax.__version__
    except Exception:
        pass
    return prov


def stamp(records: list[dict], prov: dict) -> list[dict]:
    """Attach the provenance dict to every emitted JSON record, in place."""
    for rec in records:
        rec["provenance"] = prov
    return records


def append_history(path: str, bench: str, records: list[dict],
                   prov: dict) -> None:
    """Append one history line for ``bench``'s emission."""
    entry = {"schema": HISTORY_SCHEMA, "provenance": prov,
             "bench": bench, "records": records}
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
