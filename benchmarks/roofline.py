import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (assignment §Roofline).

Methodology (documented in EXPERIMENTS.md):
- XLA's ``cost_analysis`` counts while/scan bodies ONCE regardless of trip
  count (verified empirically), so the full-program compile is used only as
  the memory-fits proof.  The roofline terms come from **unit compiles**:
  one scanned block per kind, the loss/logits head, and the optimizer
  update, each lowered at its true per-device shard shapes, multiplied by
  known trip counts (layers x microbatches).
- ``cost_analysis()`` numbers are PER DEVICE on a partitioned module
  (verified: a (4,4)-sharded matmul reports global/16), so terms divide by
  per-chip peaks directly.
- collective bytes are parsed per unit from the partitioned HLO text
  (operand shapes are already per-device) and scaled by the same
  multiplicities.

Terms (per training/serving step, seconds):
  compute    = HLO_flops_per_device / 197e12 (bf16 peak)
  memory     = HLO_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9 (ICI per-link)
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.launch import builders
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "roofline")


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (global, per step)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D-convention flops (causal-aware attention), true (unpadded)
    architecture — the 'useful work' numerator of the HLO ratio."""
    from repro.models.transformer import layer_pattern
    B, S = shape.batch, shape.seq
    if shape.kind == "decode":
        tokens = B
    else:
        tokens = B * S
    n_mat = cfg.active_params_count() - cfg.vocab * cfg.d_model * (
        1 if cfg.tie_embeddings else 0)   # head matmul counts once
    fwd = 2.0 * n_mat * tokens

    for kind in layer_pattern(cfg):
        if kind == "attn":
            w = cfg.sliding_window
            if shape.kind == "decode":
                ctx = min(w, S) if w else S
                fwd += 4.0 * B * cfg.n_heads * cfg.d_head * ctx
            else:
                ctx = (min(w, S) if w else S / 2.0)
                fwd += 4.0 * B * S * cfg.n_heads * cfg.d_head * ctx
        elif kind == "rwkv":
            fwd += 3.0 * tokens * cfg.d_model * 64    # WKV state update
        elif kind == "rglru":
            fwd += 8.0 * tokens * (cfg.d_rnn or cfg.d_model)
    if shape.kind == "train":
        return 3.0 * fwd
    return fwd


def analytic_bytes(spec, shape: ShapeConfig, mb: int, n_chips: int) -> float:
    """First-order per-device HBM traffic per step (TPU-fusion estimate).

    XLA-CPU ``bytes accessed`` counts every unfused elementwise op, which a
    TPU backend would fuse into the surrounding matmuls, so it overstates
    HBM traffic ~3-6x.  This model counts the streams that must touch HBM:
    parameter reads (per microbatch, fwd+bwd), optimizer state sweeps,
    residual/activation traffic, KV/cache reads, and the logits tensor.
    Coefficients documented in EXPERIMENTS.md §Roofline.
    """
    from repro.models.transformer import layer_pattern
    cfg = spec.model
    B, S = shape.batch, shape.seq
    P = cfg.params_count()
    L = cfg.n_layers
    d = cfg.d_model

    if shape.kind == "train":
        toks_dev = B * S / n_chips
        toks_mb_dev = toks_dev / mb
        param_stream = 2 * mb * (P * 2) * 2 / n_chips * n_chips  # gathered:
        # each device materializes the full bf16 params per microbatch
        # (fwd + bwd) under FSDP — the all-gather writes them to local HBM
        # and the matmuls read them back:
        param_stream = 2 * 2 * mb * (P * 2)
        opt_bytes = 12 if spec.opt == "adamw" else 4.5
        opt_stream = 2 * opt_bytes * P / n_chips + 2 * 4 * P / n_chips
        act_stream = 2.5 * 12 * toks_dev * d * 2 * L
        attn_stream = 0.0
        for kind in layer_pattern(cfg):
            if kind == "attn":
                ctx = min(cfg.sliding_window or S, S)
                # KV re-read per q-chunk (chunk 512) over fwd+bwd+remat
                attn_stream += 2.5 * (toks_dev / 512) * ctx * \
                    cfg.n_kv * cfg.d_head * 2 * 2
        logit_stream = 3 * 2.5 * toks_dev * cfg.vocab / 16 * 2
        return (param_stream + opt_stream + act_stream + attn_stream
                + logit_stream)

    if shape.kind == "prefill":
        toks_dev = B * S / n_chips
        param_stream = P * 2 / 16          # TP-sharded weights, read once
        act_stream = 8 * toks_dev * d * 2 * L
        attn_stream = 0.0
        for kind in layer_pattern(cfg):
            if kind == "attn":
                ctx = min(cfg.sliding_window or S, S)
                attn_stream += (toks_dev / 512) * ctx * cfg.n_kv \
                    * cfg.d_head * 2 * 2
        return param_stream + act_stream + attn_stream

    # decode: weight + cache streams dominate
    from repro.models import transformer as T
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S, jnp.bfloat16))
    cache_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(cache))
    param_stream = P * 2 / 16
    return param_stream + cache_bytes / n_chips + 10 * B * d * 2 * L / n_chips


# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Unit:
    name: str
    fn: object
    args: tuple
    mult: float


def _x_struct(mesh, dp, b, s, d, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct((b, s, d), dtype,
                                sharding=NamedSharding(mesh, P(dp, None, None)))


def _single_layer_structs(cfg, kind, policy, mesh, dtype):
    from repro.models import transformer as T
    shapes = T._BLOCK_SHAPES[kind](cfg)

    def mk(leaf):
        shp, axes = leaf
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(mesh, policy.spec(axes)))
    return {k: mk(v) for k, v in shapes.items()}


def _kind_counts(cfg):
    from repro.models.transformer import layer_pattern
    counts = {}
    for k in layer_pattern(cfg):
        counts[k] = counts.get(k, 0) + 1
    return counts


def train_units(spec, shape, mesh, opts) -> list[Unit]:
    from repro.models import transformer as T
    from repro.models.lm import _xent
    from repro.training import optimizer as opt_mod

    cfg = spec.model
    dpn = builders.dp_size(mesh) * (mesh.shape["model"] if opts.tp1 else 1)
    mb = max(1, min(opts.microbatch or spec.microbatch, shape.batch // dpn))
    b_mb = shape.batch // mb
    dp = builders._dp_spec(mesh, b_mb, tp1=opts.tp1)
    policy = builders._train_policy(spec, mesh, tp1=opts.tp1)
    units = []
    positions = jnp.arange(shape.seq)[None]

    for kind, count in _kind_counts(cfg).items():
        lp = _single_layer_structs(cfg, kind, policy, mesh, jnp.float32)
        x = _x_struct(mesh, dp, b_mb, shape.seq, cfg.d_model)

        def layer_loss(p, xx, kind=kind):
            pos = jnp.broadcast_to(jnp.arange(xx.shape[1])[None],
                                   (xx.shape[0], xx.shape[1]))
            pc = jax.tree.map(lambda a: a.astype(jnp.bfloat16)
                              if a.dtype == jnp.float32 else a, p)
            y, _ = T._BLOCK_FWD[kind](
                cfg, pc, xx, pos, None, mode="train", mesh=mesh,
                lengths=None, serve_seq_shard=False,
                causal_skip=opts.causal_skip,
                chunk_q=opts.chunk_q, chunk_kv=opts.chunk_kv)
            return jnp.sum(y.astype(jnp.float32))

        units.append(Unit(
            name=f"layer_{kind}_train",
            fn=jax.value_and_grad(layer_loss, argnums=(0, 1)),
            args=(lp, x), mult=count * mb))

    # loss head (logits + xent + bwd)
    emb = jax.ShapeDtypeStruct(
        (cfg.padded_vocab, cfg.d_model), jnp.float32,
        sharding=NamedSharding(mesh, policy.spec(("vocab", "embed_d"))))
    hid = _x_struct(mesh, dp, b_mb, shape.seq, cfg.d_model)
    lbl = jax.ShapeDtypeStruct((b_mb, shape.seq), jnp.int32,
                               sharding=NamedSharding(mesh, P(dp, None)))

    from repro.distributed.sharding import vocab_axis

    def head_loss(e, h, l):
        logits = jnp.einsum("bsd,vd->bsv", h, e.astype(h.dtype))
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, P(dp, None, vocab_axis(dp))))
        return jnp.mean(_xent(logits, l))

    units.append(Unit("loss_head_train",
                      jax.value_and_grad(head_loss, argnums=(0, 1)),
                      (emb, hid, lbl), mult=mb))

    # optimizer update over the full stacked params
    state = builders.train_state_structs(spec, mesh)
    _, opt_update = opt_mod.make_optimizer(opt_mod.OptConfig(name=spec.opt))

    def opt_step(grads, opt_state, params):
        return opt_update(grads, opt_state, params)

    units.append(Unit("optimizer", opt_step,
                      (state.params, state.opt, state.params), mult=1.0))
    return units


def fwd_units(spec, shape, mesh, opts) -> list[Unit]:
    """prefill: per-kind forward blocks + last-token logits."""
    from repro.models import transformer as T
    cfg = spec.model
    dp = builders._dp_spec(mesh, shape.batch)
    policy = builders._serve_policy(spec, mesh)
    units = []
    for kind, count in _kind_counts(cfg).items():
        lp = _single_layer_structs(cfg, kind, policy, mesh, jnp.bfloat16)
        x = _x_struct(mesh, dp, shape.batch, shape.seq, cfg.d_model)

        def layer_fwd(p, xx, kind=kind):
            pos = jnp.broadcast_to(jnp.arange(xx.shape[1])[None],
                                   (xx.shape[0], xx.shape[1]))
            y, _ = T._BLOCK_FWD[kind](
                cfg, p, xx, pos, None, mode="train", mesh=mesh,
                lengths=None, serve_seq_shard=False,
                causal_skip=opts.causal_skip,
                chunk_q=opts.chunk_q, chunk_kv=opts.chunk_kv)
            return y
        units.append(Unit(f"layer_{kind}_fwd", layer_fwd, (lp, x),
                          mult=count))

    emb = jax.ShapeDtypeStruct(
        (cfg.padded_vocab, cfg.d_model), jnp.bfloat16,
        sharding=NamedSharding(mesh, policy.spec(("vocab", "embed_d"))))
    hid = _x_struct(mesh, dp, shape.batch, 1, cfg.d_model)
    units.append(Unit(
        "logits_last",
        lambda e, h: jnp.einsum("bsd,vd->bsv", h, e), (emb, hid), mult=1.0))
    return units


def decode_units(spec, shape, mesh, opts) -> list[Unit]:
    from repro.models import transformer as T
    cfg = spec.model
    dp = builders._dp_spec(mesh, shape.batch)
    policy = builders._serve_policy(spec, mesh)
    units = []
    lengths = jax.ShapeDtypeStruct((shape.batch,), jnp.int32,
                                   sharding=NamedSharding(mesh, P(dp)))
    for kind, count in _kind_counts(cfg).items():
        lp = _single_layer_structs(cfg, kind, policy, mesh, jnp.bfloat16)
        x = _x_struct(mesh, dp, shape.batch, 1, cfg.d_model)
        cache_one = jax.eval_shape(
            lambda: T._block_cache_shape(cfg, kind, shape.batch, shape.seq,
                                         jnp.bfloat16))

        def shard_cache(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("k", "v"):
                seq = "model" if spec.serve_seq_shard else None
                kv = ("model" if (not spec.serve_seq_shard
                                  and cfg.padded_kv % mesh.shape["model"] == 0
                                  and not cfg.sliding_window) else None)
                sp = P(dp, seq, kv, None)
            elif name == "pos":
                sp = P(dp, None)
            elif name == "s":
                sp = P(dp, "model", None, None)
            else:
                sp = P(*([dp] + [None] * (leaf.ndim - 1)))
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, sp))
        cache = jax.tree_util.tree_map_with_path(shard_cache, cache_one)

        def layer_dec(p, c, xx, ln, kind=kind):
            pos = ln[:, None]
            y, nc = T._BLOCK_FWD[kind](
                cfg, p, xx, pos, c, mode="decode", mesh=mesh, lengths=ln,
                serve_seq_shard=spec.serve_seq_shard,
                causal_skip=False, chunk_q=512, chunk_kv=512)
            return y, nc
        units.append(Unit(f"layer_{kind}_decode", layer_dec,
                          (lp, cache, x, lengths), mult=count))

    emb = jax.ShapeDtypeStruct(
        (cfg.padded_vocab, cfg.d_model), jnp.bfloat16,
        sharding=NamedSharding(mesh, policy.spec(("vocab", "embed_d"))))
    hid = _x_struct(mesh, dp, shape.batch, 1, cfg.d_model)
    units.append(Unit(
        "logits_decode",
        lambda e, h: jnp.argmax(jnp.einsum("bsd,vd->bsv", h, e), -1),
        (emb, hid), mult=1.0))
    return units


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def roofline_cell(arch_id: str, shape_name: str,
                  opts: builders.CellOpts = builders.CellOpts(),
                  save: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=False)
    spec = registry.get_arch(arch_id)
    shape = SHAPES[shape_name]
    if spec.skip_reason(shape):
        return {"arch": arch_id, "shape": shape_name, "status": "skip",
                "reason": spec.skip_reason(shape)}

    if shape.kind == "train":
        units = train_units(spec, shape, mesh, opts)
    elif shape.kind == "prefill":
        units = fwd_units(spec, shape, mesh, opts)
    else:
        units = decode_units(spec, shape, mesh, opts)

    flops = bytes_ = coll = 0.0
    per_unit = []
    with mesh:
        for u in units:
            lowered = jax.jit(u.fn).lower(*u.args)
            compiled = lowered.compile()
            ca = compiled.cost_analysis()
            cc = parse_collectives(compiled.as_text())
            f = ca.get("flops", 0.0) * u.mult
            b = ca.get("bytes accessed", 0.0) * u.mult
            c = cc["total_bytes"] * u.mult
            flops += f
            bytes_ += b
            coll += c
            per_unit.append({"unit": u.name, "mult": u.mult,
                             "flops": f, "bytes": b, "coll_bytes": c,
                             "collectives": cc["count"]})

    n_chips = mesh.devices.size
    mf = model_flops(spec.model, shape)
    mb = max(1, min(opts.microbatch or spec.microbatch,
                    shape.batch // builders.dp_size(mesh))) \
        if shape.kind == "train" else 1
    abytes = analytic_bytes(spec, shape, mb, n_chips)
    terms = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": abytes / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound_time = max(terms.values())
    step_time_lb = bound_time  # roofline lower bound on step time
    rec = {
        "arch": arch_id, "shape": shape_name, "status": "ok",
        "chips": n_chips,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_,
        "analytic_bytes_per_device": abytes,
        "memory_s_hlo_unfused": bytes_ / HBM_BW,
        "collective_bytes_per_device": coll,
        "model_flops_global": mf,
        "useful_ratio": mf / max(flops * n_chips, 1.0),
        "terms_s": terms,
        "dominant": dominant,
        "roofline_step_s": step_time_lb,
        "mfu_upper_bound": mf / (n_chips * PEAK_FLOPS_BF16 * step_time_lb)
        if step_time_lb else 0.0,
        "units": per_unit,
    }
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        suffix = "_opt" if (opts.causal_skip or opts.fused_loss
                            or opts.tp1) else ""
        with open(os.path.join(
                ARTIFACT_DIR, f"{arch_id}_{shape_name}{suffix}.json"),
                "w") as fh:
            json.dump(rec, fh, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--fused-loss", action="store_true")
    ap.add_argument("--tp1", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    opts = builders.CellOpts(causal_skip=args.causal_skip,
                             fused_loss=args.fused_loss, tp1=args.tp1)
    cells = ([(args.arch, args.shape)] if args.arch
             else [(a, s) for a in registry.list_archs() for s in SHAPES])
    for arch_id, shape_name in cells:
        suffix = "_opt" if (opts.causal_skip or opts.fused_loss
                            or opts.tp1) else ""
        path = os.path.join(ARTIFACT_DIR, f"{arch_id}_{shape_name}{suffix}.json")
        if args.resume and os.path.exists(path):
            print(f"[roofline] {arch_id} {shape_name}: cached", flush=True)
            continue
        t0 = time.time()
        try:
            rec = roofline_cell(arch_id, shape_name, opts)
            if rec["status"] == "ok":
                t = rec["terms_s"]
                print(f"[roofline] {arch_id} {shape_name}: "
                      f"comp={t['compute_s']:.4f}s mem={t['memory_s']:.4f}s "
                      f"coll={t['collective_s']:.4f}s dom={rec['dominant']} "
                      f"useful={rec['useful_ratio']:.2f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            else:
                print(f"[roofline] {arch_id} {shape_name}: skip", flush=True)
        except Exception:
            print(f"[roofline] {arch_id} {shape_name}: ERROR", flush=True)
            traceback.print_exc()


if __name__ == "__main__":
    main()
