"""Kernel-level microbenches: fused A+B pass (beyond-paper fusion) vs the
paper's two-pass structure, and the batched Cholesky solve path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref

from benchmarks.common import emit, time_fn


def _problem(m=2048, n=4096, K=256, f=64, seed=0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (m, K)), jnp.int32)
    cnt = jnp.asarray(rng.integers(K // 2, K + 1, (m,)), jnp.int32)
    val = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
    return theta, idx, val, cnt


@jax.jit
def one_pass(theta, idx, val, cnt):
    """Fused: A and B from one sweep (this repo's kernel structure)."""
    g = jnp.take(theta, idx, axis=0)
    mask = kref.mask_from_cnt(cnt, idx.shape[1], theta.dtype)
    diag = jnp.where(cnt > 0, 0.05 * cnt.astype(jnp.float32), 1.0)
    return kref.herm_ref(g, val, mask, diag)


@jax.jit
def two_pass(theta, idx, val, cnt):
    """cuMF structure: get_hermitian kernel + separate cuSPARSE B pass."""
    g = jax.lax.optimization_barrier(jnp.take(theta, idx, axis=0))
    mask = kref.mask_from_cnt(cnt, idx.shape[1], theta.dtype)
    gm = g * mask[..., None]
    A = jnp.einsum("ukf,ukg->ufg", gm, g)
    g2 = jax.lax.optimization_barrier(jnp.take(theta, idx, axis=0))
    B = jnp.einsum("uk,ukf->uf", val * mask, g2)
    diag = jnp.where(cnt > 0, 0.05 * cnt.astype(jnp.float32), 1.0)
    return A + diag[:, None, None] * jnp.eye(theta.shape[1]), B


@jax.jit
def solve(A, B):
    return kref.batch_solve_ref(A, B)


def run():
    args = _problem()
    us1 = time_fn(one_pass, *args)
    us2 = time_fn(two_pass, *args)
    emit("kern_fused_AB_one_pass", us1, "passes=1")
    emit("kern_paper_two_pass", us2,
         f"passes=2;fusion_speedup={us2 / us1:.2f}x")
    A, B = one_pass(*args)
    us3 = time_fn(solve, A, B)
    m, f = B.shape
    emit("kern_batch_solve", us3,
         f"batch={m};f={f};gflops={(m * f**3 / 3) / (us3 * 1e-6) / 1e9:.1f}")


if __name__ == "__main__":
    run()
