"""ALS vs SGD vs hybrid: epochs/sec and RMSE-vs-wall-clock (CuMF_SGD's
Fig. 7 protocol — "time to RMSE", not per-iteration flops) on the scaled
planted-Netflix recipe.

Each solver runs to convergence-ish on identical data; every epoch (ALS
iteration / SGD epoch) appends a (cumulative seconds, test RMSE) point.
A fourth ``sgd_stream`` row runs the same SGD recipe through the
out-of-core tile-wave driver at a capped capacity (waves >= 2 per
diagonal set), recording the budget, the metered peak, and the streamed
traffic next to its RMSE curve.  The ``sgd_stream_skew`` /
``sgd_stream_binned`` pair reruns that streaming recipe on power-law
*users*: the uniform grid as its own baseline vs degree-sorted
per-tile-K tiles, which must cut fill waste >= 1.5x at the same RMSE.
The records land in BENCH_sgd.json via ``benchmarks/run.py``'s generic
JSON path; ``run(quick=True)`` (the CI smoke) shrinks the problem and
epoch counts.
"""
from __future__ import annotations

import time

from repro.core import als as als_mod
from repro.outofcore import TileStore, build_sgd_schedule, run_streaming_sgd
from repro.sgd import SgdConfig, block_ell, hybrid_train, sgd_train
from repro.sparse import synth

from benchmarks.common import emit

JSON_OUT = "BENCH_sgd.json"


def _timed_curve():
    """A callback capturing (cumulative wall seconds, test rmse) per epoch."""
    t0 = time.perf_counter()
    points: list[dict] = []

    def cb(_state, rec):
        points.append({"t": time.perf_counter() - t0,
                       "rmse": rec.get("test_rmse")})

    return points, cb


def run(quick: bool = False):
    if quick:
        spec = synth.SynthSpec("netflix-micro", m=512, n=128, nnz=20_000,
                               f=8, lam=0.05)
        als_iters, sgd_epochs, hyb_epochs = 3, 8, 6
    else:
        spec = synth.SynthSpec("netflix-mini", m=1536, n=256, nnz=90_000,
                               f=16, lam=0.05)
        als_iters, sgd_epochs, hyb_epochs = 8, 40, 24
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=3, noise=0.1)
    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    grid = block_ell(r, g=4)

    records = []

    def record(solver, points, epochs, **extra):
        total = points[-1]["t"] if points else 0.0
        rec = {
            "solver": solver, "m": spec.m, "n": spec.n, "nnz": r.nnz,
            "f": spec.f, "g": grid.g, "epochs": epochs,
            "final_rmse": points[-1]["rmse"] if points else None,
            "epochs_per_sec": epochs / total if total else None,
            "curve": points, **extra,
        }
        records.append(rec)
        emit(f"sgd_vs_als_{solver}", total / max(epochs, 1) * 1e6,
             f"final_rmse={rec['final_rmse']:.4f};"
             f"epochs_per_sec={rec['epochs_per_sec']:.2f}")
        return rec

    als_cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=als_iters,
                                mode="ref")
    points, cb = _timed_curve()
    als_mod.als_train(rr, rtt, r.m, rt.m, als_cfg, test=rtest, callback=cb)
    record("als", points, als_cfg.iters)

    sgd_cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.15, epochs=sgd_epochs,
                        schedule="cosine", mode="ref", seed=1)
    points, cb = _timed_curve()
    sgd_train(grid, sgd_cfg, test=rtest, callback=cb)
    record("sgd", points, sgd_cfg.epochs)

    warm_cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=2, mode="ref")
    ref_cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.15, epochs=hyb_epochs,
                        schedule="cosine", mode="ref", seed=1)
    points, cb = _timed_curve()   # hybrid_train forwards cb to both phases
    hybrid_train(rr, rtt, grid, warm_cfg, ref_cfg, test=rtest, callback=cb)
    record("hybrid", points, warm_cfg.iters + ref_cfg.epochs)

    # capped-capacity streaming row: same SGD recipe through the tile-wave
    # driver, 2 simulated workers -> 2 waves per diagonal set
    tiles = TileStore(grid)
    sched = build_sgd_schedule(grid, spec.f, n_workers=2)
    points, cb = _timed_curve()
    _, _, tel = run_streaming_sgd(tiles, sched, sgd_cfg, test_eval=rtest,
                                  callback=cb)
    rec = record("sgd_stream", points, sgd_cfg.epochs,
                 waves_per_epoch=sched.waves_per_epoch,
                 capacity_bytes=tel.capacity_bytes,
                 peak_bytes=tel.peak_bytes,
                 bytes_streamed=tel.bytes_streamed,
                 padded_slots=tel.padded_slots,
                 nnz_streamed=tel.nnz_streamed,
                 fill_waste_ratio=round(tel.fill_waste_ratio, 6),
                 wall_seconds=tel.wall_seconds,
                 phase_seconds={k: round(v, 4)
                                for k, v in tel.phase_seconds.items()})
    assert rec["peak_bytes"] <= rec["capacity_bytes"], rec

    # degree-binned streaming pair: power-law *users* (alpha_user, the skew
    # real rating matrices show on both axes) make the grid-wide uniform K
    # pad badly.  Two NEW rows on that data — the uniform layout as its own
    # baseline, then degree-sorted per-tile-K tiles — both refining the
    # SAME ALS warm start (hybrid protocol), so the layouts are compared at
    # their converged plateau: >= 1.5x less fill waste at the same RMSE
    # (the degree sort changes the still-exact visit order, so factors are
    # equivalent, not bit-equal).
    import numpy as np

    from repro.outofcore import FactorStore
    from repro.sgd.hybrid import sgd_state_from_als

    skew_r, skew_rt, skew_rte, _ = synth.make_synthetic_ratings(
        spec, seed=3, noise=0.1, alpha_user=1.2)
    skew_rr, skew_rtt, skew_rtest = (
        als_mod.ell_triplet(e) for e in (skew_r, skew_rt, skew_rte))
    warm_state, _ = als_mod.als_train(
        skew_rr, skew_rtt, skew_r.m, skew_rt.m,
        als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=4, mode="ref"))
    skew_cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.05,
                         epochs=sgd_cfg.epochs, schedule="cosine",
                         mode="ref", seed=1)

    def stream_skew(solver, **grid_kw):
        grid = block_ell(skew_r, g=4, **grid_kw)
        sched = build_sgd_schedule(grid, spec.f, n_workers=2)
        st0 = sgd_state_from_als(warm_state, grid)
        warm = FactorStore.from_arrays(np.asarray(st0.x),
                                       np.asarray(st0.theta))
        points, cb = _timed_curve()
        _, _, tel = run_streaming_sgd(TileStore(grid), sched, skew_cfg,
                                      factors=warm, test_eval=skew_rtest,
                                      callback=cb)
        return record(solver, points, skew_cfg.epochs,
                      waves_per_epoch=sched.waves_per_epoch,
                      per_tile_k=grid.tile_K is not None,
                      degree_sorted=grid.user_perm is not None,
                      capacity_bytes=tel.capacity_bytes,
                      peak_bytes=tel.peak_bytes,
                      bytes_streamed=tel.bytes_streamed,
                      padded_slots=tel.padded_slots,
                      nnz_streamed=tel.nnz_streamed,
                      fill_waste_ratio=round(tel.fill_waste_ratio, 6),
                      wall_seconds=tel.wall_seconds,
                      phase_seconds={k: round(v, 4)
                                     for k, v in tel.phase_seconds.items()})

    urec = stream_skew("sgd_stream_skew")
    brec = stream_skew("sgd_stream_binned", per_tile_k=True,
                       degree_sort=True)
    brec["fill_waste_vs_uniform"] = round(
        urec["fill_waste_ratio"] / brec["fill_waste_ratio"], 4)
    assert brec["fill_waste_vs_uniform"] >= 1.5, (
        urec["fill_waste_ratio"], brec["fill_waste_ratio"])
    assert brec["peak_bytes"] <= brec["capacity_bytes"], brec
    assert brec["final_rmse"] <= urec["final_rmse"] * 1.02, \
        (brec["final_rmse"], urec["final_rmse"])
    emit("sgd_binned_fill_win", 0.0,
         f"fill_waste {urec['fill_waste_ratio']:.3f} -> "
         f"{brec['fill_waste_ratio']:.3f} "
         f"({brec['fill_waste_vs_uniform']:.2f}x, per_tile_k+degree_sort)")

    # p > 1 mesh row: the same tile waves sharded one-tile-per-device over a
    # (data, model) mesh.  Skipped (with a CSV note) below 8 devices; CI's
    # bench-smoke forces 8 host devices so the row is always present there.
    import jax
    if len(jax.devices()) >= 8:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        points, cb = _timed_curve()
        _, _, mtel = run_streaming_sgd(TileStore(grid), sched, sgd_cfg,
                                       test_eval=rtest, mesh=mesh,
                                       callback=cb)
        mrec = record("sgd_stream_mesh", points, sgd_cfg.epochs,
                      waves_per_epoch=sched.waves_per_epoch,
                      mesh_shape={"data": 4, "model": 2},
                      capacity_bytes=mtel.capacity_bytes,
                      peak_bytes=mtel.peak_bytes,
                      bytes_streamed=mtel.bytes_streamed,
                      padded_slots=mtel.padded_slots,
                      nnz_streamed=mtel.nnz_streamed,
                      fill_waste_ratio=round(mtel.fill_waste_ratio, 6),
                      wall_seconds=mtel.wall_seconds,
                      phase_seconds={k: round(v, 4)
                                     for k, v in mtel.phase_seconds.items()})
        assert mrec["peak_bytes"] <= mrec["capacity_bytes"], mrec
        assert abs(mrec["final_rmse"] - rec["final_rmse"]) < 1e-3, \
            (mrec["final_rmse"], rec["final_rmse"])
    else:
        emit("sgd_stream_mesh_skipped", 0.0,
             f"needs 8 devices, have {len(jax.devices())};"
             "run under --xla_force_host_platform_device_count=8")
    return records


if __name__ == "__main__":
    run()
