"""Multi-device tests (SU-ALS parity, reduction schemes, flash-decode, MoE
EP).  Each test runs in a subprocess with XLA_FLAGS forcing 8 host devices,
so the main pytest process keeps the real single-device view (required:
no global XLA_FLAGS)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_script(body: str):
    # Propagate the parent environment (local XLA_FLAGS / PYTHONPATH
    # overrides survive); only add what the subprocess additionally needs:
    # 8 forced host devices and the repo's src on the import path.  The
    # device count itself is PINNED, not inherited: these tests are
    # written for an 8-way topology, and importing repro.launch.dryrun
    # anywhere in the parent process plants a 512-device flag in
    # os.environ that must not leak through.
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append("--xla_force_host_platform_device_count=8")
    env["XLA_FLAGS"] = " ".join(flags)
    src = os.path.join(REPO, "src")
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    out = subprocess.run([sys.executable, "-c", body], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


COMMON = """
import jax, numpy as np, jax.numpy as jnp
from repro.sparse import synth, padded
from repro.core import als as als_mod
from repro.distributed import su_als
from repro.launch.mesh import make_mesh

def make_problem(p, seed=1):
    spec = synth.scaled(synth.DATASETS['netflix'], 0.004, f=16)
    r_tr, r_tr_T, _, _ = synth.make_synthetic_ratings(spec, seed=seed)
    def pad_rows(e, mult):
        m2 = -(-e.m // mult) * mult
        return padded.PaddedELL(
            np.pad(e.idx, ((0, m2-e.m), (0, 0))),
            np.pad(e.val, ((0, m2-e.m), (0, 0))),
            np.pad(e.cnt, (0, m2-e.m)), e.n_cols)
    r_tr, r_tr_T = pad_rows(r_tr, 8), pad_rows(r_tr_T, 8)
    m, n = r_tr.m, r_tr_T.m
    r_tr = padded.PaddedELL(r_tr.idx, r_tr.val, r_tr.cnt, n)
    r_tr_T = padded.PaddedELL(r_tr_T.idx, r_tr_T.val, r_tr_T.cnt, m)
    return r_tr, r_tr_T, m, n
"""


def test_su_als_matches_single_device_one_phase():
    run_script(COMMON + """
r_tr, r_tr_T, m, n = make_problem(4)
cfg = als_mod.AlsConfig(f=16, lam=0.05, iters=1, mode='ref')
state = als_mod.als_init(m, n, cfg)
st1 = als_mod.als_iteration(state, als_mod.ell_triplet(r_tr),
                            als_mod.ell_triplet(r_tr_T), cfg)
mesh = make_mesh((2, 4), ('data', 'model'))
rdev = su_als.shard_ratings(padded.partition_padded(r_tr, 4), mesh)
rtdev = su_als.shard_ratings(padded.partition_padded(r_tr_T, 4), mesh)
ux, ut, it = su_als.make_su_als_fns(mesh, 0.05, scheme='one_phase')
x2, t2 = it(state.x, state.theta, rdev, rtdev)
assert np.allclose(st1.x, x2, atol=2e-3), np.abs(np.asarray(st1.x)-np.asarray(x2)).max()
assert np.allclose(st1.theta, t2, atol=2e-3)
print('OK')
""")


def test_su_als_two_phase_multipod_matches():
    run_script(COMMON + """
r_tr, r_tr_T, m, n = make_problem(4)
cfg = als_mod.AlsConfig(f=16, lam=0.05, iters=1, mode='ref')
state = als_mod.als_init(m, n, cfg)
st1 = als_mod.als_iteration(state, als_mod.ell_triplet(r_tr),
                            als_mod.ell_triplet(r_tr_T), cfg)
mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
rdev = su_als.shard_ratings(padded.partition_padded(r_tr, 4), mesh)
rtdev = su_als.shard_ratings(padded.partition_padded(r_tr_T, 4), mesh)
for scheme in ('one_phase', 'two_phase'):
    ux, ut, it = su_als.make_su_als_fns(mesh, 0.05, scheme=scheme)
    x2, t2 = it(state.x, state.theta, rdev, rtdev)
    assert np.allclose(st1.x, x2, atol=2e-3), scheme
    assert np.allclose(st1.theta, t2, atol=2e-3), scheme
print('OK')
""")


def test_su_als_row_block_matches():
    run_script(COMMON + """
r_tr, r_tr_T, m, n = make_problem(4)
cfg = als_mod.AlsConfig(f=16, lam=0.05, iters=1, mode='ref')
state = als_mod.als_init(m, n, cfg)
mesh = make_mesh((2, 4), ('data', 'model'))
rdev = su_als.shard_ratings(padded.partition_padded(r_tr, 4), mesh)
rtdev = su_als.shard_ratings(padded.partition_padded(r_tr_T, 4), mesh)
_, _, it0 = su_als.make_su_als_fns(mesh, 0.05, row_block=0)
_, _, it1 = su_als.make_su_als_fns(mesh, 0.05, row_block=64)
xa, ta = it0(state.x, state.theta, rdev, rtdev)
xb, tb = it1(state.x, state.theta, rdev, rtdev)
assert np.allclose(xa, xb, atol=1e-4)
assert np.allclose(ta, tb, atol=1e-4)
print('OK')
""")


def test_flash_decode_matches_local():
    run_script("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models import layers as L
from repro.distributed.flash_decode import flash_decode

mesh = make_mesh((2, 4), ('data', 'model'))
rng = np.random.default_rng(0)
B, S, H, KV, dh = 4, 64, 8, 2, 16
q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
kc = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
vc = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
lengths = jnp.asarray([64, 10, 33, 1], jnp.int32)
want = L.attention_decode(q, kc, vc, lengths)
kd = jax.device_put(kc, NamedSharding(mesh, P('data', 'model')))
vd = jax.device_put(vc, NamedSharding(mesh, P('data', 'model')))
got = jax.jit(lambda a,b,c,d: flash_decode(a,b,c,d,mesh=mesh))(q, kd, vd, lengths)
assert np.allclose(want, got, atol=1e-4), np.abs(np.asarray(want-got)).max()
print('OK')
""")


def test_moe_ep_matches_single_device():
    run_script("""
import jax, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.models import moe as moe_mod

mesh = make_mesh((2, 4), ('data', 'model'))
D, FF, E, K, T = 8, 16, 8, 2, 32
cfg = moe_mod.MoEConfig(n_experts=E, top_k=K, capacity_factor=100.0)
rng = np.random.default_rng(0)
params = {
  'router': jnp.asarray(rng.standard_normal((D, E)), jnp.float32),
  'w_gate': jnp.asarray(rng.standard_normal((E, D, FF))*0.2, jnp.float32),
  'w_up': jnp.asarray(rng.standard_normal((E, D, FF))*0.2, jnp.float32),
  'w_down': jnp.asarray(rng.standard_normal((E, FF, D))*0.2, jnp.float32),
}
x = jnp.asarray(rng.standard_normal((2, T, D)), jnp.float32)
want = moe_mod.moe_ffn(params, x, cfg, mesh=None)
got = jax.jit(lambda p, xx: moe_mod.moe_ffn(p, xx, cfg, mesh=mesh))(params, x)
assert np.allclose(want, got, atol=2e-4), np.abs(np.asarray(want-got)).max()
print('OK')
""")


def test_hierarchical_reduction_equals_flat():
    run_script("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.launch.mesh import make_mesh
from repro.distributed import collectives as C

mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)

def flat(v):
    return C.reduce_scatter_flat(v, ('model', 'pod'))
def hier(v):
    return C.hierarchical_reduce_scatter(v, 'model', 'pod')

f1 = compat.shard_map(flat, mesh=mesh, in_specs=P(), out_specs=P(('model','pod')),
                      axis_names={'pod','data','model'}, check_vma=False)(x)
# hierarchical: scatter over model only, then psum over pod (replicated)
f2 = compat.shard_map(hier, mesh=mesh, in_specs=P(), out_specs=P('model'),
                      axis_names={'pod','data','model'}, check_vma=False)(x)
want = 4 * np.asarray(x)   # psum over model x pod = 4 copies ('data' stays auto)
assert np.allclose(f1, want, atol=1e-4)
assert np.allclose(f2, want, atol=1e-4)
print('OK')
""")


def test_train_step_runs_on_mesh():
    """A real (tiny) sharded train step executes on an 8-device mesh."""
    run_script("""
import jax, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.launch import builders
from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.models import lm as lm_mod, transformer as T
from repro.training import optimizer as opt_mod

mesh = make_mesh((2, 4), ('data', 'model'))
arch = registry.get_arch('qwen3-4b')
cfg = registry.smoke_config('qwen3-4b')
spec = type(arch)(model=cfg, fsdp=True, microbatch=2)
shape = ShapeConfig('tiny_train', 32, 8, 'train')
with mesh:
    step, (state_s, batch_s), jk, meta = builders.build_train_cell(spec, shape, mesh)
    state = lm_mod.init_train_state(cfg, jax.random.PRNGKey(0), opt_mod.OptConfig())
    state = jax.device_put(state, jax.tree.map(lambda s: s.sharding, state_s))
    key = jax.random.PRNGKey(1)
    batch = {
      'tokens': jax.random.randint(key, (8, 32), 0, cfg.vocab),
      'labels': jax.random.randint(key, (8, 32), 0, cfg.vocab),
      'mask': jnp.ones((8, 32), jnp.float32),
    }
    batch = jax.device_put(batch, jax.tree.map(lambda s: s.sharding, batch_s))
    new_state, m = jax.jit(step, **jk)(state, batch)
    assert np.isfinite(float(m['loss']))
print('OK')
""")


def test_pod_compressed_grad_sync():
    run_script("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.launch.mesh import make_mesh
from repro.models.lm import compressed_pod_psum

mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
rng = np.random.default_rng(0)
g = {'w': jnp.asarray(rng.standard_normal((32, 8)) * 1e-3, jnp.float32)}
key = jax.random.PRNGKey(0)
out = compat.shard_map(lambda gg: compressed_pod_psum(gg, key),
                       mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), g),),
                       out_specs=jax.tree.map(lambda _: P(), g),
                       axis_names={'pod','data','model'}, check_vma=False)(g)
# replicated input: compressed mean over pods == input within quant error
err = np.abs(np.asarray(out['w']) - np.asarray(g['w'])).max()
scale = float(jnp.max(jnp.abs(g['w']))) / 127
assert err <= 2 * scale, (err, scale)
print('OK')
""")
