"""Collection-time regression net for JAX API drift.

1. Import every ``repro.*`` module — a renamed/removed JAX symbol at
   module scope (the failure mode that killed the seed suite) now fails
   here, loudly, instead of silently dropping test modules at collection.
2. Compat-routing audit: version-sensitive JAX surfaces must only ever
   be spelled inside ``src/repro/compat.py`` so the next rename is a
   one-file fix.  This used to be a string grep; it now invokes the
   reprolint ``compat-routing`` rule so this test and ``python -m
   repro.analysis`` cannot drift apart.
"""
import importlib
import pathlib
import pkgutil

import pytest

import repro

SRC = pathlib.Path(repro.__path__[0])
REPO = SRC.parent.parent


def _all_repro_modules():
    mods = []
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        mods.append(info.name)
    return sorted(mods)


MODULES = _all_repro_modules()


def test_sweep_finds_the_whole_tree():
    # every package layer must be represented (catches a broken walk)
    tops = {m.split(".")[1] for m in MODULES if m.count(".") >= 1}
    assert {"compat", "kernels", "distributed", "launch", "models",
            "core", "sparse", "training", "checkpoint"} <= tops, MODULES


@pytest.mark.parametrize("name", MODULES)
def test_module_imports(name):
    importlib.import_module(name)


# ---------------------------------------------------------------------------
# Compat-routing audit: AxisType / CompilerParams / direct shard_map /
# direct pallas_call may only appear in repro/compat.py.  The rule's own
# `exclude` tuple carries the allow-list (the shim, the compat unit tests
# that spell both branches, and this file).
# ---------------------------------------------------------------------------


def test_version_sensitive_names_only_in_compat():
    from repro.analysis.engine import AnalysisConfig, run_analysis
    from repro.analysis.rules.compat_routing import CompatRoutingRule

    new, _ = run_analysis(
        AnalysisConfig(root=REPO, rules=[CompatRoutingRule()]))
    assert not new, (
        "version-sensitive JAX surfaces outside repro/compat.py "
        "(route them through the compat shim):\n"
        + "\n".join(f.format() for f in new))
