"""Collection-time regression net for JAX API drift.

1. Import every ``repro.*`` module — a renamed/removed JAX symbol at
   module scope (the failure mode that killed the seed suite) now fails
   here, loudly, instead of silently dropping test modules at collection.
2. Grep-style ban: version-sensitive JAX names must only ever be spelled
   inside ``src/repro/compat.py`` so the next rename is a one-file fix.
"""
import importlib
import pathlib
import pkgutil

import pytest

import repro

SRC = pathlib.Path(repro.__path__[0])
REPO = SRC.parent.parent


def _all_repro_modules():
    mods = []
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        mods.append(info.name)
    return sorted(mods)


MODULES = _all_repro_modules()


def test_sweep_finds_the_whole_tree():
    # every package layer must be represented (catches a broken walk)
    tops = {m.split(".")[1] for m in MODULES if m.count(".") >= 1}
    assert {"compat", "kernels", "distributed", "launch", "models",
            "core", "sparse", "training", "checkpoint"} <= tops, MODULES


@pytest.mark.parametrize("name", MODULES)
def test_module_imports(name):
    importlib.import_module(name)


# ---------------------------------------------------------------------------
# Banned-name audit: AxisType / CompilerParams / TPUCompilerParams may only
# appear in repro/compat.py (plus this checker and the compat unit tests,
# which spell them to simulate both shim branches).
# ---------------------------------------------------------------------------

BANNED = ("AxisType", "CompilerParams", "TPUCompilerParams")
ALLOWED = {SRC / "compat.py", pathlib.Path(__file__),
           pathlib.Path(__file__).parent / "test_compat.py"}


def test_version_sensitive_names_only_in_compat():
    offenders = []
    for root in (REPO / "src", REPO / "tests", REPO / "benchmarks",
                 REPO / "examples"):
        if not root.is_dir():
            continue
        for path in sorted(root.rglob("*.py")):
            if path in ALLOWED:
                continue
            text = path.read_text()
            for lineno, line in enumerate(text.splitlines(), 1):
                if any(name in line for name in BANNED):
                    offenders.append(f"{path.relative_to(REPO)}:{lineno}: "
                                     f"{line.strip()}")
    assert not offenders, (
        "version-sensitive JAX names outside repro/compat.py "
        "(route them through the compat shim):\n" + "\n".join(offenders))
