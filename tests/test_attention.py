"""Attention math: chunked/online-softmax vs full oracle, windows, GQA,
decode, ring cache — property-tested."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.models import layers as L


def _qkv(seed, B, S, H, KV, dh):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, dh)), jnp.float32)
    return q, k, v


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99),
       S=st.sampled_from([32, 64]),
       H=st.sampled_from([4, 8]),
       KV=st.sampled_from([1, 2, 4]),
       cq=st.sampled_from([8, 16, 32]),
       skip=st.booleans())
def test_chunked_equals_full(seed, S, H, KV, cq, skip):
    if H % KV:
        H = KV * (H // KV or 1)
    q, k, v = _qkv(seed, 2, S, H, KV, 8)
    o_full = L.attention_full(q, k, v, causal=True)
    o_chun = L.attention_chunked(q, k, v, causal=True, chunk_q=cq,
                                 chunk_kv=cq, causal_skip=skip)
    np.testing.assert_allclose(o_full, o_chun, atol=3e-5, rtol=3e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 99), window=st.sampled_from([8, 16, 24]))
def test_window_chunked_equals_full(seed, window):
    q, k, v = _qkv(seed, 2, 64, 4, 4, 8)
    o_full = L.attention_full(q, k, v, causal=True, window=window)
    o_chun = L.attention_chunked(q, k, v, causal=True, window=window,
                                 chunk_q=16, chunk_kv=16, causal_skip=True)
    np.testing.assert_allclose(o_full, o_chun, atol=3e-5, rtol=3e-5)


def test_decode_equals_full_last_row():
    q, k, v = _qkv(0, 2, 48, 8, 2, 16)
    o_full = L.attention_full(q, k, v, causal=True)
    Smax = 64
    kc = jnp.pad(k, ((0, 0), (0, Smax - 48), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, Smax - 48), (0, 0), (0, 0)))
    od = L.attention_decode(q[:, -1], kc, vc, jnp.full((2,), 48, jnp.int32))
    np.testing.assert_allclose(o_full[:, -1], od, atol=3e-5)


def test_decode_window_equals_full():
    q, k, v = _qkv(1, 2, 48, 4, 1, 16)
    o_full = L.attention_full(q, k, v, causal=True, window=16)
    kc = jnp.pad(k, ((0, 0), (0, 16), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, 16), (0, 0), (0, 0)))
    od = L.attention_decode(q[:, -1], kc, vc,
                            jnp.full((2,), 48, jnp.int32), window=16)
    np.testing.assert_allclose(o_full[:, -1], od, atol=3e-5)


def test_ragged_lengths_decode():
    """Per-row lengths mask correctly (continuous-batching requirement)."""
    q, k, v = _qkv(2, 2, 32, 4, 2, 8)
    lengths = jnp.asarray([10, 32], jnp.int32)
    od = L.attention_decode(q[:, -1], k, v, lengths)
    # row 0 must equal attention over only the first 10 positions
    od0 = L.attention_decode(q[:1, -1], k[:1, :10], v[:1, :10],
                             jnp.asarray([10], jnp.int32))
    np.testing.assert_allclose(od[:1], od0, atol=3e-5)


def test_rope_rotation_preserves_norm_and_relative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)[None]
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
        atol=1e-4, rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    def dot_at(i, j):
        qi = L.rope(q, jnp.asarray([[i]]), 10_000.0)
        kj = L.rope(k, jnp.asarray([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3


def test_sinusoidal_positions_shape():
    e = L.sinusoidal_positions(jnp.arange(6)[None], 32)
    assert e.shape == (1, 6, 32)
    assert bool(jnp.isfinite(e).all())
