"""Minimal property-sweep helper: the `given/settings/strategies` subset
this repo's tests use, with or without hypothesis installed.

When hypothesis is importable, its real decorators are re-exported
unchanged (full shrinking / example databases / health checks).  When it
is not — the pinned CI environment deliberately omits it — a small
deterministic fallback provides the same surface:

- ``st.integers(lo, hi)``, ``st.sampled_from(seq)``, ``st.booleans()``
- ``@settings(max_examples=N, deadline=...)`` (other kwargs ignored)
- ``@given(**kwargs)`` — runs the test body ``max_examples`` times over a
  deterministic pseudo-random sweep of the strategy space (seeded PRNG, so
  every run and every machine sees the same examples).

The fallback intentionally does *not* shrink or persist failures; a
failing example is reported in the assertion message so it can be pinned
as a regression test by hand.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _DEFAULT_MAX_EXAMPLES = 10
    _SWEEP_SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, pick):
            self._pick = pick

        def example_for(self, rng):
            return self._pick(rng)

    class _StrategiesNS:
        """The ``strategies`` (``st``) namespace subset."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    strategies = _StrategiesNS()

    def settings(*, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        """Record max_examples on the (already-)wrapped test function."""
        def deco(fn):
            fn._propcheck_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        """Deterministic sweep over the named strategies.

        The wrapper takes no parameters on purpose: pytest must not
        mistake the swept arguments for fixtures.
        """
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_propcheck_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = _np.random.default_rng(_SWEEP_SEED)
                for i in range(n):
                    kwargs = {name: s.example_for(rng)
                              for name, s in strats.items()}
                    # Exception only: pytest.skip()/KeyboardInterrupt are
                    # BaseExceptions and must keep their control-flow
                    # meaning rather than becoming test failures.
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"propcheck example {i + 1}/{n} failed for "
                            f"{fn.__name__}({kwargs!r}): {e}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
