"""SGD solver subsystem: blocking invariants, kernel-vs-oracle, and the
ALS-parity convergence acceptance (SGD and hybrid within 2% of the ALS
baseline RMSE on the planted-Netflix recipe)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import als as als_mod
from repro.kernels.sgd_update import sgd_block_update
from repro.sgd import (SgdConfig, SgdState, block_coo, block_ell,
                       diagonal_sets, hybrid_train, sgd_train)
from repro.sgd.train import sgd_init
from repro.sparse import synth


def _random_coo(rng, m, n, nnz):
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    key = rows * n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


# ---------------------------------------------------------------------------
# blocking
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(g=st.integers(1, 6))
def test_diagonal_sets_conflict_free(g):
    """Within a set no two tiles share a user block or an item block, and
    the g sets cover every tile of the g x g grid exactly once."""
    sets = diagonal_sets(g)
    assert len(sets) == g
    seen = set()
    for s in sets:
        assert len(s) == g
        assert len({i for i, _ in s}) == g, s     # user blocks disjoint
        assert len({j for _, j in s}) == g, s     # item blocks disjoint
        seen.update(s)
    assert len(seen) == g * g


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 40), n=st.integers(4, 40), nnz=st.integers(1, 300),
       g=st.sampled_from([1, 2, 3, 4]), seed=st.integers(0, 1000))
def test_block_grid_roundtrip(m, n, nnz, g, seed):
    """block_coo -> to_coo reassembles the original nonzero set exactly."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = _random_coo(rng, m, n, nnz)
    grid = block_coo(rows, cols, vals, m, n, g)
    assert grid.nnz == len(rows)
    r2, c2, v2 = grid.to_coo()
    want = sorted(zip(rows.tolist(), cols.tolist(), vals.tolist()))
    got = sorted(zip(r2.tolist(), c2.tolist(), v2.tolist()))
    assert [(a, b) for a, b, _ in want] == [(a, b) for a, b, _ in got]
    np.testing.assert_allclose([v for _, _, v in want],
                               [v for _, _, v in got], rtol=1e-6)


def test_block_ell_matches_block_coo():
    rng = np.random.default_rng(7)
    rows, cols, vals = _random_coo(rng, 32, 24, 200)
    from repro.sparse.padded import csr_from_coo, pad_csr_fast
    ptr, cc, vv = csr_from_coo(rows, cols, vals, 32)
    ell = pad_csr_fast(ptr, cc, vv, 24)
    ga = block_coo(rows, cols, vals, 32, 24, 3)
    gb = block_ell(ell, 3)
    np.testing.assert_array_equal(ga.idx, gb.idx)
    np.testing.assert_array_equal(ga.val, gb.val)
    np.testing.assert_array_equal(ga.cnt, gb.cnt)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mb,nb,f,K", [(12, 10, 5, 9), (8, 8, 8, 8),
                                       (16, 24, 4, 17)])
def test_sgd_kernel_matches_oracle(mb, nb, f, K):
    """Pallas tile sweep (interpret) == pure-JAX ref, including the
    determinized in-slot item-collision semantics and padding."""
    rng = np.random.default_rng(mb * 100 + K)
    x = jnp.asarray(rng.standard_normal((mb, f)), jnp.float32) * 0.3
    th = jnp.asarray(rng.standard_normal((nb, f)), jnp.float32) * 0.3
    cnt = jnp.asarray(rng.integers(0, K + 1, mb), jnp.int32)
    idx = jnp.asarray(rng.integers(0, nb, (mb, K)), jnp.int32)
    val = jnp.asarray(rng.standard_normal((mb, K)), jnp.float32)
    xr, tr = sgd_block_update(x, th, idx, val, cnt, 0.05, 0.01, mode="ref")
    xk, tk = sgd_block_update(x, th, idx, val, cnt, 0.05, 0.01,
                              mode="kernel_interpret",
                              row_mult=8, col_mult=8, f_mult=8)
    np.testing.assert_allclose(xr, xk, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tr, tk, atol=1e-5, rtol=1e-5)


def test_sgd_update_is_pure_decay_on_empty_rows():
    """Rows with cnt=0 must be untouched (padding rows of the grid)."""
    x = jnp.ones((4, 3))
    th = jnp.ones((5, 3))
    idx = jnp.zeros((4, 6), jnp.int32)
    val = jnp.zeros((4, 6))
    cnt = jnp.zeros((4,), jnp.int32)
    x2, t2 = sgd_block_update(x, th, idx, val, cnt, 0.1, 0.05, mode="ref")
    np.testing.assert_array_equal(x2, x)
    np.testing.assert_array_equal(t2, th)


# ---------------------------------------------------------------------------
# convergence acceptance: SGD / hybrid vs the ALS baseline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    spec = synth.SynthSpec("netflix-mini", m=768, n=160, nnz=40_000,
                           f=8, lam=0.05)
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=2, noise=0.1)
    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    grid = block_ell(r, g=4)
    als_cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=8, mode="ref")
    _, hist = als_mod.als_train(rr, rtt, r.m, rt.m, als_cfg, test=rtest)
    return spec, grid, rr, rtt, rtest, hist[-1]["test_rmse"]


def test_sgd_within_2pct_of_als(problem):
    spec, grid, _, _, rtest, als_rmse = problem
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.15, epochs=40,
                    schedule="cosine", mode="ref", seed=1)
    _, hist = sgd_train(grid, cfg, test=rtest)
    sgd_rmse = hist[-1]["test_rmse"]
    assert sgd_rmse <= als_rmse * 1.02, (sgd_rmse, als_rmse)
    # the schedule actually decayed
    assert hist[-1]["lr"] < hist[0]["lr"] * 0.1


def test_hybrid_within_2pct_of_als(problem):
    spec, grid, rr, rtt, rtest, als_rmse = problem
    warm = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=2, mode="ref")
    refine = SgdConfig(f=spec.f, lam=spec.lam, lr=0.12, epochs=16,
                       schedule="cosine", mode="ref", seed=1)
    _, hist = hybrid_train(rr, rtt, grid, warm, refine, test=rtest)
    assert [h["phase"] for h in hist] == ["als"] * 2 + ["sgd"] * 16
    hyb_rmse = hist[-1]["test_rmse"]
    assert hyb_rmse <= als_rmse * 1.02, (hyb_rmse, als_rmse)
    # warm start pays off: first SGD epoch starts far below a cold start
    assert hist[2]["test_rmse"] < hist[0]["test_rmse"]


def test_sgd_checkpoint_resume_bit_exact(problem, tmp_path):
    """Kill after 3 epochs + resume to 5 == straight 5-epoch run."""
    spec, grid, _, _, rtest, _ = problem
    # decay pinned explicitly: the default (10/epochs) would make the
    # 3-epoch and 5-epoch configs follow different schedules
    kw = dict(f=spec.f, lam=spec.lam, lr=0.1, schedule="inverse_time",
              decay=1.0, mode="ref", seed=4)
    straight, _ = sgd_train(grid, SgdConfig(epochs=5, **kw))
    ck = str(tmp_path / "sgd_ck")
    sgd_train(grid, SgdConfig(epochs=3, **kw), ckpt_dir=ck)
    resumed, hist = sgd_train(grid, SgdConfig(epochs=5, **kw), ckpt_dir=ck)
    assert [h["epoch"] for h in hist] == [4, 5]
    np.testing.assert_allclose(resumed.x, straight.x, atol=1e-6)
    np.testing.assert_allclose(resumed.theta, straight.theta, atol=1e-6)


def test_hybrid_resume_skips_als_warm_start(problem, tmp_path):
    """Resuming a checkpointed hybrid run must not re-run (and re-report)
    the ALS warm start: the checkpoint already embeds it."""
    spec, grid, rr, rtt, rtest, _ = problem
    warm = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1, mode="ref")
    refine = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=2,
                       schedule="inverse_time", decay=1.0, mode="ref")
    ck = str(tmp_path / "hyb_ck")
    final1, hist1 = hybrid_train(rr, rtt, grid, warm, refine, ckpt_dir=ck)
    assert [h["phase"] for h in hist1] == ["als", "sgd", "sgd"]
    final2, hist2 = hybrid_train(rr, rtt, grid, warm, refine, ckpt_dir=ck)
    assert hist2 == []     # fully complete: no ALS re-run, no SGD epochs
    np.testing.assert_allclose(final2.x, final1.x, atol=1e-6)
    np.testing.assert_allclose(final2.theta, final1.theta, atol=1e-6)


@pytest.mark.parametrize("shuffled", [False, True])
def test_diagonal_set_order_within_set_is_irrelevant(problem, shuffled):
    """Conflict-freedom, observed: permuting tiles inside a set cannot
    change the epoch result because the tiles touch disjoint factor rows —
    with the canonical set order and with a PRNG-shuffled one (the rotation
    perm maps run B's set s onto exactly run A's set s, so a shared
    set_order preserves the equivalence)."""
    spec, grid, _, _, _, _ = problem
    from repro.sgd.train import epoch_set_order, grid_triplet, sgd_epoch
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=1, mode="ref")
    order = epoch_set_order(cfg.seed, 5, grid.g) if shuffled else None
    state = sgd_init(grid, cfg)
    a = sgd_epoch(state, grid_triplet(grid), grid, cfg, 0.1,
                  set_order=order)

    idx, val, cnt = (np.array(grid.idx), np.array(grid.val),
                     np.array(grid.cnt))
    perm = [(i + 1) % grid.g for i in range(grid.g)]  # rotate tiles per set
    # permuting user-block i within a set s means visiting (i, (i+s)%g) in a
    # different order; emulate by reordering both factors and tiles
    idx2 = idx[perm][:, :]                       # reorder user-block rows
    val2 = val[perm]
    cnt2 = cnt[perm]
    # rotate item-block columns the same way so (i, (i+s)%g) still pairs
    # the same data; the factor blocks rotate alongside
    idx2 = idx2[:, perm]
    val2 = val2[:, perm]
    cnt2 = cnt2[:, perm]
    xb = np.array(state.x).reshape(grid.g, grid.mb, cfg.f)[perm]
    tb = np.array(state.theta).reshape(grid.g, grid.nb, cfg.f)[perm]
    state2 = SgdState(x=jnp.asarray(xb.reshape(-1, cfg.f)),
                      theta=jnp.asarray(tb.reshape(-1, cfg.f)),
                      epoch=jnp.int32(0))
    gt2 = (jnp.asarray(idx2), jnp.asarray(val2), jnp.asarray(cnt2))
    b = sgd_epoch(state2, gt2, grid, cfg, 0.1, set_order=order)
    bx = np.array(b.x).reshape(grid.g, grid.mb, cfg.f)
    bt = np.array(b.theta).reshape(grid.g, grid.nb, cfg.f)
    ax = np.array(a.x).reshape(grid.g, grid.mb, cfg.f)
    at = np.array(a.theta).reshape(grid.g, grid.nb, cfg.f)
    np.testing.assert_allclose(bx, ax[perm], atol=1e-6)
    np.testing.assert_allclose(bt, at[perm], atol=1e-6)


# ---------------------------------------------------------------------------
# scan epoch: set-order shuffling, dispatch count, shape threading,
# checkpoint materialization
# ---------------------------------------------------------------------------

def test_epoch_set_order_is_reproducible_permutation():
    """Keyed on (seed, epoch): a true permutation, bit-stable across calls,
    and actually different between epochs (the CuMF_SGD randomization)."""
    from repro.sgd.train import epoch_set_order
    g = 6
    orders = [np.asarray(epoch_set_order(0, ep, g)) for ep in range(8)]
    for o in orders:
        assert sorted(o.tolist()) == list(range(g))
    np.testing.assert_array_equal(
        orders[3], np.asarray(epoch_set_order(0, 3, g)))
    assert any(not np.array_equal(orders[0], o) for o in orders[1:]), \
        "set order never changed across epochs"
    # a different seed reshuffles epoch 0
    assert any(not np.array_equal(np.asarray(epoch_set_order(s, 0, g)),
                                  orders[0]) for s in range(1, 5))


def _unrolled_epoch(state, gt, grid, cfg, lr, set_order):
    """The pre-scan reference epoch: g^2 per-tile dispatches."""
    idx, val, cnt = gt
    g, mb, nb, f = grid.g, grid.mb, grid.nb, cfg.f
    xb = state.x.reshape(g, mb, f)
    tb = state.theta.reshape(g, nb, f)
    lr_t = jnp.float32(lr)
    for s in np.asarray(set_order).tolist():
        for i in range(g):
            j = (i + s) % g
            xi, tj = sgd_block_update(
                xb[i], tb[j], idx[i, j], val[i, j], cnt[i, j], lr_t,
                cfg.lam, mode=cfg.mode, row_mult=cfg.row_mult,
                col_mult=cfg.col_mult, f_mult=cfg.f_mult)
            xb = xb.at[i].set(xi)
            tb = tb.at[j].set(tj)
    return SgdState(x=xb.reshape(g * mb, f), theta=tb.reshape(g * nb, f),
                    epoch=state.epoch + 1)


@pytest.mark.parametrize("shuffled", [False, True])
def test_scan_epoch_matches_unrolled(problem, shuffled):
    """Acceptance: the lax.scan epoch (stacked per-set tile sweep) produces
    the same factors as the unrolled per-tile loop to float32 tolerance."""
    spec, grid, _, _, _, _ = problem
    from repro.sgd.train import epoch_set_order, grid_triplet, sgd_epoch
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=1, mode="ref",
                    seed=9)
    order = (epoch_set_order(cfg.seed, 1, grid.g) if shuffled
             else jnp.arange(grid.g))
    state = sgd_init(grid, cfg)
    gt = grid_triplet(grid)
    a = sgd_epoch(state, gt, grid, cfg, 0.1, set_order=order)
    b = _unrolled_epoch(state, gt, grid, cfg, 0.1, order)
    np.testing.assert_allclose(np.asarray(a.x), np.asarray(b.x),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a.theta), np.asarray(b.theta),
                               atol=1e-5, rtol=1e-5)


def test_scan_epoch_issues_o_g_dispatches(monkeypatch):
    """Acceptance: one epoch makes O(g), not O(g^2), host-level
    sgd_block_update calls (the scan traces the per-set stacked call once;
    a fresh grid shape forces the trace so the count is observable)."""
    import repro.sgd.train as train_mod
    from repro.sgd.train import grid_triplet, sgd_epoch
    rng = np.random.default_rng(11)
    g = 5                       # unique shape: avoid jit-cache hits
    rows, cols, vals = _random_coo(rng, 7 * g, 6 * g, 420)
    grid = block_coo(rows, cols, vals, 7 * g, 6 * g, g)
    cfg = SgdConfig(f=6, lam=0.05, lr=0.1, epochs=1, mode="ref")
    calls = []
    real = train_mod.sgd_block_update
    monkeypatch.setattr(train_mod, "sgd_block_update",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    state = sgd_init(grid, cfg)
    sgd_epoch(state, grid_triplet(grid), grid, cfg, 0.1)
    assert 1 <= len(calls) <= g, f"{len(calls)} dispatches for g={g}"


def test_sgd_epoch_rejects_overpadded_factors(problem):
    """nb comes from the grid, not from theta's shape: factors padded past
    g*nb (e.g. a stale pad_factor target) must fail loudly instead of
    silently mis-slicing every theta block."""
    spec, grid, _, _, _, _ = problem
    from repro.sgd.train import grid_triplet, pad_factor, sgd_epoch
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=1, mode="ref")
    state = sgd_init(grid, cfg)
    bad = SgdState(x=state.x,
                   theta=pad_factor(state.theta, grid.g * grid.nb + grid.g),
                   epoch=state.epoch)
    with pytest.raises(AssertionError):
        sgd_epoch(bad, grid_triplet(grid), grid, cfg, 0.1)


def test_sgd_train_checkpoints_host_copies(problem, tmp_path, monkeypatch):
    """Regression: the tree handed to the async CheckpointManager must be
    host-materialized copies, never views aliasing the live factors — a
    later in-place/donated update would race the background writer."""
    import repro.checkpoint as ckpt_mod
    spec, grid, _, _, _, _ = problem
    captured = []

    class SpyManager(ckpt_mod.CheckpointManager):
        def save(self, step, tree):
            captured.append((step, tree))
            super().save(step, tree)

    monkeypatch.setattr(ckpt_mod, "CheckpointManager", SpyManager)
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=2, mode="ref",
                    schedule="inverse_time", decay=1.0, seed=4)
    state, _ = sgd_train(grid, cfg, ckpt_dir=str(tmp_path / "ck"))
    assert len(captured) == 2
    live = {"x": np.asarray(state.x), "theta": np.asarray(state.theta)}
    for _, tree in captured:
        for k in ("x", "theta"):
            leaf = tree[k]
            assert isinstance(leaf, np.ndarray), type(leaf)
            assert not np.shares_memory(leaf, live[k]), \
                f"checkpoint tree aliases the live {k} buffer"
    # the final epoch's snapshot equals (but does not alias) the final state
    np.testing.assert_array_equal(captured[-1][1]["x"], live["x"])


# ---------------------------------------------------------------------------
# Per-tile K + degree sort (ISSUE 9: degree-binned layout at tile granularity)
# ---------------------------------------------------------------------------

def _skewed_coo(rng, m, n, nnz, alpha=1.2):
    ranks = np.arange(1, m + 1, dtype=np.float64)
    p = ranks ** -alpha
    rows = rng.choice(m, size=nnz, p=p / p.sum())
    cols = rng.integers(0, n, nnz)
    key = rows * n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


def test_per_tile_k_epoch_is_bit_exact():
    """Tile K slicing drops only masked all-padding slot columns, so the
    grouped same-K dispatch must be numerically identical to the uniform
    grid-wide-K dispatch — not close, identical."""
    rng = np.random.default_rng(5)
    m, n = 96, 48
    rows, cols, vals = _skewed_coo(rng, m, n, 1200)
    uni = block_coo(rows, cols, vals, m, n, g=4)
    ptk = block_coo(rows, cols, vals, m, n, g=4, per_tile_k=True)
    assert ptk.tile_K is not None and uni.tile_K is None
    assert int(ptk.tile_K.max()) <= uni.K
    assert ptk.padded_slots <= uni.padded_slots
    cfg = SgdConfig(f=8, lam=0.05, lr=0.1, epochs=3, mode="ref", seed=9,
                    schedule="inverse_time", decay=1.0)
    s_uni, _ = sgd_train(uni, cfg)
    s_ptk, _ = sgd_train(ptk, cfg)
    np.testing.assert_array_equal(np.asarray(s_uni.x), np.asarray(s_ptk.x))
    np.testing.assert_array_equal(np.asarray(s_uni.theta),
                                  np.asarray(s_ptk.theta))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), g=st.sampled_from([2, 3, 4]))
def test_degree_sort_grid_roundtrip(seed, g):
    """degree_sort permutes users into blocks but to_coo must still
    reassemble the original nonzero set, and the recorded permutation
    round-trips (``user_inv[user_perm] == arange``)."""
    rng = np.random.default_rng(seed)
    m, n = 40, 24
    rows, cols, vals = _skewed_coo(rng, m, n, 500)
    grid = block_coo(rows, cols, vals, m, n, g,
                     per_tile_k=True, degree_sort=True)
    assert grid.user_perm is not None
    np.testing.assert_array_equal(grid.user_inv[grid.user_perm],
                                  np.arange(m))
    r2, c2, v2 = grid.to_coo()
    want = sorted(zip(rows.tolist(), cols.tolist(), vals.tolist()))
    got = sorted(zip(r2.tolist(), c2.tolist(), v2.tolist()))
    assert want == got
    # degrees descend across the sorted user order
    deg = np.bincount(rows, minlength=m)
    sorted_deg = deg[grid.user_perm]
    assert np.all(np.diff(sorted_deg) <= 0)


def test_degree_sort_cuts_fill_on_skewed_data():
    """The bench claim in miniature: degree-sorted per-tile-K padding is
    materially cheaper than the uniform grid on power-law users, and the
    factors it trains land at the same quality in original coordinates."""
    from repro.sgd.train import factors_np
    rng = np.random.default_rng(11)
    m, n = 256, 64
    rows, cols, vals = _skewed_coo(rng, m, n, 4000, alpha=1.2)
    uni = block_coo(rows, cols, vals, m, n, g=4)
    srt = block_coo(rows, cols, vals, m, n, g=4,
                    per_tile_k=True, degree_sort=True)
    assert uni.fill / srt.fill >= 1.5, (uni.fill, srt.fill)
    cfg = SgdConfig(f=8, lam=0.05, lr=0.1, epochs=10, mode="ref", seed=9,
                    schedule="inverse_time", decay=1.0)
    s_uni, _ = sgd_train(uni, cfg)
    s_srt, _ = sgd_train(srt, cfg)
    xu, tu = factors_np(s_uni, uni)
    xs, ts = factors_np(s_srt, srt)

    def rmse(x, th):
        pred = (x[rows] * th[cols]).sum(axis=1)
        return float(np.sqrt(np.mean((pred - vals) ** 2)))

    # visit order differs (both exact Hogwild-free sweeps), so factors are
    # not bit-equal — but quality in original coordinates must match
    assert abs(rmse(xu, tu) - rmse(xs, ts)) < 5e-2
    assert xs.shape == (m, 8) and ts.shape == (n, 8)
