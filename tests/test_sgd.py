"""SGD solver subsystem: blocking invariants, kernel-vs-oracle, and the
ALS-parity convergence acceptance (SGD and hybrid within 2% of the ALS
baseline RMSE on the planted-Netflix recipe)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import als as als_mod
from repro.kernels.sgd_update import sgd_block_update
from repro.sgd import (SgdConfig, SgdState, block_coo, block_ell,
                       diagonal_sets, hybrid_train, sgd_train)
from repro.sgd.train import sgd_init
from repro.sparse import synth


def _random_coo(rng, m, n, nnz):
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    key = rows * n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


# ---------------------------------------------------------------------------
# blocking
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(g=st.integers(1, 6))
def test_diagonal_sets_conflict_free(g):
    """Within a set no two tiles share a user block or an item block, and
    the g sets cover every tile of the g x g grid exactly once."""
    sets = diagonal_sets(g)
    assert len(sets) == g
    seen = set()
    for s in sets:
        assert len(s) == g
        assert len({i for i, _ in s}) == g, s     # user blocks disjoint
        assert len({j for _, j in s}) == g, s     # item blocks disjoint
        seen.update(s)
    assert len(seen) == g * g


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 40), n=st.integers(4, 40), nnz=st.integers(1, 300),
       g=st.sampled_from([1, 2, 3, 4]), seed=st.integers(0, 1000))
def test_block_grid_roundtrip(m, n, nnz, g, seed):
    """block_coo -> to_coo reassembles the original nonzero set exactly."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = _random_coo(rng, m, n, nnz)
    grid = block_coo(rows, cols, vals, m, n, g)
    assert grid.nnz == len(rows)
    r2, c2, v2 = grid.to_coo()
    want = sorted(zip(rows.tolist(), cols.tolist(), vals.tolist()))
    got = sorted(zip(r2.tolist(), c2.tolist(), v2.tolist()))
    assert [(a, b) for a, b, _ in want] == [(a, b) for a, b, _ in got]
    np.testing.assert_allclose([v for _, _, v in want],
                               [v for _, _, v in got], rtol=1e-6)


def test_block_ell_matches_block_coo():
    rng = np.random.default_rng(7)
    rows, cols, vals = _random_coo(rng, 32, 24, 200)
    from repro.sparse.padded import csr_from_coo, pad_csr_fast
    ptr, cc, vv = csr_from_coo(rows, cols, vals, 32)
    ell = pad_csr_fast(ptr, cc, vv, 24)
    ga = block_coo(rows, cols, vals, 32, 24, 3)
    gb = block_ell(ell, 3)
    np.testing.assert_array_equal(ga.idx, gb.idx)
    np.testing.assert_array_equal(ga.val, gb.val)
    np.testing.assert_array_equal(ga.cnt, gb.cnt)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mb,nb,f,K", [(12, 10, 5, 9), (8, 8, 8, 8),
                                       (16, 24, 4, 17)])
def test_sgd_kernel_matches_oracle(mb, nb, f, K):
    """Pallas tile sweep (interpret) == pure-JAX ref, including the
    determinized in-slot item-collision semantics and padding."""
    rng = np.random.default_rng(mb * 100 + K)
    x = jnp.asarray(rng.standard_normal((mb, f)), jnp.float32) * 0.3
    th = jnp.asarray(rng.standard_normal((nb, f)), jnp.float32) * 0.3
    cnt = jnp.asarray(rng.integers(0, K + 1, mb), jnp.int32)
    idx = jnp.asarray(rng.integers(0, nb, (mb, K)), jnp.int32)
    val = jnp.asarray(rng.standard_normal((mb, K)), jnp.float32)
    xr, tr = sgd_block_update(x, th, idx, val, cnt, 0.05, 0.01, mode="ref")
    xk, tk = sgd_block_update(x, th, idx, val, cnt, 0.05, 0.01,
                              mode="kernel_interpret",
                              row_mult=8, col_mult=8, f_mult=8)
    np.testing.assert_allclose(xr, xk, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tr, tk, atol=1e-5, rtol=1e-5)


def test_sgd_update_is_pure_decay_on_empty_rows():
    """Rows with cnt=0 must be untouched (padding rows of the grid)."""
    x = jnp.ones((4, 3))
    th = jnp.ones((5, 3))
    idx = jnp.zeros((4, 6), jnp.int32)
    val = jnp.zeros((4, 6))
    cnt = jnp.zeros((4,), jnp.int32)
    x2, t2 = sgd_block_update(x, th, idx, val, cnt, 0.1, 0.05, mode="ref")
    np.testing.assert_array_equal(x2, x)
    np.testing.assert_array_equal(t2, th)


# ---------------------------------------------------------------------------
# convergence acceptance: SGD / hybrid vs the ALS baseline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    spec = synth.SynthSpec("netflix-mini", m=768, n=160, nnz=40_000,
                           f=8, lam=0.05)
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=2, noise=0.1)
    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    grid = block_ell(r, g=4)
    als_cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=8, mode="ref")
    _, hist = als_mod.als_train(rr, rtt, r.m, rt.m, als_cfg, test=rtest)
    return spec, grid, rr, rtt, rtest, hist[-1]["test_rmse"]


def test_sgd_within_2pct_of_als(problem):
    spec, grid, _, _, rtest, als_rmse = problem
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.15, epochs=40,
                    schedule="cosine", mode="ref", seed=1)
    _, hist = sgd_train(grid, cfg, test=rtest)
    sgd_rmse = hist[-1]["test_rmse"]
    assert sgd_rmse <= als_rmse * 1.02, (sgd_rmse, als_rmse)
    # the schedule actually decayed
    assert hist[-1]["lr"] < hist[0]["lr"] * 0.1


def test_hybrid_within_2pct_of_als(problem):
    spec, grid, rr, rtt, rtest, als_rmse = problem
    warm = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=2, mode="ref")
    refine = SgdConfig(f=spec.f, lam=spec.lam, lr=0.12, epochs=16,
                       schedule="cosine", mode="ref", seed=1)
    _, hist = hybrid_train(rr, rtt, grid, warm, refine, test=rtest)
    assert [h["phase"] for h in hist] == ["als"] * 2 + ["sgd"] * 16
    hyb_rmse = hist[-1]["test_rmse"]
    assert hyb_rmse <= als_rmse * 1.02, (hyb_rmse, als_rmse)
    # warm start pays off: first SGD epoch starts far below a cold start
    assert hist[2]["test_rmse"] < hist[0]["test_rmse"]


def test_sgd_checkpoint_resume_bit_exact(problem, tmp_path):
    """Kill after 3 epochs + resume to 5 == straight 5-epoch run."""
    spec, grid, _, _, rtest, _ = problem
    # decay pinned explicitly: the default (10/epochs) would make the
    # 3-epoch and 5-epoch configs follow different schedules
    kw = dict(f=spec.f, lam=spec.lam, lr=0.1, schedule="inverse_time",
              decay=1.0, mode="ref", seed=4)
    straight, _ = sgd_train(grid, SgdConfig(epochs=5, **kw))
    ck = str(tmp_path / "sgd_ck")
    sgd_train(grid, SgdConfig(epochs=3, **kw), ckpt_dir=ck)
    resumed, hist = sgd_train(grid, SgdConfig(epochs=5, **kw), ckpt_dir=ck)
    assert [h["epoch"] for h in hist] == [4, 5]
    np.testing.assert_allclose(resumed.x, straight.x, atol=1e-6)
    np.testing.assert_allclose(resumed.theta, straight.theta, atol=1e-6)


def test_hybrid_resume_skips_als_warm_start(problem, tmp_path):
    """Resuming a checkpointed hybrid run must not re-run (and re-report)
    the ALS warm start: the checkpoint already embeds it."""
    spec, grid, rr, rtt, rtest, _ = problem
    warm = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1, mode="ref")
    refine = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=2,
                       schedule="inverse_time", decay=1.0, mode="ref")
    ck = str(tmp_path / "hyb_ck")
    final1, hist1 = hybrid_train(rr, rtt, grid, warm, refine, ckpt_dir=ck)
    assert [h["phase"] for h in hist1] == ["als", "sgd", "sgd"]
    final2, hist2 = hybrid_train(rr, rtt, grid, warm, refine, ckpt_dir=ck)
    assert hist2 == []     # fully complete: no ALS re-run, no SGD epochs
    np.testing.assert_allclose(final2.x, final1.x, atol=1e-6)
    np.testing.assert_allclose(final2.theta, final1.theta, atol=1e-6)


def test_diagonal_set_order_within_set_is_irrelevant(problem):
    """Conflict-freedom, observed: permuting tiles inside a set cannot
    change the epoch result because the tiles touch disjoint factor rows."""
    spec, grid, _, _, _, _ = problem
    from repro.sgd.train import grid_triplet, sgd_epoch
    cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=1, mode="ref")
    state = sgd_init(grid, cfg)
    a = sgd_epoch(state, grid_triplet(grid), grid.g, cfg, 0.1)

    idx, val, cnt = (np.array(grid.idx), np.array(grid.val),
                     np.array(grid.cnt))
    perm = [(i + 1) % grid.g for i in range(grid.g)]  # rotate tiles per set
    # permuting user-block i within a set s means visiting (i, (i+s)%g) in a
    # different order; emulate by reordering both factors and tiles
    idx2 = idx[perm][:, :]                       # reorder user-block rows
    val2 = val[perm]
    cnt2 = cnt[perm]
    # rotate item-block columns the same way so (i, (i+s)%g) still pairs
    # the same data; the factor blocks rotate alongside
    idx2 = idx2[:, perm]
    val2 = val2[:, perm]
    cnt2 = cnt2[:, perm]
    xb = np.array(state.x).reshape(grid.g, grid.mb, cfg.f)[perm]
    tb = np.array(state.theta).reshape(grid.g, grid.nb, cfg.f)[perm]
    state2 = SgdState(x=jnp.asarray(xb.reshape(-1, cfg.f)),
                      theta=jnp.asarray(tb.reshape(-1, cfg.f)),
                      epoch=jnp.int32(0))
    gt2 = (jnp.asarray(idx2), jnp.asarray(val2), jnp.asarray(cnt2))
    b = sgd_epoch(state2, gt2, grid.g, cfg, 0.1)
    bx = np.array(b.x).reshape(grid.g, grid.mb, cfg.f)
    bt = np.array(b.theta).reshape(grid.g, grid.nb, cfg.f)
    ax = np.array(a.x).reshape(grid.g, grid.mb, cfg.f)
    at = np.array(a.theta).reshape(grid.g, grid.nb, cfg.f)
    np.testing.assert_allclose(bx, ax[perm], atol=1e-6)
    np.testing.assert_allclose(bt, at[perm], atol=1e-6)
