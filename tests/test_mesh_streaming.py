"""ISSUE-5 acceptance suite: out-of-core waves on a real (data, model) mesh.

Fast tests cover the topology-aware reduction (bit-for-bit f64 vs the naive
all-reduce oracle, schedule determinism, traffic accounting) and the
p-sharded store invariants — pure host-side, no devices needed.

The end-to-end mesh runs are marked ``mesh`` and execute in a subprocess
with ``--xla_force_host_platform_device_count=8`` (the same harness as
test_distributed.run_script), so the main pytest process keeps its real
single-device view; CI runs them in the dedicated mesh-streaming lane.
"""
import numpy as np
import pytest

from repro.distributed.reduce import (DeviceTopology, allreduce_oracle,
                                      linear_topology, reduce_traffic,
                                      topology_reduce)
from repro.outofcore import FactorStore, RatingStore
from repro.sparse import synth

SPEC = synth.SynthSpec("oc", 96, 40, 1500, 8, 0.05)


def _bitexact(a: np.ndarray, b: np.ndarray) -> bool:
    assert a.dtype == b.dtype == np.float64, (a.dtype, b.dtype)
    return bool((a.view(np.uint64) == b.view(np.uint64)).all())


# ---------------------------------------------------------------------------
# Topology-aware reduction (fast, host-side)
# ---------------------------------------------------------------------------

def _parts(n_dev=8, shape=(6, 4, 4), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(shape).astype(np.float32)
            for _ in range(n_dev)]


@pytest.mark.parametrize("groups", [
    ((0, 1, 2, 3, 4, 5, 6, 7),),                  # flat ring
    ((0, 1), (2, 3), (4, 5), (6, 7)),             # paper: 2 per PCIe switch
    ((0, 1, 2, 3), (4, 5, 6, 7)),                 # 2 sockets
    ((0, 1, 2), (3, 4, 5), (6, 7)),               # ragged domains
])
def test_topology_reduce_matches_allreduce_oracle_bitexact(groups):
    """Acceptance: for f32 partials the staged f64 reduction is exact, so
    ANY declared grouping must match the flat oracle bit for bit."""
    parts = _parts()
    got = topology_reduce(parts, DeviceTopology(groups))
    assert _bitexact(got, allreduce_oracle(parts))


def test_topology_reduce_deterministic_order():
    """The schedule depends only on the declared topology: scrambled group
    spellings normalize to the same ascending-device-id fold, and repeated
    runs are bit-identical."""
    parts = _parts(4)
    a = topology_reduce(parts, DeviceTopology(((1, 0), (3, 2))))
    b = topology_reduce(parts, DeviceTopology(((0, 1), (2, 3))))
    c = topology_reduce(parts, DeviceTopology(((0, 1), (2, 3))))
    assert _bitexact(a, b) and _bitexact(b, c)
    # default topology (single flat group) is the oracle itself
    assert _bitexact(topology_reduce(parts), allreduce_oracle(parts))


def test_topology_validation_and_helpers():
    with pytest.raises(AssertionError):
        DeviceTopology(((0, 1), (1, 2)))           # overlapping
    with pytest.raises(AssertionError):
        DeviceTopology(((0, 2),))                  # gap
    topo = linear_topology(6, 4)
    assert topo.groups == ((0, 1, 2, 3), (4, 5)) and topo.n_devices == 6
    assert "0,1,2,3" in topo.describe()


def test_reduce_traffic_two_phase_beats_flat_on_slow_link():
    """The paper's Fig. 5b claim in the analytic model: grouping keeps
    slow-link traffic at one already-reduced partial per extra domain,
    while the flat scheme drags (D-1)/D of everything across every link."""
    nbytes = 1 << 20
    grouped = reduce_traffic(nbytes, linear_topology(8, 2))
    flat = reduce_traffic(nbytes, linear_topology(8, 8))
    assert flat["slow_link_bytes"] == 0 and flat["slow_link_crossings"] == 0
    # a single flat domain IS the flat scheme: byte counts must coincide
    assert flat["fast_link_bytes"] == flat["flat_all_links_bytes"]
    assert grouped["slow_link_crossings"] == 3
    assert grouped["slow_link_bytes"] == 3 * nbytes
    assert grouped["slow_link_bytes"] < grouped["flat_all_links_bytes"]
    # staging rearranges the D-1 partial moves, it never adds any
    for t in (grouped, flat):
        assert t["fast_link_bytes"] + t["slow_link_bytes"] == \
            t["flat_all_links_bytes"] == 7 * nbytes


# ---------------------------------------------------------------------------
# p-sharded store invariants (fast, host-side)
# ---------------------------------------------------------------------------

def test_rating_store_model_partition_roundtrips():
    """p > 1 stores carry R column-partitioned into the p theta shards:
    same nonzeros, shard-local item coordinates, mesh-layout slices."""
    r, _, _, _ = synth.make_synthetic_ratings(SPEC, seed=0)
    store = RatingStore(r, q=4, p=2)
    parts = store.r_model_parts
    assert parts.idx.shape[0] == 2
    assert int(parts.cnt.sum()) == r.nnz
    npp = store.n // 2
    idx, val, cnt = store.x_slice_mesh_triplet(0, store.m_pad // 4)
    rows, pk = idx.shape
    K_loc = pk // 2
    assert cnt.shape == (rows, 2)
    # per-shard columns only reference shard-local coordinates
    for k in range(2):
        blk = idx[:, k * K_loc:(k + 1) * K_loc]
        live = np.arange(K_loc)[None, :] < cnt[:, k][:, None]
        if live.any():
            assert blk[live].max() < npp
    # slice holds the same nonzero values as the same rows of plain R
    _, rval, rcnt = store.x_slice_triplet(0, store.m_pad // 4)
    assert int(cnt.sum()) == int(rcnt.sum())
    np.testing.assert_allclose(np.sort(val[val != 0]),
                               np.sort(rval[rval != 0]), rtol=1e-6)
    assert store.fill_r_model >= 1.0
    assert store.worst_fill >= store.fill_r_model
    # p = 1 store refuses to cut mesh slices
    with pytest.raises(AssertionError):
        RatingStore(r, q=4).x_slice_mesh_triplet(0, 8)


def test_factor_store_shard_io():
    fs = FactorStore.from_arrays(np.zeros((8, 3), np.float32),
                                 np.arange(12, dtype=np.float32).reshape(6, 2))
    np.testing.assert_array_equal(fs.read_shard("theta", 1, 3),
                                  fs.theta[2:4])
    fs.write_shard("theta", 2, 3, np.full((2, 2), 9.0))
    assert (fs.theta[4:6] == 9.0).all() and (fs.theta[:4] != 9.0).all()
    with pytest.raises(AssertionError):
        fs.shard_bounds("theta", 0, 4)          # 6 rows not divisible by 4


# ---------------------------------------------------------------------------
# End-to-end mesh runs (subprocess-pinned to 8 host devices)
# ---------------------------------------------------------------------------

MESH_COMMON = """
import numpy as np, jax
from repro.core import als as als_mod
from repro.core.partition import plan_for, streaming_acc_bytes
from repro.outofcore import (RatingStore, SimulatedFailure, TileStore,
                             build_schedule, build_sgd_schedule,
                             required_capacity_bytes, run_streaming_als,
                             run_streaming_sgd)
from repro.sparse import synth
from repro.launch.mesh import make_mesh

assert len(jax.devices()) == 8, jax.devices()
SPEC = synth.SynthSpec("oc", 96, 40, 1500, 8, 0.05)
r, rt, rte, _ = synth.make_synthetic_ratings(SPEC, seed=0)
rtest = als_mod.ell_triplet(rte)

def als_plan(store, q, n_data, p):
    return plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=p, q=q, n_data=n_data,
                    fill=store.worst_fill, eps=0, buffers=4,
                    acc_bytes=streaming_acc_bytes(SPEC.n, SPEC.f),
                    hbm_bytes=1 << 22)
"""


@pytest.mark.mesh
def test_streaming_als_on_mesh_matches_incore():
    """Acceptance: forced waves >= 2 streaming ALS on a (data=2, model=2)
    mesh with p = 2 theta shards matches the in-core single-device factors
    to 1e-4, under the p-sharded plan capacity."""
    from test_distributed import run_script
    run_script(MESH_COMMON + """
cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=3, mode="ref")
rr, rtt = als_mod.ell_triplet(r), als_mod.ell_triplet(rt)
state, hist = als_mod.als_train(rr, rtt, r.m, rt.m, cfg, test=rtest)

store = RatingStore(r, q=4, p=2)
plan = als_plan(store, q=4, n_data=2, p=2)
assert plan.waves >= 2 and plan.p == 2
sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
mesh = make_mesh((2, 2), ("data", "model"))
fac, shist, tel = run_streaming_als(store, sched, cfg, mesh=mesh,
                                    train_eval=rr, test_eval=rtest)
assert len(shist) == len(hist)
for a, b in zip(shist, hist):
    assert abs(a["train_rmse"] - b["train_rmse"]) < 1e-4, (a, b)
    assert abs(a["test_rmse"] - b["test_rmse"]) < 1e-4, (a, b)
assert np.abs(fac.x[:r.m] - np.asarray(state.x)).max() < 1e-4
assert np.abs(fac.theta - np.asarray(state.theta)).max() < 1e-4
# per-device simulated peak under the plan capacity AND the honest model
assert tel.peak_bytes <= tel.capacity_bytes, (tel.peak_bytes, tel.capacity_bytes)
assert tel.peak_bytes <= required_capacity_bytes(store, sched, SPEC.f)
assert tel.waves_run == 2 * len(sched.waves) * cfg.iters
assert tel.topology and tel.reduce_fast_bytes > 0
print("mesh ALS parity OK")
""")


@pytest.mark.mesh
def test_streaming_als_mesh_ragged_last_wave():
    """q = 3 with n_data = 2, p = 2 (q not divisible by n_data * p): the
    last wave carries one batch, is padded with empty rows/batches on the
    mesh, and still matches the in-core trajectory."""
    from test_distributed import run_script
    run_script(MESH_COMMON + """
cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
rr, rtt = als_mod.ell_triplet(r), als_mod.ell_triplet(rt)
state, hist = als_mod.als_train(rr, rtt, r.m, rt.m, cfg)

store = RatingStore(r, q=3, p=2)
plan = als_plan(store, q=3, n_data=2, p=2)
sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
assert len(sched.waves) == 2 and len(sched.waves[-1].batches) == 1
mesh = make_mesh((2, 2), ("data", "model"))
fac, shist, tel = run_streaming_als(store, sched, cfg, mesh=mesh,
                                    train_eval=rr)
assert abs(shist[-1]["train_rmse"] - hist[-1]["train_rmse"]) < 1e-4
assert np.abs(fac.x[:r.m] - np.asarray(state.x)).max() < 1e-4
assert np.abs(fac.theta - np.asarray(state.theta)).max() < 1e-4
assert tel.peak_bytes <= tel.capacity_bytes
print("mesh ALS ragged OK")
""")


@pytest.mark.mesh
def test_streaming_als_mesh_kill_resume_bit_exact():
    """Killed mid-solve-X (wave 1) and mid-accumulate-Theta (wave 3), the
    mesh run resumes to bit-identical factors: the checkpoint carries the
    per-data-shard f64 partials, so the topology reduce replays exactly."""
    from test_distributed import run_script
    run_script(MESH_COMMON + """
import tempfile
cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
store = RatingStore(r, q=4, p=2)
sched = build_schedule(als_plan(store, 4, 2, 2), SPEC.m, SPEC.n, n_data=2)
mesh = make_mesh((2, 2), ("data", "model"))
ref, _, _ = run_streaming_als(store, sched, cfg, mesh=mesh)
for kill in (1, 3):
    with tempfile.TemporaryDirectory() as d:
        try:
            run_streaming_als(store, sched, cfg, mesh=mesh, ckpt_dir=d,
                              fail_after_waves=kill)
            raise SystemExit("simulated kill did not fire")
        except SimulatedFailure:
            pass
        fac, _, tel = run_streaming_als(store, sched, cfg, mesh=mesh,
                                        ckpt_dir=d)
        assert tel.resumed_from_step == kill
        assert np.array_equal(fac.x, ref.x), kill
        assert np.array_equal(fac.theta, ref.theta), kill
print("mesh ALS resume OK")
""")


@pytest.mark.mesh
def test_streaming_als_mesh_binned_matches_incore():
    """PR-10 acceptance: a degree-binned store (n_bins = 4) streams on a
    p = 2 mesh — theta half through the batch-uniform stacked bins — and
    still matches the in-core trajectory, with a validating zero-error
    ledger priced from the store's real bin fills."""
    from test_distributed import run_script
    run_script(MESH_COMMON + """
from repro.obs.ledger import validate_ledger
cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=3, mode="ref")
rr, rtt = als_mod.ell_triplet(r), als_mod.ell_triplet(rt)
state, hist = als_mod.als_train(rr, rtt, r.m, rt.m, cfg, test=rtest)

store = RatingStore(r, q=4, p=2, n_bins=4)
assert store.rt_stacked is not None and len(store.rt_stacked) >= 2
plan = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=2, q=4, n_data=2,
                bin_fills=store.bin_fill_pairs(), eps=0, buffers=4,
                acc_bytes=streaming_acc_bytes(SPEC.n, SPEC.f),
                hbm_bytes=1 << 22)
assert plan.waves >= 2 and plan.p == 2
sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
mesh = make_mesh((2, 2), ("data", "model"))
fac, shist, tel = run_streaming_als(store, sched, cfg, mesh=mesh,
                                    train_eval=rr, test_eval=rtest)
for a, b in zip(shist, hist):
    assert abs(a["train_rmse"] - b["train_rmse"]) < 1e-4, (a, b)
assert np.abs(fac.x[:r.m] - np.asarray(state.x)).max() < 1e-4
assert np.abs(fac.theta - np.asarray(state.theta)).max() < 1e-4
assert tel.peak_bytes <= tel.capacity_bytes
assert tel.peak_bytes <= required_capacity_bytes(store, sched, SPEC.f)
led = tel.ledger
assert led["run"]["n_bins"] == 4 and led["run"]["p"] == 2
assert led["run"]["autotune"] is None          # layout was pinned by hand
summary = validate_ledger(led)
assert summary["errors"] == 0 and summary["ok"], summary
names = {rec["name"] for rec in led["records"]}
assert {"bytes_streamed", "padded_slots", "nnz_streamed"} <= names
for rec in led["records"]:
    if rec["check"] == "exact":
        assert rec["ok"] and rec["drift"] == 0.0, rec
print("mesh binned ALS parity OK")
""")


@pytest.mark.mesh
def test_streaming_als_mesh_binned_kill_resume_bit_exact():
    """Binned mesh runs (p = 2, n_bins = 4) killed mid-half resume to
    bit-identical factors — the stacked-bin theta half checkpoints its
    per-data-shard f64 partials like the uniform path."""
    from test_distributed import run_script
    run_script(MESH_COMMON + """
import tempfile
cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
store = RatingStore(r, q=4, p=2, n_bins=4)
plan = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=2, q=4, n_data=2,
                bin_fills=store.bin_fill_pairs(), eps=0, buffers=4,
                acc_bytes=streaming_acc_bytes(SPEC.n, SPEC.f),
                hbm_bytes=1 << 22)
sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
mesh = make_mesh((2, 2), ("data", "model"))
ref, _, _ = run_streaming_als(store, sched, cfg, mesh=mesh)
for kill in (1, 3, 5):
    with tempfile.TemporaryDirectory() as d:
        try:
            run_streaming_als(store, sched, cfg, mesh=mesh, ckpt_dir=d,
                              fail_after_waves=kill)
            raise SystemExit("simulated kill did not fire")
        except SimulatedFailure:
            pass
        fac, _, tel = run_streaming_als(store, sched, cfg, mesh=mesh,
                                        ckpt_dir=d)
        assert tel.resumed_from_step == kill
        assert np.array_equal(fac.x, ref.x), kill
        assert np.array_equal(fac.theta, ref.theta), kill
print("mesh binned ALS resume OK")
""")


@pytest.mark.mesh
def test_streaming_sgd_on_mesh_matches_incore():
    """Streaming SGD with each wave's tiles sharded one-per-device over the
    joint (data, model) axes matches the in-core trajectory to 1e-4 —
    including a ragged wave split (n_workers = 3 on a g = 4 grid)."""
    from test_distributed import run_script
    run_script(MESH_COMMON + """
from repro.sgd import SgdConfig, block_ell, sgd_train
grid = block_ell(r, g=4)
cfg = SgdConfig(f=SPEC.f, lam=SPEC.lam, lr=0.1, mode="ref", seed=3,
                schedule="inverse_time", decay=1.0, epochs=3)
state, hist = sgd_train(grid, cfg, test=rtest)
mesh = make_mesh((4, 2), ("data", "model"))
for n_workers in (2, 3):          # 3 -> ragged waves (3 tiles + 1 tile)
    tiles = TileStore(grid)
    sched = build_sgd_schedule(grid, SPEC.f, n_workers=n_workers)
    fac, shist, tel = run_streaming_sgd(tiles, sched, cfg, test_eval=rtest,
                                        mesh=mesh)
    assert np.abs(fac.x - np.asarray(state.x)).max() < 1e-4, n_workers
    assert np.abs(fac.theta - np.asarray(state.theta)).max() < 1e-4
    assert abs(shist[-1]["test_rmse"] - hist[-1]["test_rmse"]) < 1e-4
    assert tel.peak_bytes <= tel.capacity_bytes
    assert tel.waves_run == sched.waves_per_epoch * cfg.epochs
print("mesh SGD parity OK")
""")
