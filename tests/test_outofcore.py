"""Out-of-core wave scheduler: ISSUE-2 acceptance suite.

Fast tests cover the Prefetcher lifecycle regression and the store/schedule
invariants; the multi-wave end-to-end runs (streaming == in-core oracle,
capacity, kill/resume) are marked ``slow`` and run in their own CI job.
"""
import shutil
import time

import numpy as np
import pytest

from repro.core import als as als_mod
from repro.core.partition import plan_for
from repro.data.prefetch import Prefetcher
from repro.outofcore import (RatingStore, SimulatedFailure, TileStore,
                             build_schedule, build_sgd_schedule,
                             required_capacity_bytes, run_streaming_als,
                             run_streaming_sgd)
from repro.sgd import SgdConfig, block_ell, sgd_train
from repro.sparse import synth

SPEC = synth.SynthSpec("oc", 96, 40, 1500, 8, 0.05)


def _problem(seed=0):
    return synth.make_synthetic_ratings(SPEC, seed=seed)


def _forced_plan(r, q=4, n_data=2, store=None, depth=2):
    """A waves >= 2 plan on in-core-sized data, priced with the store's real
    padding fills and the driver's accumulator + double-buffer residents
    (depth queued + one loader-held + one being consumed)."""
    fill = store.worst_fill if store is not None else r.fill
    acc_eps = SPEC.n * (SPEC.f * SPEC.f + 3 * SPEC.f + 1) * 4
    return plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=1, q=q, n_data=n_data,
                    fill=fill, eps=acc_eps, buffers=depth + 2,
                    hbm_bytes=1 << 22)


# ---------------------------------------------------------------------------
# Prefetcher lifecycle (satellite: abandoning iteration must not leak the
# worker thread blocked on Queue.put)
# ---------------------------------------------------------------------------

def _join(pf, timeout=5.0):
    deadline = time.monotonic() + timeout
    while pf._thread.is_alive() and time.monotonic() < deadline:
        time.sleep(0.01)
    return not pf._thread.is_alive()


def test_prefetcher_close_unblocks_worker():
    pf = Prefetcher(({"x": np.asarray([i])} for i in range(1000)), depth=1)
    next(pf)                      # worker is now blocked on a full queue
    pf.close()
    assert _join(pf), "worker thread leaked after close()"
    assert pf.closed
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()                    # idempotent


def test_prefetcher_context_manager():
    with Prefetcher(iter(range(1000)), depth=1,
                    put=lambda x: x) as pf:
        assert next(pf) == 0
    assert _join(pf)


def test_prefetcher_close_after_exhaustion():
    pf = Prefetcher(iter(range(3)), depth=2, put=lambda x: x)
    assert list(pf) == [0, 1, 2]
    pf.close()
    assert _join(pf)


def test_prefetcher_still_propagates_errors():
    def boom():
        yield 1
        raise ValueError("boom")

    with Prefetcher(boom(), depth=2, put=lambda x: x) as pf:
        assert next(pf) == 1
        with pytest.raises(ValueError, match="boom"):
            next(pf)


# ---------------------------------------------------------------------------
# Store / schedule invariants (fast)
# ---------------------------------------------------------------------------

def test_rating_store_keeps_both_orientations():
    r, _, _, _ = _problem()
    store = RatingStore(r, q=4)
    assert store.m_pad % 4 == 0 and store.m_pad >= r.m
    assert store.r.m == store.m_pad
    # padded rows are empty
    assert int(store.r.cnt[r.m:].sum()) == 0
    # the q R^T shards hold exactly the same nonzeros as R
    assert int(store.rt_parts.cnt.sum()) == r.nnz == store.nnz
    # shard j only references batch-local user coordinates
    npp = store.m_pad // 4
    for j in range(4):
        idx, val, cnt = store.theta_batch_triplet(j)
        live = np.arange(idx.shape[1])[None, :] < cnt[:, None]
        assert live.sum() and idx[live].max() < npp
    assert store.fill_rt >= 1.0 and store.worst_fill >= store.fill_r


def test_rating_store_roundtrips_the_matrix():
    """Sum of R^T shard j's entries == sum over batch j's rows of R."""
    r, _, _, _ = _problem()
    q = 4
    store = RatingStore(r, q=q)
    npp = store.m_pad // q
    for j in range(q):
        idx, val, cnt = store.x_slice_triplet(j * npp, (j + 1) * npp)
        live = np.arange(idx.shape[1])[None, :] < cnt[:, None]
        _, tval, tcnt = store.theta_batch_triplet(j)
        tlive = np.arange(tval.shape[1])[None, :] < tcnt[:, None]
        assert int(cnt.sum()) == int(tcnt.sum())
        np.testing.assert_allclose(val[live].sum(), tval[tlive].sum(),
                                   rtol=1e-5)


def test_build_schedule_covers_rows_once():
    r, _, _, _ = _problem()
    store = RatingStore(r, q=4)
    plan = _forced_plan(r, q=4, n_data=2, store=store)
    sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
    assert plan.waves == len(sched.waves) == 2
    assert sched.m_pad == store.m_pad
    covered = np.zeros(sched.m_pad, np.int32)
    for wave in sched.waves:
        for b in wave.batches:
            covered[b.row_start:b.row_stop] += 1
    assert (covered == 1).all()
    assert required_capacity_bytes(store, sched, SPEC.f) > 0


def test_streaming_ragged_last_wave():
    """q not divisible by n_data: the last wave carries fewer batches and its
    per-device metering divides by the actual batch count."""
    r, rt, rte, _ = _problem()
    cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=1, mode="ref")
    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    _, hist = als_mod.als_train(rr, rtt, r.m, rt.m, cfg)

    store = RatingStore(r, q=3)
    plan = _forced_plan(r, q=3, n_data=2, store=store)
    sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
    assert len(sched.waves) == 2 and len(sched.waves[-1].batches) == 1
    _, shist, tel = run_streaming_als(store, sched, cfg, train_eval=rr)
    assert abs(shist[-1]["train_rmse"] - hist[-1]["train_rmse"]) < 1e-4
    assert tel.peak_bytes <= tel.capacity_bytes


# ---------------------------------------------------------------------------
# End-to-end multi-wave runs (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_streaming_matches_incore_rmse():
    """Acceptance: forced waves >= 2 streaming == in-core als_run to 1e-4,
    and the peak simulated device footprint respects the plan's budget."""
    r, rt, rte, _ = _problem()
    cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=3, mode="ref")
    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    _, hist = als_mod.als_train(rr, rtt, r.m, rt.m, cfg, test=rtest)

    store = RatingStore(r, q=4)
    plan = _forced_plan(r, q=4, n_data=2, store=store)
    assert plan.waves >= 2
    sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
    _, shist, tel = run_streaming_als(store, sched, cfg, train_eval=rr,
                                      test_eval=rtest)

    assert len(shist) == len(hist)
    for a, b in zip(shist, hist):
        assert abs(a["train_rmse"] - b["train_rmse"]) < 1e-4
        assert abs(a["test_rmse"] - b["test_rmse"]) < 1e-4
    # memory: under the plan's per-device budget, and genuinely streaming
    # (well below holding the whole padded problem resident)
    assert tel.peak_bytes <= tel.capacity_bytes
    in_core_bytes = store.host_nbytes + (store.m_pad + store.n) * SPEC.f * 4
    assert tel.peak_bytes < in_core_bytes
    assert tel.waves_run == 2 * len(sched.waves) * cfg.iters


@pytest.mark.slow
def test_wave_update_fn_on_mesh_matches_oracle():
    """`distributed.su_als.make_wave_update_fn` — the driver's hook for
    running a wave slice on a real mesh — must match the single-device
    per-slice solve.  Runs in a subprocess with 8 forced host devices
    (same harness as test_distributed)."""
    from test_distributed import run_script
    run_script("""
import numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.sparse import synth, padded
from repro.distributed.su_als import make_wave_update_fn
from repro.kernels import ops as kops

mesh = make_mesh((4, 2), ("data", "model"))
spec = synth.SynthSpec("oc", 64, 16, 600, 8, 0.05)
r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
parts = padded.partition_padded(r, 2)       # model-axis column shards
P, m, K = parts.idx.shape
idx = np.transpose(parts.idx, (1, 0, 2)).reshape(m, P * K)[:32]
val = np.transpose(parts.val, (1, 0, 2)).reshape(m, P * K)[:32]
cnt = np.transpose(parts.cnt, (1, 0)).reshape(m, P)[:32]
theta = np.random.default_rng(0).standard_normal((16, 8)).astype(np.float32)

out = make_wave_update_fn(mesh, lam=0.05, mode="ref")(theta, idx, val, cnt)
ref = np.asarray(kops.als_update_factor(
    jnp.asarray(theta), jnp.asarray(r.idx[:32]), jnp.asarray(r.val[:32]),
    jnp.asarray(r.cnt[:32]), 0.05))
assert out.shape == (32, 8), out.shape
assert np.abs(out - ref).max() < 1e-4, np.abs(out - ref).max()
print("wave update on mesh OK")
""")


# ---------------------------------------------------------------------------
# Streaming SGD: tile-wave schedule invariants (fast) + parity suite (slow)
# ---------------------------------------------------------------------------

def _sgd_problem(g=4, n_workers=2):
    r, _, rte, _ = _problem()
    grid = block_ell(r, g=g)
    tiles = TileStore(grid)
    sched = build_sgd_schedule(grid, SPEC.f, n_workers=n_workers)
    return r, rte, grid, tiles, sched


def _sgd_cfg(**kw):
    kw.setdefault("schedule", "inverse_time")
    kw.setdefault("decay", 1.0)
    return SgdConfig(f=SPEC.f, lam=SPEC.lam, lr=0.1, mode="ref", seed=3, **kw)


def test_sgd_schedule_covers_every_tile_once():
    """Every (i, j) tile appears in exactly one wave per epoch, waves never
    mix diagonal sets, and n_workers < g forces multiple waves per set."""
    _, _, grid, tiles, sched = _sgd_problem(g=4, n_workers=3)   # ragged
    g = grid.g
    assert sched.waves_per_epoch == g * 2       # ceil(4/3) = 2 waves/set
    seen = set()
    for s, ws in enumerate(sched.set_waves):
        for w in ws:
            assert w.set_index == s
            for i, j in w.tiles:
                assert (j - i) % g == s          # tile belongs to its set
                assert (i, j) not in seen
                seen.add((i, j))
    assert len(seen) == g * g
    # epoch flattening follows the permuted set order and renumbers
    order = [2, 0, 3, 1]
    waves = sched.epoch_waves(order)
    assert [w.index for w in waves] == list(range(sched.waves_per_epoch))
    assert [w.set_index for w in waves] == [2, 2, 0, 0, 3, 3, 1, 1]
    with pytest.raises(AssertionError):
        sched.epoch_waves([0, 1, 2, 2])          # not a permutation


def test_tile_store_views_grid():
    _, _, grid, tiles, _ = _sgd_problem()
    assert (tiles.g, tiles.mb, tiles.nb, tiles.K) == \
        (grid.g, grid.mb, grid.nb, grid.K)
    assert tiles.nnz == grid.nnz
    idx, val, cnt = tiles.tile_triplet(1, 2)
    np.testing.assert_array_equal(idx, grid.idx[1, 2])
    assert np.shares_memory(val, grid.val), "tile views must not copy"
    assert tiles.host_nbytes > 0


@pytest.mark.slow
def test_streaming_sgd_matches_incore():
    """Acceptance: a forced waves >= 2 tile plan matches the in-core SGD
    RMSE trajectory, and peak metered bytes stay under the plan capacity."""
    r, rte, grid, tiles, sched = _sgd_problem(g=4, n_workers=2)
    assert all(len(ws) >= 2 for ws in sched.set_waves)
    rtest = als_mod.ell_triplet(rte)
    cfg = _sgd_cfg(epochs=3)
    state, hist = sgd_train(grid, cfg, test=rtest)
    fac, shist, tel = run_streaming_sgd(tiles, sched, cfg, test_eval=rtest)
    assert len(shist) == len(hist)
    for a, b in zip(shist, hist):
        assert abs(a["test_rmse"] - b["test_rmse"]) < 1e-3
    np.testing.assert_allclose(fac.x, np.asarray(state.x), atol=1e-5)
    np.testing.assert_allclose(fac.theta, np.asarray(state.theta), atol=1e-5)
    # memory: under budget, and genuinely streaming (well below resident)
    assert tel.peak_bytes <= tel.capacity_bytes
    assert tel.peak_bytes < tiles.host_nbytes + fac.nbytes
    assert tel.waves_run == sched.waves_per_epoch * cfg.epochs


@pytest.mark.slow
@pytest.mark.parametrize("kill_after", [3, 11])
def test_streaming_sgd_kill_and_resume_bit_exact(tmp_path, kill_after):
    """Acceptance: killed after wave ``kill_after`` (3 = mid-first-epoch,
    11 = mid-second-epoch across the set-order reshuffle), the resumed run
    reaches bit-identical factors."""
    _, _, grid, tiles, sched = _sgd_problem(g=4, n_workers=2)
    cfg = _sgd_cfg(epochs=2)
    assert kill_after < cfg.epochs * sched.waves_per_epoch
    ref_fac, ref_hist, _ = run_streaming_sgd(tiles, sched, cfg)

    ckpt = str(tmp_path / "sgd_ckpt")
    shutil.rmtree(ckpt, ignore_errors=True)
    with pytest.raises(SimulatedFailure):
        run_streaming_sgd(tiles, sched, cfg, ckpt_dir=ckpt,
                          fail_after_waves=kill_after)
    fac, hist, tel = run_streaming_sgd(tiles, sched, cfg, ckpt_dir=ckpt)
    assert tel.resumed_from_step == kill_after
    assert len(hist) == cfg.epochs - kill_after // sched.waves_per_epoch
    np.testing.assert_array_equal(fac.x, ref_fac.x)
    np.testing.assert_array_equal(fac.theta, ref_fac.theta)


@pytest.mark.slow
def test_streaming_hybrid_runs_both_phases_streamed(tmp_path):
    """Streaming warm start + streaming refine under one budget; a restart
    with a committed SGD checkpoint skips the ALS phase."""
    from repro.sgd import run_streaming_hybrid
    r, rte, grid, tiles, sched = _sgd_problem(g=4, n_workers=2)
    rtest = als_mod.ell_triplet(rte)
    store = RatingStore(r, q=4)
    plan = _forced_plan(r, q=4, n_data=2, store=store)
    als_sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
    als_cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
    cfg = _sgd_cfg(epochs=2)

    ck = str(tmp_path / "hyb")
    fac, hist, tel = run_streaming_hybrid(
        store, als_sched, tiles, sched, als_cfg, cfg, test_eval=rtest,
        ckpt_dir=ck)
    assert [h["phase"] for h in hist] == ["als"] * 2 + ["sgd"] * 2
    # warm start pays off: first SGD epoch starts below the cold ALS start
    assert hist[2]["test_rmse"] < hist[0]["test_rmse"]
    # ONE merged telemetry (ISSUE 7 satellite): the per-phase views stay
    # reachable and each ran within its own budget
    atel, stel = tel.phases["als"], tel.phases["sgd"]
    assert atel.peak_bytes <= atel.capacity_bytes
    assert stel.peak_bytes <= stel.capacity_bytes
    assert tel.waves_run == atel.waves_run + stel.waves_run
    assert tel.peak_bytes == max(atel.peak_bytes, stel.peak_bytes)
    assert tel.wall_seconds >= max(atel.wall_seconds, stel.wall_seconds)
    assert any(k.startswith("als/") for k in tel.phase_seconds)
    assert any(k.startswith("sgd/") for k in tel.phase_seconds)
    fac2, hist2, tel2 = run_streaming_hybrid(
        store, als_sched, tiles, sched, als_cfg, cfg, test_eval=rtest,
        ckpt_dir=ck)
    # complete: no ALS re-run, so the merged view has no ALS phase
    assert hist2 == [] and "als" not in tel2.phases
    np.testing.assert_array_equal(fac2.x, fac.x)
    np.testing.assert_array_equal(fac2.theta, fac.theta)


@pytest.mark.slow
@pytest.mark.parametrize("kill_after", [1, 3])
def test_kill_and_resume_reaches_same_result(tmp_path, kill_after):
    """Acceptance: a run killed after wave ``kill_after`` (1 = first solve-X
    wave, 3 = mid accumulate-Theta) resumes from checkpoint to the same
    final factors."""
    r, _, _, _ = _problem()
    cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
    rr = als_mod.ell_triplet(r)
    store = RatingStore(r, q=4)
    plan = _forced_plan(r, q=4, n_data=2, store=store)
    sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)

    ref_fac, ref_hist, _ = run_streaming_als(store, sched, cfg, train_eval=rr)

    ckpt = str(tmp_path / "ckpt")
    shutil.rmtree(ckpt, ignore_errors=True)
    with pytest.raises(SimulatedFailure):
        run_streaming_als(store, sched, cfg, ckpt_dir=ckpt, train_eval=rr,
                          fail_after_waves=kill_after)
    fac, hist, tel = run_streaming_als(store, sched, cfg, ckpt_dir=ckpt,
                                       train_eval=rr)
    assert tel.resumed_from_step == kill_after
    assert abs(hist[-1]["train_rmse"] - ref_hist[-1]["train_rmse"]) < 1e-4
    np.testing.assert_allclose(fac.x, ref_fac.x, atol=1e-5)
    np.testing.assert_allclose(fac.theta, ref_fac.theta, atol=1e-5)


# ---------------------------------------------------------------------------
# Degree-binned stores and streaming (ISSUE 9)
# ---------------------------------------------------------------------------

def test_rating_store_binned_invariants():
    """Binned shards hold the same nonzeros, expose per-component fills,
    and price the planner through per-bin (slots, nnz) pairs whose
    aggregate equals the worst-orientation fill."""
    r, _, _, _ = _problem()
    store_u = RatingStore(r, q=4)
    store_b = RatingStore(r, q=4, n_bins=4)
    assert store_b.n_bins == 4 and store_u.n_bins == 1
    assert store_b.r_binned.nnz == r.nnz
    assert sum(b.nnz for b in store_b.rt_binned) == r.nnz
    # binned fills never exceed the uniform ones
    assert store_b.fill_r <= store_u.fill_r
    assert store_b.fill_rt <= store_u.fill_rt
    assert store_b.worst_fill <= store_u.worst_fill
    fb = store_b.fill_breakdown()
    assert set(fb) == {"r", "rt"}
    assert fb["r"] == store_b.fill_r and fb["rt"] == store_b.fill_rt
    pairs = store_b.bin_fill_pairs()
    slots = sum(s for s, _ in pairs)
    nnz = sum(z for _, z in pairs)
    assert abs(slots / nnz - store_b.worst_fill) < 1e-12
    # the planner prices exactly that aggregate
    pa = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=1, q=4,
                  fill=store_b.worst_fill)
    pb = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=1, q=4, bin_fills=pairs)
    assert pa.terms["R_shard"] == pb.terms["R_shard"]
    # row slices cover the binned matrix exactly
    npp = store_b.m_pad // 4
    assert sum(store_b.x_slice_binned(j * npp, (j + 1) * npp).nnz
               for j in range(4)) == r.nnz
    # uniform stores don't grow binned shards or accept binned queries
    assert store_u.r_binned is None
    with pytest.raises(AssertionError):
        store_u.x_slice_binned(0, npp)


def test_binned_store_with_model_shards_builds_stacks():
    """Binned + p > 1 now builds the batch-uniform stacked theta bins
    (``rt_stacked``) instead of the p = 1 per-batch BinnedELL shards —
    the layout the mesh herm stack can shard (one shape per bin)."""
    r, _, _, _ = _problem()
    store = RatingStore(r, q=4, p=2, n_bins=4)
    assert store.r_binned is None and store.rt_binned is None
    stacks = store.rt_stacked
    assert stacks is not None and len(stacks) >= 2
    # every stack is batch-uniform and p-divisible; nonzeros conserved
    assert all(st.idx.shape[0] == 4 and st.rows % 2 == 0 for st in stacks)
    assert sum(st.nnz for st in stacks) == r.nnz
    # caps ascend and the fill pairs price exactly the stacked slots
    caps = [st.cap for st in stacks]
    assert caps == sorted(caps)
    assert store.bin_fill_pairs() == [(st.padded_slots, st.nnz)
                                      for st in stacks]


@pytest.mark.slow
def test_binned_streaming_matches_unbinned():
    """Acceptance: a binned waves >= 2 streaming run reproduces the
    unbinned factors to 1e-5 (padding slots are exact zeros, so binning is
    a layout change only), its ledger stays green, and the measured
    fill_waste_ratio drops vs the uniform layout."""
    r, _, _, _ = _problem()
    cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=3, mode="ref")
    rr = als_mod.ell_triplet(r)

    store_u = RatingStore(r, q=4)
    plan_u = _forced_plan(r, q=4, n_data=2, store=store_u)
    sched_u = build_schedule(plan_u, SPEC.m, SPEC.n, n_data=2)
    fac_u, hist_u, tel_u = run_streaming_als(store_u, sched_u, cfg,
                                             train_eval=rr)

    store_b = RatingStore(r, q=4, n_bins=4)
    acc_eps = SPEC.n * (SPEC.f * SPEC.f + 3 * SPEC.f + 1) * 4
    plan_b = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=1, q=4, n_data=2,
                      bin_fills=store_b.bin_fill_pairs(), eps=acc_eps,
                      buffers=4, hbm_bytes=1 << 22)
    sched_b = build_schedule(plan_b, SPEC.m, SPEC.n, n_data=2)
    assert len(sched_b.waves) >= 2
    fac_b, hist_b, tel_b = run_streaming_als(store_b, sched_b, cfg,
                                             train_eval=rr)

    np.testing.assert_allclose(fac_b.x, fac_u.x, atol=1e-5)
    np.testing.assert_allclose(fac_b.theta, fac_u.theta, atol=1e-5)
    for a, b in zip(hist_b, hist_u):
        assert abs(a["train_rmse"] - b["train_rmse"]) < 1e-5
    assert tel_b.peak_bytes <= tel_b.capacity_bytes

    def _rec(tel, name):
        return next(rec for rec in tel.ledger["records"]
                    if rec["name"] == name)

    for tel in (tel_u, tel_b):
        assert all(rec["ok"] for rec in tel.ledger["records"]), \
            [rec for rec in tel.ledger["records"] if not rec["ok"]]
    assert tel_u.ledger["run"]["n_bins"] == 1
    assert tel_b.ledger["run"]["n_bins"] == 4
    # the measured fill actually dropped, and the per-half fills exist
    fwu = _rec(tel_u, "fill_waste_ratio")["measured"]
    fwb = _rec(tel_b, "fill_waste_ratio")["measured"]
    assert fwb < fwu
    for name in ("fill/solve_x", "fill/accumulate_theta",
                 "fill_bound/r", "fill_bound/rt"):
        assert _rec(tel_b, name)["ok"]


@pytest.mark.slow
def test_binned_kill_and_resume_bit_exact(tmp_path):
    """A binned streaming run killed mid-iteration resumes to the same
    factors as the uninterrupted binned run — checkpoint state is
    layout-agnostic (factors in original row order)."""
    r, _, _, _ = _problem()
    cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
    store = RatingStore(r, q=4, n_bins=4)
    acc_eps = SPEC.n * (SPEC.f * SPEC.f + 3 * SPEC.f + 1) * 4
    plan = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=1, q=4, n_data=2,
                    bin_fills=store.bin_fill_pairs(), eps=acc_eps,
                    buffers=4, hbm_bytes=1 << 22)
    sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
    ref_fac, _, _ = run_streaming_als(store, sched, cfg)

    ckpt = str(tmp_path / "binned_ckpt")
    shutil.rmtree(ckpt, ignore_errors=True)
    with pytest.raises(SimulatedFailure):
        run_streaming_als(store, sched, cfg, ckpt_dir=ckpt,
                          fail_after_waves=3)
    fac, _, tel = run_streaming_als(store, sched, cfg, ckpt_dir=ckpt)
    assert tel.resumed_from_step == 3
    np.testing.assert_array_equal(fac.x, ref_fac.x)
    np.testing.assert_array_equal(fac.theta, ref_fac.theta)


@pytest.mark.slow
def test_streaming_sgd_per_tile_k_matches_uniform():
    """Per-tile-K tiles stream through the grouped same-K dispatch and must
    land on bit-identical factors (slot-column slicing drops only masked
    padding) while storing strictly fewer padded slots."""
    r, rte, grid_u, tiles_u, sched_u = _sgd_problem(g=4, n_workers=2)
    grid_b = block_ell(r, g=4, per_tile_k=True)
    tiles_b = TileStore(grid_b)
    sched_b = build_sgd_schedule(grid_b, SPEC.f, n_workers=2)
    assert grid_b.padded_slots <= grid_u.padded_slots
    cfg = _sgd_cfg(epochs=2)
    fac_u, hist_u, _ = run_streaming_sgd(tiles_u, sched_u, cfg)
    fac_b, hist_b, tel_b = run_streaming_sgd(tiles_b, sched_b, cfg)
    np.testing.assert_array_equal(fac_b.x, fac_u.x)
    np.testing.assert_array_equal(fac_b.theta, fac_u.theta)
    assert tel_b.peak_bytes <= tel_b.capacity_bytes


@pytest.mark.slow
def test_streaming_hybrid_binned_matches_uniform(tmp_path):
    """Hybrid parity: binned ALS warm start + per-tile-K SGD refine lands
    within 1e-5 of the all-uniform hybrid (ALS layout change is exact to
    float roundoff; the SGD phase is bit-exact given the same start)."""
    from repro.sgd import run_streaming_hybrid
    r, rte, grid_u, tiles_u, sched_sgd_u = _sgd_problem(g=4, n_workers=2)
    rtest = als_mod.ell_triplet(rte)
    als_cfg = als_mod.AlsConfig(f=SPEC.f, lam=SPEC.lam, iters=2, mode="ref")
    cfg = _sgd_cfg(epochs=2)

    store_u = RatingStore(r, q=4)
    plan_u = _forced_plan(r, q=4, n_data=2, store=store_u)
    als_sched_u = build_schedule(plan_u, SPEC.m, SPEC.n, n_data=2)
    fac_u, hist_u, _ = run_streaming_hybrid(
        store_u, als_sched_u, tiles_u, sched_sgd_u, als_cfg, cfg,
        test_eval=rtest, ckpt_dir=str(tmp_path / "hyb_u"))

    store_b = RatingStore(r, q=4, n_bins=4)
    acc_eps = SPEC.n * (SPEC.f * SPEC.f + 3 * SPEC.f + 1) * 4
    plan_b = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=1, q=4, n_data=2,
                      bin_fills=store_b.bin_fill_pairs(), eps=acc_eps,
                      buffers=4, hbm_bytes=1 << 22)
    als_sched_b = build_schedule(plan_b, SPEC.m, SPEC.n, n_data=2)
    grid_b = block_ell(r, g=4, per_tile_k=True)
    tiles_b = TileStore(grid_b)
    sched_sgd_b = build_sgd_schedule(grid_b, SPEC.f, n_workers=2)
    fac_b, hist_b, _ = run_streaming_hybrid(
        store_b, als_sched_b, tiles_b, sched_sgd_b, als_cfg, cfg,
        test_eval=rtest, ckpt_dir=str(tmp_path / "hyb_b"))

    np.testing.assert_allclose(fac_b.x, fac_u.x, atol=1e-5)
    np.testing.assert_allclose(fac_b.theta, fac_u.theta, atol=1e-5)
    for a, b in zip(hist_b, hist_u):
        assert a["phase"] == b["phase"]
        assert abs(a["test_rmse"] - b["test_rmse"]) < 1e-5
