"""Optimizers, grad accumulation, loss variants, int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.configs import registry
from repro.models import lm as lm_mod
from repro.training import optimizer as opt_mod


def _quadratic_steps(opt_name, steps=60, lr=0.1):
    cfg = opt_mod.OptConfig(name=opt_name, lr=lr, grad_clip=10.0)
    init, update = opt_mod.make_optimizer(cfg)
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((2, 3))}
    target = {"w": jnp.asarray([1.0, 1.0]), "m": jnp.zeros((2, 3))}
    state = init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = update(g, state, params)
    return l0, float(loss(params))


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_decreases_quadratic(name):
    l0, l1 = _quadratic_steps(name)
    assert l1 < 0.05 * l0, (name, l0, l1)


def test_lr_schedule_shapes():
    """constant / inverse-time / cosine endpoints and monotonicity."""
    sched = opt_mod.lr_schedule
    assert float(sched("constant", 0, base_lr=0.3)) == pytest.approx(0.3)
    assert float(sched("constant", 999, base_lr=0.3)) == pytest.approx(0.3)
    assert float(sched("inverse_time", 0, base_lr=0.2)) == pytest.approx(0.2)
    inv = [float(sched("inverse_time", s, base_lr=0.2, decay=0.5))
           for s in range(6)]
    assert all(b < a for a, b in zip(inv, inv[1:]))
    assert inv[2] == pytest.approx(0.2 / 2.0)        # 1 + 0.5*2
    assert float(sched("cosine", 0, base_lr=0.4,
                       total_steps=10)) == pytest.approx(0.4)
    assert float(sched("cosine", 10, base_lr=0.4, total_steps=10,
                       min_lr=0.04)) == pytest.approx(0.04)
    # flat at the floor past the horizon
    assert float(sched("cosine", 25, base_lr=0.4, total_steps=10,
                       min_lr=0.04)) == pytest.approx(0.04)
    cos = [float(sched("cosine", s, base_lr=0.4, total_steps=10))
           for s in range(11)]
    assert all(b <= a for a, b in zip(cos, cos[1:]))
    with pytest.raises(ValueError):
        sched("nope", 0)


def test_lr_schedule_traced_under_jit():
    f = jax.jit(lambda s: opt_mod.lr_schedule(
        "cosine", s, base_lr=0.1, total_steps=10))
    assert float(f(jnp.int32(5))) == pytest.approx(0.05)


def test_optimizer_uses_schedule():
    """First step (cos(0)=1) matches constant exactly; a step at the
    cosine horizon with min_lr=0 is a no-op."""
    params = {"w": jnp.asarray([3.0, -2.0])}
    g = {"w": jnp.asarray([1.0, 0.5])}
    const = opt_mod.OptConfig(lr=0.1, grad_clip=10.0)
    cos = opt_mod.OptConfig(lr=0.1, grad_clip=10.0, schedule="cosine",
                            schedule_steps=8)
    s_const = opt_mod.adam_init(params)
    s_cos = opt_mod.adam_init(params)
    p1, _, _ = opt_mod.adam_update(g, s_const, params, const)
    p2, _, _ = opt_mod.adam_update(g, s_cos, params, cos)
    np.testing.assert_allclose(p1["w"], p2["w"])
    # at step >= horizon the cosine lr is min_lr = 0 -> params frozen
    s_end = opt_mod.AdamState(m=s_cos.m, v=s_cos.v, step=jnp.int32(8))
    p3, _, _ = opt_mod.adam_update(g, s_end, params, cos)
    np.testing.assert_allclose(p3["w"], params["w"])


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(opt_mod.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_microbatch_accumulation_matches_full_batch():
    """Grad accumulation must be a pure implementation detail."""
    cfg = registry.smoke_config("phi3-mini-3.8b")
    key = jax.random.PRNGKey(0)
    state = lm_mod.init_train_state(cfg, key, opt_mod.OptConfig(lr=1e-3))
    B, S = 4, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    import jax.numpy as _jnp
    s1 = lm_mod.make_train_step(cfg, opt_mod.OptConfig(lr=1e-3),
                                microbatch=1, remat=False,
                                compute_dtype=_jnp.float32)
    s2 = lm_mod.make_train_step(cfg, opt_mod.OptConfig(lr=1e-3),
                                microbatch=2, remat=False,
                                compute_dtype=_jnp.float32)
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        # one Adam step from zero moments is sign-like: any grad
        # reassociation flips updates by up to +-lr (1e-3)
        np.testing.assert_allclose(a, b, atol=2.5e-3, rtol=1e-3)


def test_fused_loss_matches_unfused():
    cfg = registry.smoke_config("qwen3-4b")
    key = jax.random.PRNGKey(1)
    from repro.models import transformer as T
    params = T.init_params(cfg, key)
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    l1 = lm_mod.lm_loss(cfg, params, batch, remat=False, fused_loss=False)
    l2 = lm_mod.lm_loss(cfg, params, batch, remat=False, fused_loss=True,
                        loss_chunk=4)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


def test_xent_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((2, 3, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, (2, 3)), jnp.int32)
    nll = lm_mod._xent(logits, labels)
    ref = -jax.nn.log_softmax(logits, -1)
    want = np.take_along_axis(np.asarray(ref), np.asarray(labels)[..., None],
                              axis=-1)[..., 0]
    np.testing.assert_allclose(nll, want, atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.sampled_from([1e-3, 1.0, 50.0]))
def test_int8_quantization_error_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)) * scale, jnp.float32)
    q, s = lm_mod.quantize_int8(g, jax.random.PRNGKey(seed))
    back = q.astype(jnp.float32) * s
    # error bounded by one quantization bin
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 1.01


def test_int8_stochastic_rounding_unbiased():
    g = jnp.full((20000,), 0.3e-2, jnp.float32)
    q, s = lm_mod.quantize_int8(g, jax.random.PRNGKey(0))
    back = float(jnp.mean(q.astype(jnp.float32) * s))
    assert back == pytest.approx(0.3e-2, rel=0.05)


def test_train_loss_goes_down_tiny_lm():
    """Integration: a tiny LM learns the synthetic n-gram stream."""
    from repro.data.tokens import synthetic_lm_batches
    cfg = registry.smoke_config("phi3-mini-3.8b")
    state = lm_mod.init_train_state(cfg, jax.random.PRNGKey(0),
                                    opt_mod.OptConfig(lr=3e-3))
    step = jax.jit(lm_mod.make_train_step(
        cfg, opt_mod.OptConfig(lr=3e-3), remat=False,
        compute_dtype=jnp.float32))
    it = synthetic_lm_batches(cfg.vocab, 32, 8, seed=0)
    losses = []
    for i, batch in zip(range(60), it):
        state, m = step(state, jax.tree.map(jnp.asarray, batch))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
