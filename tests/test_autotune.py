"""Layout autotuner (``repro.core.autotune``): PR-10 acceptance suite.

The analytic sweep's whole claim is *exactness* — each ladder rung is priced
at the same integers ``predicted_stream_stats`` would derive from a real
store built at that config — so the core test here is a brute-force store
build per rung, on both p = 1 and p = 2 topologies.  The rest covers the
TuneCache contract (round-trip, key separation, stale-shape miss, foreign
schema) and the ``"auto"`` wiring through ``RatingStore`` / ``block_ell`` /
``plan_for``.
"""
import json

import numpy as np
import pytest

from repro.core import autotune as at
from repro.core.partition import plan_for
from repro.outofcore import RatingStore, build_schedule
from repro.outofcore.schedule import predicted_stream_stats
from repro.sgd import block_ell
from repro.sparse import synth

SPEC = synth.SynthSpec("oc", 96, 40, 1500, 8, 0.05)


def _problem(seed=0, alpha_user=0.0):
    return synth.make_synthetic_ratings(SPEC, seed=seed,
                                        alpha_user=alpha_user)


def _store_bytes(r, q, cfg, p):
    """Ground truth for one rung: build the real store, price its schedule."""
    store = RatingStore(r, q=q, p=p, k_multiple=cfg.k_multiple,
                        n_bins=cfg.n_bins)
    fill_kw = (dict(bin_fills=store.bin_fill_pairs()) if store.n_bins > 1
               else dict(fill=store.worst_fill))
    plan = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, p=p, q=q, n_data=2,
                    hbm_bytes=1 << 22, **fill_kw)
    sched = build_schedule(plan, SPEC.m, SPEC.n, n_data=2)
    stats = predicted_stream_stats(store, sched, SPEC.f)
    return sum(stats["x_bytes"]) + sum(stats["t_bytes"])


# ---------------------------------------------------------------------------
# Analytic pricing: exact vs brute-force store builds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [1, 2])
def test_analytic_pricing_matches_real_store_per_rung(p):
    """Every ladder rung's analytic price equals, to the byte, what the
    schedule layer predicts for a real store built at that config — on the
    uniform topology and on a p = 2 mesh (stacked bins)."""
    r, _, _, _ = _problem()
    for cfg in at.als_ladder(8):
        priced = at.predicted_als_bytes(r, 4, cfg, p=p, f=SPEC.f)
        assert priced["bytes"] == _store_bytes(r, 4, cfg, p), cfg


@pytest.mark.parametrize("p", [1, 2])
def test_sweep_argmin_matches_brute_force(p):
    """The sweep keeps the rung a brute-force enumeration over real stores
    would keep: score == min over the ladder, every candidate priced."""
    r, _, _, _ = _problem()
    res = at.tune_als_layout(r, 4, p=p, f=SPEC.f)
    assert res.unit == "bytes" and res.mode == "analytic"
    assert not res.cache_hit
    ladder = at.als_ladder(8)
    assert len(res.candidates) == len(ladder)
    truth = {json.dumps(cfg.to_obj(), sort_keys=True):
             _store_bytes(r, 4, cfg, p) for cfg in ladder}
    assert res.score == min(truth.values())
    assert truth[json.dumps(res.config.to_obj(), sort_keys=True)] == res.score
    for cand in res.candidates:
        assert res.score <= cand["score"]
        assert cand["score"] == \
            truth[json.dumps(cand["config"], sort_keys=True)]
    # the skewed fixture must actually reward binning, or the sweep is moot
    assert res.config.n_bins > 1


def test_measured_mode_scores_seconds():
    """Measured mode (Alg. 2 proper) times one real wave per rung through
    the obs phase clock and argmins on seconds."""
    r, _, _, _ = _problem()
    ladder = [at.LayoutConfig(n_bins=1), at.LayoutConfig(n_bins=2)]
    res = at.tune_als_layout(r, 2, f=SPEC.f, ladder=ladder, mode="measured")
    assert res.unit == "seconds" and res.mode == "measured"
    secs = [c["seconds"] for c in res.candidates]
    assert len(secs) == 2 and all(s > 0 for s in secs)
    assert res.score == min(secs)


# ---------------------------------------------------------------------------
# TuneCache contract
# ---------------------------------------------------------------------------

def test_cache_roundtrip_and_key_separation(tmp_path):
    r, _, _, _ = _problem()
    path = str(tmp_path / "tune_cache.json")
    miss = at.tune_als_layout(r, 4, f=SPEC.f, cache=path)
    assert not miss.cache_hit
    hit = at.tune_als_layout(r, 4, f=SPEC.f, cache=path)
    assert hit.cache_hit
    assert hit.config == miss.config and hit.score == miss.score
    assert hit.key == miss.key
    # a different topology is a different problem class: q = 2 must miss
    other = at.tune_als_layout(r, 2, f=SPEC.f, cache=path)
    assert not other.cache_hit and other.key != miss.key
    # on-disk form: schema + provenance stamp survive the round trip
    with open(path) as fh:
        data = json.load(fh)
    assert data["schema"] == at.TUNECACHE_SCHEMA
    entry = data["entries"][miss.key]
    assert entry["config"] == miss.config.to_obj()
    assert {"git_sha", "timestamp", "jax", "backend",
            "schema"} <= set(entry["provenance"])
    # invalidation: the next touch re-tunes
    cache = at.TuneCache(path)
    cache.invalidate(miss.key)
    assert at.tune_als_layout(r, 4, f=SPEC.f, cache=cache).cache_hit is False


def test_stale_shape_or_skew_misses():
    """Keys bucket shapes to powers of two and fingerprint the degree skew:
    minor drift hits, a 2x scale change or a different skew profile misses."""
    r, _, _, _ = _problem()
    deg = r.cnt[:r.m]
    base = at.tune_key("als", r.m, r.n_cols, r.nnz, deg, q=4)
    # minor drift within the same power-of-two bucket still hits
    assert at.tune_key("als", r.m + 3, r.n_cols, r.nnz + 40, deg, q=4) == base
    # a real scale change misses
    assert at.tune_key("als", 2 * r.m, r.n_cols, r.nnz, deg, q=4) != base
    assert at.tune_key("als", r.m, r.n_cols, 2 * r.nnz, deg, q=4) != base
    # same shapes, flat instead of skewed degrees: different signature
    flat = np.full_like(deg, max(int(deg.mean()), 1))
    assert at.tune_key("als", r.m, r.n_cols, r.nnz, flat, q=4) != base
    # solvers never share entries
    assert at.tune_key("sgd", r.m, r.n_cols, r.nnz, deg, q=4) != base


def test_cache_ignores_foreign_schema(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text(json.dumps({"schema": "somebody/else-v9",
                                "entries": {"k": {}}}))
    cache = at.TuneCache(str(path))
    assert len(cache) == 0                     # a miss, not an error
    cache.put("k2", {"config": at.LayoutConfig().to_obj(), "score": 1})
    assert json.loads(path.read_text())["schema"] == at.TUNECACHE_SCHEMA


# ---------------------------------------------------------------------------
# "auto" wiring: store / planner / SGD grid
# ---------------------------------------------------------------------------

def test_store_auto_matches_explicit_best():
    """``RatingStore(n_bins="auto")`` builds exactly the store the sweep's
    winner describes and records the decision for the ledger."""
    r, _, _, _ = _problem()
    cache = at.TuneCache(None)
    res = at.tune_als_layout(r, 4, cache=cache)       # store's default f=16
    store = RatingStore(r, q=4, n_bins="auto", tune_cache=cache)
    assert store.tune is not None and store.tune["cache_hit"] is True
    assert store.tune["config"] == res.config.to_obj()
    assert store.tune["key"] == res.key
    explicit = RatingStore(r, q=4, n_bins=res.config.n_bins,
                           k_multiple=res.config.k_multiple)
    assert store.n_bins == explicit.n_bins
    assert store.bin_fill_pairs() == explicit.bin_fill_pairs()
    # hand-built stores carry no decision
    assert explicit.tune is None


def test_plan_for_auto_prices_winner_bin_fills():
    r, _, _, _ = _problem()
    deg = np.asarray(r.cnt[:r.m])
    auto = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, 1, 4, n_data=2,
                    hbm_bytes=1 << 22, auto=True, degrees=deg)
    res = at.tune_plan_fills(SPEC.m, SPEC.n, r.nnz, SPEC.f, 1, 4,
                             degrees=deg)
    want = res.config.to_obj()
    pairs = next(c["bin_fills"] for c in res.candidates
                 if c["config"] == want)
    manual = plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, 1, 4, n_data=2,
                      hbm_bytes=1 << 22, bin_fills=pairs)
    assert auto.bytes_per_device == manual.bytes_per_device
    assert auto.terms == manual.terms
    # degrees are mandatory on the auto path
    with pytest.raises(AssertionError, match="degrees"):
        plan_for(SPEC.m, SPEC.n, r.nnz, SPEC.f, 1, 4, auto=True)


def test_sgd_auto_picks_min_dispatched_slots(tmp_path):
    """``block_ell(per_tile_k="auto")`` returns the grid with the fewest
    dispatched slots over the (per_tile_k, degree_sort) ladder, stamps the
    decision on ``grid.tune``, and rebuilds identically from a cache hit."""
    r, _, _, _ = _problem(alpha_user=1.2)         # skew both axes
    cache = str(tmp_path / "cache.json")
    grid = block_ell(r, 4, per_tile_k="auto", tune_cache=cache)
    slots = {(ptk, ds): block_ell(r, 4, per_tile_k=ptk,
                                  degree_sort=ds).padded_slots
             for ptk, ds in at.SGD_LADDER}
    assert grid.padded_slots == min(slots.values())
    assert grid.tune is not None and grid.tune["unit"] == "slots"
    assert not grid.tune["cache_hit"]
    assert grid.tune["score"] == grid.padded_slots
    cfg = at.LayoutConfig.from_obj(grid.tune["config"])
    assert slots[(cfg.per_tile_k, cfg.degree_sort)] == grid.padded_slots
    # the skewed fixture must reward per-tile K, or the sweep is moot
    assert cfg.per_tile_k
    # cache hit: config-only entry, grid rebuilt to the same layout
    again = block_ell(r, 4, per_tile_k="auto", tune_cache=cache)
    assert again.tune["cache_hit"] is True
    assert again.tune["config"] == grid.tune["config"]
    assert again.padded_slots == grid.padded_slots
    np.testing.assert_array_equal(again.cnt, grid.cnt)
    np.testing.assert_array_equal(again.idx, grid.idx)
