"""RG-LRU and RWKV6 recurrences: parallel scan vs step-by-step decode,
chunked vs plain WKV, MoE dispatch vs dense-mixture oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import rwkv6 as rw


def _rg_params(seed, D, R):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s) * 0.2, jnp.float32)
    return {
        "w_in_rnn": mk(D, R), "w_in_gate": mk(D, R), "conv": mk(4, R),
        "w_a": mk(R, R), "w_x": mk(R, R),
        "lam": jnp.asarray(rng.standard_normal(R), jnp.float32),
        "w_out": mk(R, D),
    }


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50))
def test_rglru_scan_equals_stepwise(seed):
    """associative_scan prefill == sequential single-step decode."""
    D, R, B, S = 8, 8, 2, 12
    p = _rg_params(seed, D, R)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)

    y_scan, cache = rg.recurrent_branch(p, x, cache=None)

    c = {"conv": jnp.zeros((B, 3, R)), "h": jnp.zeros((B, R))}
    ys = []
    for t in range(S):
        yt, c = rg.recurrent_branch(p, x[:, t:t + 1], cache=c)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_scan, y_step, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(cache["h"], c["h"], atol=1e-4)
    np.testing.assert_allclose(cache["conv"], c["conv"], atol=1e-5)


def _rw_params(seed, D, FF):
    rng = np.random.default_rng(seed)
    shapes = rw.rwkv_param_shapes(D, FF)
    out = {}
    for k, (shp, _) in shapes.items():
        if k.startswith("mu_"):
            out[k] = jnp.full(shp, 0.5, jnp.float32)
        elif k == "w0":
            out[k] = jnp.full(shp, -2.0, jnp.float32)
        elif k in ("ln_w",):
            out[k] = jnp.ones(shp, jnp.float32)
        elif k in ("ln_b", "u"):
            out[k] = jnp.zeros(shp, jnp.float32)
        else:
            out[k] = jnp.asarray(
                np.random.default_rng(hash(k) % 2**31).standard_normal(shp)
                * 0.2, jnp.float32)
    return out


def test_wkv_chunked_equals_plain():
    B, S, H, dh = 2, 32, 2, 8
    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jax.nn.sigmoid(mk())            # decay in (0,1)
    u = jnp.asarray(rng.standard_normal((H, dh)), jnp.float32)
    y1, s1 = rw._wkv_scan(r, k, v, w, u, chunk=1 << 30)   # plain
    y2, s2 = rw._wkv_scan(r, k, v, w, u, chunk=8)          # chunked
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)


def test_rwkv_time_mix_scan_equals_stepwise():
    D, FF, B, S = 128, 256, 2, 10     # D multiple of HEAD_DIM=64
    p = _rw_params(0, D, FF)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_scan, cache = rw.time_mix(p, x, cache=None)

    c = {"s": jnp.zeros((B, D // 64, 64, 64)), "x_prev": jnp.zeros((B, D))}
    ys = []
    for t in range(S):
        yt, nc = rw.time_mix(p, x[:, t:t + 1], cache=c)
        c = {"s": nc["s"], "x_prev": nc["x_prev"]}
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_scan, y_step, atol=2e-3, rtol=2e-3)


def test_moe_dispatch_matches_dense_mixture():
    """Sort-based dispatch == dense weighted mixture when capacity is
    unbounded (no drops)."""
    D, FF, E, K, T = 8, 16, 4, 2, 24
    cfg = moe_mod.MoEConfig(n_experts=E, top_k=K, capacity_factor=100.0)
    rng = np.random.default_rng(0)
    params = {
        "router": jnp.asarray(rng.standard_normal((D, E)), jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((E, D, FF)) * 0.2, jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((E, D, FF)) * 0.2, jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((E, FF, D)) * 0.2, jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((1, T, D)), jnp.float32)
    got = moe_mod.moe_ffn(params, x, cfg)[0]

    # dense oracle
    logits = x[0] @ params["router"]
    w, idx = moe_mod.router_topk(logits, K)
    want = np.zeros((T, D), np.float32)
    for t in range(T):
        for j in range(K):
            e = int(idx[t, j])
            h = jax.nn.silu(x[0, t] @ params["w_gate"][e]) * (
                x[0, t] @ params["w_up"][e])
            want[t] += float(w[t, j]) * np.asarray(h @ params["w_down"][e])
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_tokens_not_crash():
    D, FF, E, K, T = 8, 16, 4, 2, 64
    cfg = moe_mod.MoEConfig(n_experts=E, top_k=K, capacity_factor=0.25)
    rng = np.random.default_rng(1)
    params = {
        "router": jnp.asarray(rng.standard_normal((D, E)), jnp.float32),
        "w_gate": jnp.ones((E, D, FF), jnp.float32) * 0.1,
        "w_up": jnp.ones((E, D, FF), jnp.float32) * 0.1,
        "w_down": jnp.ones((E, FF, D), jnp.float32) * 0.1,
    }
    x = jnp.asarray(rng.standard_normal((1, T, D)), jnp.float32)
    out = moe_mod.moe_ffn(params, x, cfg)
    assert bool(jnp.isfinite(out).all())
