# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Tests that need a multi-device mesh spawn a
# subprocess with XLA_FLAGS set (see test_distributed.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
