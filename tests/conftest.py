# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Tests that need a multi-device mesh spawn a
# subprocess with XLA_FLAGS set (see test_distributed.py).
import os

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="run under JAX runtime sanitizers: rank_promotion='raise', "
             "debug_nans, enable_checks (also enabled by REPRO_SANITIZE=1)")


def _sanitize_enabled(config) -> bool:
    return bool(config.getoption("--sanitize")
                or os.environ.get("REPRO_SANITIZE"))


def pytest_configure(config):
    if not _sanitize_enabled(config):
        return
    # Opt-in sanitizer mode (CI's sanitizer lane; locally: pytest
    # --sanitize or REPRO_SANITIZE=1).  Three classes of silent bug become
    # loud failures:
    #   rank_promotion="raise" — the implicit-broadcast bug class (a
    #     [n] vector meeting a [n, 1] column silently outer-products);
    #   debug_nans — NaNs surface at the op that made them, not as a
    #     diverged RMSE forty waves later;
    #   enable_checks — jax's internal invariant checks.
    import jax

    jax.config.update("jax_numpy_rank_promotion", "raise")
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_enable_checks", True)


def pytest_report_header(config):
    if _sanitize_enabled(config):
        return ("sanitize: ON (jax_numpy_rank_promotion=raise, "
                "jax_debug_nans, jax_enable_checks)")
    return None


@pytest.fixture
def rng():
    return np.random.default_rng(0)
