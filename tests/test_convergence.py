"""ALS convergence — paper Fig. 6 protocol on planted synthetic data.

The planted model has noise sigma=0.1, so test RMSE ~ 0.1 is the oracle
floor; the paper reports convergence within 5-20 ALS iterations."""
import numpy as np
import pytest

from repro.core import als as als_mod
from repro.core.objective import objective_j, rmse_padded
from repro.sparse import synth


@pytest.fixture(scope="module")
def problem():
    # netflix-like density: ~50 ratings/row >> f (the paper's regime —
    # Netflix averages ~200/user); a uniform rescale of Table 5 would
    # leave ~1 rating/row, which no factorization can recover.
    spec = synth.SynthSpec("netflix-mini", m=768, n=160, nnz=40_000,
                           f=8, lam=0.05)
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=2, noise=0.1)
    return spec, r, rt, rte


def test_als_converges(problem):
    spec, r_tr, r_tr_T, r_te = problem
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=8, mode="ref")
    state, hist = als_mod.als_train(
        als_mod.ell_triplet(r_tr), als_mod.ell_triplet(r_tr_T),
        r_tr.m, r_tr_T.m, cfg,
        test=als_mod.ell_triplet(r_te))
    rmses = [h["test_rmse"] for h in hist]
    assert rmses[-1] < 0.5 * rmses[0], rmses
    assert rmses[-1] < 0.35, rmses          # near the noise floor
    # monotone-ish: last iterate is the best or within 5%
    assert rmses[-1] <= min(rmses) * 1.05


def test_objective_decreases(problem):
    spec, r_tr, r_tr_T, _ = problem
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=4, mode="ref")
    r = als_mod.ell_triplet(r_tr)
    rt = als_mod.ell_triplet(r_tr_T)
    state = als_mod.als_init(r_tr.m, r_tr_T.m, cfg)
    js = []
    for _ in range(cfg.iters):
        state = als_mod.als_iteration(state, r, rt, cfg)
        js.append(float(objective_j(state.x, state.theta, r[0], r[1], r[2],
                                    rt[2], spec.lam)))
    # ALS is a (block) coordinate descent on J: must be non-increasing
    assert all(b <= a * (1 + 1e-5) for a, b in zip(js, js[1:])), js


def test_qbatched_equals_full(problem):
    """cuMF's q-batching (out-of-core waves) must not change the math."""
    spec, r_tr, r_tr_T, _ = problem
    r = als_mod.ell_triplet(r_tr)
    rt = als_mod.ell_triplet(r_tr_T)
    cfg_full = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1, mode="ref")
    cfg_batched = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1,
                                    mode="ref", batch_rows=128)
    s0 = als_mod.als_init(r_tr.m, r_tr_T.m, cfg_full)
    s1 = als_mod.als_iteration(s0, r, rt, cfg_full)
    s2 = als_mod.als_iteration(s0, r, rt, cfg_batched)
    np.testing.assert_allclose(s1.x, s2.x, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s1.theta, s2.theta, atol=2e-4, rtol=2e-4)


def test_kernel_path_converges_same(problem):
    """Pallas-kernel ALS (interpret) and oracle ALS converge identically."""
    spec, r_tr, r_tr_T, r_te = problem
    r = als_mod.ell_triplet(r_tr)
    rt = als_mod.ell_triplet(r_tr_T)
    kw = dict(f=spec.f, lam=spec.lam, iters=2)
    c_ref = als_mod.AlsConfig(mode="ref", **kw)
    c_kern = als_mod.AlsConfig(mode="kernel_interpret", tm=8, tk=8, tb=8,
                               f_mult=8, **kw)
    s0 = als_mod.als_init(r_tr.m, r_tr_T.m, c_ref)
    sr = als_mod.als_iteration(s0, r, rt, c_ref)
    sk = als_mod.als_iteration(s0, r, rt, c_kern)
    np.testing.assert_allclose(sr.x, sk.x, atol=3e-3, rtol=3e-3)
