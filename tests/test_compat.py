"""Unit tests for the JAX version-compat layer (repro.compat).

Every shim is exercised on BOTH branches: the one the installed JAX
actually takes, and the other one simulated by monkeypatching the
module-level attribute the shim resolves at call time.
"""
import enum
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


# ---------------------------------------------------------------------------
# make_mesh / AxisType
# ---------------------------------------------------------------------------

def test_make_mesh_builds_on_installed_jax():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == 1


def test_make_mesh_passes_axis_types_when_supported(monkeypatch):
    """Simulate a new JAX: AxisType exists and make_mesh accepts axis_types."""
    class FakeAxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"

    calls = {}

    def fake_make_mesh(axis_shapes, axis_names, *, devices=None,
                       axis_types=None):
        calls["args"] = (axis_shapes, axis_names, devices, axis_types)
        return "mesh"

    monkeypatch.setattr(compat, "AxisType", FakeAxisType)
    monkeypatch.setattr(compat, "_JAX_MAKE_MESH", fake_make_mesh)
    out = compat.make_mesh((2, 4), ("data", "model"), axis_types="auto")
    assert out == "mesh"
    assert calls["args"] == ((2, 4), ("data", "model"), None,
                             (FakeAxisType.Auto, FakeAxisType.Auto))


def test_make_mesh_drops_axis_types_when_absent(monkeypatch):
    """Simulate old JAX: no AxisType, make_mesh without the kwarg."""
    calls = {}

    def fake_make_mesh(axis_shapes, axis_names, *, devices=None):
        calls["args"] = (axis_shapes, axis_names, devices)
        return "mesh"

    monkeypatch.setattr(compat, "AxisType", None)
    monkeypatch.setattr(compat, "_JAX_MAKE_MESH", fake_make_mesh)
    out = compat.make_mesh((8,), ("data",), axis_types="auto")
    assert out == "mesh"
    assert calls["args"] == ((8,), ("data",), None)


def test_make_mesh_rejects_bogus_axis_types():
    """Validation must not depend on which JAX branch is installed."""
    with pytest.raises(ValueError, match="axis_types"):
        compat.make_mesh((1,), ("data",), axis_types="bogus")


def test_make_mesh_mesh_utils_fallback(monkeypatch):
    """Pre-jax.make_mesh branch: plain Mesh over a device grid."""
    monkeypatch.setattr(compat, "_JAX_MAKE_MESH", None)
    mesh = compat.make_mesh((1,), ("data",))
    assert isinstance(mesh, jax.sharding.Mesh)
    assert mesh.axis_names == ("data",)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def test_shard_map_runs_on_installed_jax():
    mesh = compat.make_mesh((1,), ("data",))
    x = jnp.arange(4.0)
    out = compat.shard_map(
        lambda v: v * 2, mesh=mesh,
        in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(),
        check_vma=False)(x)
    np.testing.assert_allclose(out, np.arange(4.0) * 2)


def test_shard_map_new_api_translation(monkeypatch):
    """axis_names/check_vma pass straight through to a new-style jax.shard_map."""
    calls = {}

    def fake_new(f, *, mesh, in_specs, out_specs, check_vma, axis_names=None):
        calls["kw"] = dict(mesh=mesh, check_vma=check_vma,
                           axis_names=axis_names)
        return f

    monkeypatch.setattr(compat, "_NEW_SHARD_MAP", fake_new)
    fn = lambda v: v
    out = compat.shard_map(fn, mesh="m", in_specs=0, out_specs=0,
                           axis_names={"model"}, check_vma=False)
    assert out is fn
    assert calls["kw"] == {"mesh": "m", "check_vma": False,
                           "axis_names": {"model"}}
    # axis_names=None must omit the kwarg (new API default = all manual)
    compat.shard_map(fn, mesh="m", in_specs=0, out_specs=0, check_vma=True)
    assert calls["kw"]["axis_names"] is None
    assert calls["kw"]["check_vma"] is True


def test_shard_map_legacy_translation(monkeypatch):
    """axis_names (manual) inverts to auto=, check_vma maps to check_rep=."""
    calls = {}

    def fake_legacy(f, *, mesh, in_specs, out_specs, check_rep, auto):
        calls["kw"] = dict(check_rep=check_rep, auto=auto)
        return f

    mesh = types.SimpleNamespace(axis_names=("pod", "data", "model"))
    monkeypatch.setattr(compat, "_NEW_SHARD_MAP", None)
    monkeypatch.setattr(compat, "_LEGACY_SHARD_MAP", fake_legacy)
    compat.shard_map(lambda v: v, mesh=mesh, in_specs=0, out_specs=0,
                     axis_names={"model"}, check_vma=False)
    assert calls["kw"] == {"check_rep": False,
                           "auto": frozenset({"pod", "data"})}
    # fully-manual default: auto is empty
    compat.shard_map(lambda v: v, mesh=mesh, in_specs=0, out_specs=0,
                     check_vma=False)
    assert calls["kw"]["auto"] == frozenset()


# ---------------------------------------------------------------------------
# pallas compiler params / pallas_call
# ---------------------------------------------------------------------------

def test_tpu_compiler_params_resolves_installed_name():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    if compat.has_pallas_tpu():
        assert params is not None
        assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")
    else:
        assert params is None


def test_tpu_compiler_params_new_name(monkeypatch):
    class FakeParams:
        def __init__(self, dimension_semantics=None):
            self.dimension_semantics = dimension_semantics

    fake = types.SimpleNamespace(CompilerParams=FakeParams)
    monkeypatch.setattr(compat, "_pltpu", fake)
    p = compat.tpu_compiler_params(dimension_semantics=("parallel",),
                                   bogus_future_kwarg=1)
    assert isinstance(p, FakeParams)
    assert p.dimension_semantics == ("parallel",)


def test_tpu_compiler_params_old_name(monkeypatch):
    class FakeTPUParams:
        def __init__(self, dimension_semantics=None):
            self.dimension_semantics = dimension_semantics

    fake = types.SimpleNamespace(TPUCompilerParams=FakeTPUParams)
    monkeypatch.setattr(compat, "_pltpu", fake)
    p = compat.tpu_compiler_params(dimension_semantics=("arbitrary",))
    assert isinstance(p, FakeTPUParams)


def test_tpu_compiler_params_no_backend(monkeypatch):
    monkeypatch.setattr(compat, "_pltpu", None)
    assert compat.tpu_compiler_params(dimension_semantics=()) is None


def test_pallas_call_degrades_to_interpret_off_tpu(monkeypatch):
    calls = {}

    def fake_pallas_call(kernel, *, interpret, **kwargs):
        calls["interpret"] = interpret
        return kernel

    monkeypatch.setattr(compat, "_PALLAS_CALL", fake_pallas_call)
    monkeypatch.setattr(compat, "_backend", lambda: "cpu")
    compat.pallas_call(lambda: None, out_shape=None)
    assert calls["interpret"] is True


def test_pallas_call_compiles_on_tpu(monkeypatch):
    calls = {}

    def fake_pallas_call(kernel, *, interpret, **kwargs):
        calls["interpret"] = interpret
        return kernel

    monkeypatch.setattr(compat, "_PALLAS_CALL", fake_pallas_call)
    monkeypatch.setattr(compat, "_backend", lambda: "tpu")
    compat.pallas_call(lambda: None, out_shape=None)
    assert calls["interpret"] is False
    # explicit interpret=True is preserved even on TPU
    compat.pallas_call(lambda: None, out_shape=None, interpret=True)
    assert calls["interpret"] is True


def test_vmem_degrades_without_pltpu(monkeypatch):
    """No TPU pallas backend -> a generic interpret-capable scratch ref."""
    monkeypatch.setattr(compat, "_pltpu", None)
    from jax.experimental import pallas as pl

    ref = compat.vmem((8,), jnp.float32)

    def k(x_ref, o_ref, s):
        s[...] = x_ref[...]
        o_ref[...] = s[...] * 2

    out = pl.pallas_call(
        k, out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
        scratch_shapes=[ref], interpret=True)(jnp.arange(8.0))
    np.testing.assert_allclose(out, np.arange(8.0) * 2)


def test_jax_version_tuple():
    v = compat.jax_version()
    assert len(v) == 3 and all(isinstance(p, int) for p in v)
    assert v >= (0, 4, 37), "supported JAX floor is 0.4.37"
