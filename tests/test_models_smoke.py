"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm as lm_mod
from repro.models import transformer as T
from repro.training.optimizer import OptConfig

ARCHS = registry.list_archs()


def _batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.frontend in ("audio_stub", "vision_stub"):
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.float32)}
    else:
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    b["mask"] = jnp.ones((B, S), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = registry.smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, _ = T.forward(cfg, params, batch, mode="train", remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.smoke_config(arch)
    state = lm_mod.init_train_state(cfg, jax.random.PRNGKey(0),
                                    OptConfig(lr=1e-3))
    step = jax.jit(lm_mod.make_train_step(cfg, OptConfig(lr=1e-3),
                                          remat=False))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state.params),
        jax.tree.leaves(T.init_params(cfg, jax.random.PRNGKey(0)))))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_consistent_with_forward(arch):
    """Greedy decode over a prefix must match the argmax of a full forward
    at the same position — validates KV caches / recurrent states."""
    cfg = registry.smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = _batch(cfg, B, S, seed=3)
    inputs = {k: v for k, v in batch.items() if k in ("tokens", "embeds")}

    full_logits, _ = T.forward(cfg, params, inputs, mode="train", remat=False)
    want = np.asarray(jnp.argmax(full_logits[:, -1], axis=-1))

    prefill = lm_mod.make_prefill_step(cfg, max_seq=S + 4)
    tok, cache = prefill(params, jax.tree.map(lambda x: x[:, :S], inputs))
    np.testing.assert_array_equal(np.asarray(tok), want)

    # now decode one token starting from a shorter prefix and compare
    short = jax.tree.map(lambda x: x[:, :S - 1], inputs)
    _, cache2 = prefill(params, short)
    decode = lm_mod.make_decode_step(cfg)
    last = (inputs["tokens"][:, S - 1] if "tokens" in inputs
            else inputs["embeds"][:, S - 1])
    tok2, _, _ = decode(params, cache2, last,
                        jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(tok2), want)


def test_param_counts_match_reference():
    """Analytic parameter counts are near the published model sizes."""
    expect = {
        "mistral-large-123b": (110e9, 130e9),
        "internvl2-26b": (17e9, 26e9),      # LLM backbone only (~19.9B)
        "rwkv6-7b": (6e9, 8.5e9),
        "qwen3-4b": (3.4e9, 4.6e9),
        "phi3-mini-3.8b": (3.2e9, 4.2e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "recurrentgemma-2b": (2e9, 3.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get_arch(arch).model.params_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_smaller():
    m = registry.get_arch("olmoe-1b-7b").model
    assert m.active_params_count() < 0.35 * m.params_count()
