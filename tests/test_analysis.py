"""reprolint unit tests: one flagged + one clean snippet per rule, plus
suppressions, baseline round-trip, and the CLI's --rule validation.

Each snippet is a synthetic violation of exactly the invariant the rule
guards (the CI lint job's fail-on-new behavior is demonstrated here: the
flagged corpus produces new findings, the clean corpus produces none).
The analyzer's verdict on the *real* repo is covered at the end — the
tree must be clean at merge.
"""
import json
import pathlib
import textwrap

import pytest

from repro.analysis.engine import (AnalysisConfig, Baseline, Finding,
                                   run_analysis)
from repro.analysis.rules import ALL_RULES, get_rules, rule_names
from repro.analysis.rules.bin_shape import BinShapeRule
from repro.analysis.rules.checkpoint_aliasing import CheckpointAliasingRule
from repro.analysis.rules.compat_routing import CompatRoutingRule
from repro.analysis.rules.obs_routing import ObsRoutingRule
from repro.analysis.rules.pallas_budget import PallasBudgetRule
from repro.analysis.rules.precision_drift import PrecisionDriftRule
from repro.analysis.rules.shard_safety import ShardSafetyRule
from repro.analysis.__main__ import main as cli_main

REPO = pathlib.Path(__file__).resolve().parent.parent

AXES = frozenset({"data", "model", "pod"})


def run_rule(tmp_path, rule, source, rel="src/mod.py"):
    """Write ``source`` at ``tmp_path/rel`` and run one rule over it."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    cfg = AnalysisConfig(root=tmp_path, rules=[rule], paths=[path])
    new, _ = run_analysis(cfg)
    return new


# ---------------------------------------------------------------------------
# compat-routing
# ---------------------------------------------------------------------------

class TestCompatRouting:
    def test_flags_direct_shard_map_import(self, tmp_path):
        found = run_rule(tmp_path, CompatRoutingRule(), """
            from jax.experimental.shard_map import shard_map
        """)
        assert len(found) >= 1
        assert all(f.rule == "compat-routing" for f in found)

    def test_flags_banned_names_and_interpret(self, tmp_path):
        found = run_rule(tmp_path, CompatRoutingRule(), """
            import jax
            from jax.experimental import pallas as pl

            def bad(mesh):
                params = jax.sharding.AxisType
                return pl.pallas_call(lambda r: r, interpret=True)
        """)
        msgs = "\n".join(f.message for f in found)
        assert "AxisType" in msgs
        assert "pl.pallas_call" in msgs
        assert "interpret=" in msgs

    def test_flags_check_rep_vocabulary(self, tmp_path):
        found = run_rule(tmp_path, CompatRoutingRule(), """
            from repro import compat

            def f(mesh, g):
                return compat.shard_map(g, mesh=mesh, check_rep=False)
        """)
        assert any("check_rep" in f.message for f in found)

    def test_clean_compat_spelling_passes(self, tmp_path):
        found = run_rule(tmp_path, CompatRoutingRule(), """
            from repro import compat

            def good(mesh, g, x):
                return compat.shard_map(g, mesh=mesh)(x)

            def kernel(x):
                return compat.pallas_call(lambda r, o: None)(x)
        """)
        assert found == []

    def test_shim_itself_is_excluded(self, tmp_path):
        found = run_rule(tmp_path, CompatRoutingRule(), """
            from jax.experimental.shard_map import shard_map
        """, rel="src/repro/compat.py")
        assert found == []


# ---------------------------------------------------------------------------
# obs-routing (ISSUE 7 satellite): bare clocks in src/repro/ outside obs/
# ---------------------------------------------------------------------------

class TestObsRouting:
    def test_flags_bare_perf_counter_and_time(self, tmp_path):
        found = run_rule(tmp_path, ObsRoutingRule(), """
            import time

            def slow_path():
                t0 = time.perf_counter()
                t1 = time.time()
                return t1 - t0
        """, rel="src/repro/outofcore/driver.py")
        assert len(found) == 2
        assert all(f.rule == "obs-routing" for f in found)
        msgs = "\n".join(f.message for f in found)
        assert "time.perf_counter" in msgs and "time.time" in msgs

    def test_flags_from_import_and_aliases(self, tmp_path):
        found = run_rule(tmp_path, ObsRoutingRule(), """
            import time as clock
            from time import perf_counter as pc

            def f():
                return clock.monotonic() + pc()
        """, rel="src/repro/sgd/train.py")
        assert len(found) == 2

    def test_obs_layer_itself_is_excluded(self, tmp_path):
        found = run_rule(tmp_path, ObsRoutingRule(), """
            import time

            def now():
                return time.perf_counter()
        """, rel="src/repro/obs/trace.py")
        assert found == []

    def test_phase_spelling_is_clean(self, tmp_path):
        found = run_rule(tmp_path, ObsRoutingRule(), """
            from repro.obs.trace import phase

            def wave(reg, tracer):
                with phase("als.wave_x", cat="solve", tracer=tracer,
                           registry=reg):
                    pass
            # non-clock time attrs don't trip the rule
            def fmt(t):
                import time
                return time.strftime("%H:%M", t)
        """, rel="src/repro/outofcore/driver.py")
        assert found == []

    def test_suppression_comment_works(self, tmp_path):
        found = run_rule(tmp_path, ObsRoutingRule(), """
            import time

            def probe():
                return time.time()  # reprolint: disable=obs-routing
        """, rel="src/repro/launch/dryrun.py")
        assert found == []


# ---------------------------------------------------------------------------
# bin-shape
# ---------------------------------------------------------------------------

class TestBinShape:
    def test_flags_grid_wide_k_in_bin_loop(self, tmp_path):
        found = run_rule(tmp_path, BinShapeRule(), """
            def solve_all(fixed, binned, ell, kern):
                for b, rows in zip(binned.bins, binned.rows):
                    kern(fixed, ell.idx[:, :ell.K], b.cnt)
        """)
        assert len(found) == 1 and found[0].rule == "bin-shape"
        assert "ell.K" in found[0].message

    def test_flags_in_comprehension_and_k_groups_loop(self, tmp_path):
        found = run_rule(tmp_path, BinShapeRule(), """
            def sizes(binned, ell):
                return [b.m * ell.K for b in binned.bins]

            def sweep(grid, idx, kern):
                for k_t, ii, jj in _set_k_groups(grid, 0):
                    kern(idx[ii, jj, :, :grid.K])
        """)
        assert len(found) == 2
        assert {"ell.K" in f.message or "grid.K" in f.message
                for f in found} == {True}

    def test_per_bin_k_is_clean(self, tmp_path):
        found = run_rule(tmp_path, BinShapeRule(), """
            def solve_all(fixed, binned, kern):
                out = []
                for b, rows in zip(binned.bins, binned.rows):
                    kb = b.K
                    out.append(kern(fixed, b.idx[:, :kb], b.cnt))
                return out, sum((hi - lo) * b.K for b, (lo, hi)
                                in zip(binned.bins, binned.spans))

            def uniform(ell, kern):
                return kern(ell.idx[:, :ell.K])   # no bin in scope: fine
        """)
        assert found == []

    def test_suppression_comment_works(self, tmp_path):
        found = run_rule(tmp_path, BinShapeRule(), """
            def audit(binned, ell):
                for b in binned.bins:
                    assert b.K <= ell.K  # reprolint: disable=bin-shape
        """)
        assert found == []


# ---------------------------------------------------------------------------
# pallas-budget
# ---------------------------------------------------------------------------

def _budget_rule(limit, bounds):
    from repro.kernels.budgets import KernelBudget
    return PallasBudgetRule(
        budgets={"my_kernel": KernelBudget(vmem_limit=limit,
                                           dim_bounds=dict(bounds))})


KERNEL_SRC = """
    from repro import compat

    def my_kernel(x, tm):
        return compat.pallas_call(
            lambda xr, orf: None,
            in_specs=[compat.BlockSpec((tm, 128), lambda i: (i, 0))],
            out_specs=compat.BlockSpec((8, 128), lambda i: (i, 0)),
            scratch_shapes=[compat.vmem((8, 128), jnp.float32)],
        )(x)
"""


class TestPallasBudget:
    def test_flags_over_budget_footprint(self, tmp_path):
        # 2*(8*128*4 + 8*128*4) + 8*128*4 = 20480 B > 100 B limit
        found = run_rule(tmp_path, _budget_rule(100, {"tm": 8}), KERNEL_SRC)
        assert len(found) == 1
        assert "20480 B" in found[0].message
        assert "exceeds" in found[0].message

    def test_clean_within_budget(self, tmp_path):
        found = run_rule(tmp_path, _budget_rule(1 << 20, {"tm": 8}),
                         KERNEL_SRC)
        assert found == []

    def test_flags_missing_budget_entry(self, tmp_path):
        found = run_rule(tmp_path, _budget_rule(1 << 20, {"tm": 8}), """
            from repro import compat

            def unregistered_kernel(x):
                return compat.pallas_call(lambda r, o: None)(x)
        """)
        assert len(found) == 1
        assert "no declared budget" in found[0].message

    def test_flags_undeclared_symbolic_dim(self, tmp_path):
        # tm has no bound in the entry -> unbounded dim is a finding
        found = run_rule(tmp_path, _budget_rule(1 << 20, {}), KERNEL_SRC)
        assert any("no declared bound" in f.message for f in found)

    def test_real_kernels_fit_their_declared_budgets(self):
        cfg = AnalysisConfig(root=REPO, rules=[PallasBudgetRule()])
        new, _ = run_analysis(cfg)
        assert new == [], "\n".join(f.format() for f in new)


# ---------------------------------------------------------------------------
# precision-drift
# ---------------------------------------------------------------------------

class TestPrecisionDrift:
    def test_flags_narrow_accumulator(self, tmp_path):
        found = run_rule(tmp_path, PrecisionDriftRule(), """
            import numpy as np
            from repro.distributed.reduce import topology_reduce

            def wave(parts, plan):
                acc = np.zeros((4, 4), dtype=np.float32)
                return topology_reduce(acc, plan)
        """)
        assert len(found) == 1
        assert "float64" in found[0].message

    def test_flags_through_one_call_level(self, tmp_path):
        # the driver._reduce_and_solve shape: caller allocates, callee
        # reduces
        found = run_rule(tmp_path, PrecisionDriftRule(), """
            import numpy as np
            from repro.distributed.reduce import topology_reduce

            def _reduce_and_solve(A_dev, plan):
                return topology_reduce(A_dev, plan)

            def driver(plan):
                A_dev = np.zeros((4,), dtype=np.float32)
                return _reduce_and_solve(A_dev, plan)
        """)
        assert len(found) == 1

    def test_flags_astype_narrowing(self, tmp_path):
        found = run_rule(tmp_path, PrecisionDriftRule(), """
            import numpy as np
            from repro.distributed.reduce import topology_reduce

            def wave(acc, plan):
                acc.astype(np.float32)
                return topology_reduce(acc, plan)
        """)
        assert len(found) == 1
        assert "astype" in found[0].message

    def test_clean_f64_accumulator_and_downstream_cast(self, tmp_path):
        # casting the *result* after the reduce is deliberately fine
        found = run_rule(tmp_path, PrecisionDriftRule(), """
            import numpy as np
            from repro.distributed.reduce import topology_reduce

            def wave(parts, plan):
                acc = np.zeros((4, 4), dtype=np.float64)
                acc += parts[0]
                total = topology_reduce(acc, plan)
                return total.astype(np.float32)
        """)
        assert found == []


# ---------------------------------------------------------------------------
# shard-safety
# ---------------------------------------------------------------------------

class TestShardSafety:
    def test_flags_unknown_axis_in_specs(self, tmp_path):
        found = run_rule(tmp_path, ShardSafetyRule(axes=AXES), """
            from repro import compat
            from jax.sharding import PartitionSpec as P

            def f(mesh, x):
                def inner(a):
                    return a
                return compat.shard_map(
                    inner, mesh=mesh,
                    in_specs=(P("modle"),), out_specs=P("data"))(x)
        """)
        assert len(found) == 1
        assert "'modle'" in found[0].message

    def test_flags_unknown_collective_axis(self, tmp_path):
        found = run_rule(tmp_path, ShardSafetyRule(axes=AXES), """
            from jax import lax

            def inner(a):
                return lax.psum(a, "podd")
        """)
        assert len(found) == 1
        assert "'podd'" in found[0].message

    def test_flags_in_specs_arity_mismatch(self, tmp_path):
        found = run_rule(tmp_path, ShardSafetyRule(axes=AXES), """
            from repro import compat
            from jax.sharding import PartitionSpec as P

            def f(mesh, x, y):
                def inner(a, b):
                    return a + b
                return compat.shard_map(
                    inner, mesh=mesh,
                    in_specs=(P("data"), P("data"), P("data")),
                    out_specs=P("data"))(x, y)
        """)
        assert len(found) == 1
        assert "3 entries" in found[0].message
        assert "takes 2" in found[0].message

    def test_flags_out_specs_arity_mismatch(self, tmp_path):
        found = run_rule(tmp_path, ShardSafetyRule(axes=AXES), """
            from repro import compat
            from jax.sharding import PartitionSpec as P

            def f(mesh, x):
                def inner(a):
                    return a, a
                return compat.shard_map(
                    inner, mesh=mesh, in_specs=(P("data"),),
                    out_specs=(P("data"), P("data"), P(None)))(x)
        """)
        assert len(found) == 1
        assert "out_specs" in found[0].message

    def test_clean_declared_axes_and_matching_arity(self, tmp_path):
        found = run_rule(tmp_path, ShardSafetyRule(axes=AXES), """
            from repro import compat
            from jax import lax
            from jax.sharding import PartitionSpec as P

            def f(mesh, x, y):
                def inner(a, b):
                    return a + lax.psum(b, "model"), b

                return compat.shard_map(
                    inner, mesh=mesh,
                    in_specs=(P("data"), P(None)),
                    out_specs=(P("data"), P(None)))(x, y)
        """)
        assert found == []

    def test_vocabulary_parsed_from_real_mesh_builders(self):
        from repro.analysis.rules.shard_safety import axes_from_mesh_builder
        axes = axes_from_mesh_builder(REPO / "src/repro/launch/mesh.py")
        assert {"data", "model"} <= axes


# ---------------------------------------------------------------------------
# checkpoint-aliasing
# ---------------------------------------------------------------------------

class TestCheckpointAliasing:
    def test_flags_asarray_on_commit_path(self, tmp_path):
        # the PR 5 race: asarray(acc) with matching dtype returns the
        # live accumulator itself
        found = run_rule(tmp_path, CheckpointAliasingRule(), """
            import numpy as np
            from repro.checkpoint.manager import CheckpointManager

            def save(ckpt_dir, step, acc):
                mgr = CheckpointManager(ckpt_dir)
                tree = {"a_acc": np.asarray(acc, np.float64)}
                mgr.save(step, tree)
        """)
        assert len(found) == 1
        assert "asarray" in found[0].message

    def test_flags_live_attribute_and_view(self, tmp_path):
        found = run_rule(tmp_path, CheckpointAliasingRule(), """
            from repro.checkpoint.manager import CheckpointManager

            def save(ckpt_dir, step, state, buf):
                mgr = CheckpointManager(ckpt_dir)
                mgr.save(step, {"x": state.x, "rows": buf[2:]})
        """)
        assert len(found) == 2
        msgs = "\n".join(f.message for f in found)
        assert "live array reference" in msgs
        assert "view" in msgs

    def test_flags_mutation_of_returned_tree(self, tmp_path):
        # the WaveCheckpointer thunk protocol: tree[...] = np.asarray(...)
        found = run_rule(tmp_path, CheckpointAliasingRule(), """
            import numpy as np
            from repro.outofcore.runtime import WaveCheckpointer

            def run(ckpt_dir, step, acc):
                ck = WaveCheckpointer(ckpt_dir)

                def tree_fn():
                    tree = {}
                    tree["a_acc"] = np.asarray(acc, np.float64)
                    return tree

                ck.save(step, tree_fn)
        """)
        assert len(found) == 1
        assert "asarray" in found[0].message

    def test_clean_materialized_copies(self, tmp_path):
        found = run_rule(tmp_path, CheckpointAliasingRule(), """
            import numpy as np
            from repro.checkpoint.manager import CheckpointManager

            def save(ckpt_dir, step, x, acc):
                mgr = CheckpointManager(ckpt_dir)
                tree = {"x": x.copy(),
                        "a_acc": np.array(acc, np.float64),
                        "step": step}
                mgr.save(step, tree)
        """)
        assert found == []


# ---------------------------------------------------------------------------
# engine: suppressions, baseline, parse errors
# ---------------------------------------------------------------------------

class TestEngine:
    BAD = """
        from jax.experimental.shard_map import shard_map
    """

    def test_suppression_comment_silences_the_rule(self, tmp_path):
        src = ("from jax.experimental.shard_map import shard_map"
               "  # reprolint: disable=compat-routing\n")
        found = run_rule(tmp_path, CompatRoutingRule(), src)
        assert found == []

    def test_suppression_disable_all(self, tmp_path):
        src = ("from jax.experimental.shard_map import shard_map"
               "  # reprolint: disable=all\n")
        found = run_rule(tmp_path, CompatRoutingRule(), src)
        assert found == []

    def test_suppressing_a_different_rule_does_not_silence(self, tmp_path):
        src = ("from jax.experimental.shard_map import shard_map"
               "  # reprolint: disable=pallas-budget\n")
        found = run_rule(tmp_path, CompatRoutingRule(), src)
        assert len(found) >= 1

    def test_baseline_round_trip(self, tmp_path):
        path = tmp_path / "src/mod.py"
        path.parent.mkdir(parents=True)
        path.write_text(textwrap.dedent(self.BAD))
        cfg = AnalysisConfig(root=tmp_path, rules=[CompatRoutingRule()],
                             paths=[path])
        first, _ = run_analysis(cfg)
        assert first

        bl_path = tmp_path / "baseline.json"
        Baseline.write(bl_path, first)
        baseline = Baseline.load(bl_path)

        cfg = AnalysisConfig(root=tmp_path, rules=[CompatRoutingRule()],
                             baseline=baseline, paths=[path])
        new, grandfathered = run_analysis(cfg)
        assert new == []
        assert [f.fingerprint for f in grandfathered] == \
            [f.fingerprint for f in first]

    def test_baseline_survives_line_number_churn(self, tmp_path):
        path = tmp_path / "src/mod.py"
        path.parent.mkdir(parents=True)
        path.write_text(textwrap.dedent(self.BAD))
        cfg = AnalysisConfig(root=tmp_path, rules=[CompatRoutingRule()],
                             paths=[path])
        first, _ = run_analysis(cfg)
        bl_path = tmp_path / "baseline.json"
        Baseline.write(bl_path, first)

        # push the offending line down: identity is the snippet, not the
        # line number
        path.write_text("# a new comment\n\n" + textwrap.dedent(self.BAD))
        cfg = AnalysisConfig(root=tmp_path, rules=[CompatRoutingRule()],
                             baseline=Baseline.load(bl_path), paths=[path])
        new, grandfathered = run_analysis(cfg)
        assert new == []
        assert len(grandfathered) == len(first)

    def test_baseline_rejects_empty_justification(self, tmp_path):
        bl_path = tmp_path / "baseline.json"
        bl_path.write_text(json.dumps({"findings": [
            {"rule": "compat-routing", "path": "src/mod.py",
             "snippet": "x = 1", "justification": ""}]}))
        with pytest.raises(ValueError, match="justification"):
            Baseline.load(bl_path)

    def test_baseline_write_preserves_old_justifications(self, tmp_path):
        f = Finding(rule="compat-routing", path="src/mod.py", line=1,
                    col=0, message="m", snippet="bad line")
        bl_path = tmp_path / "baseline.json"
        old = Baseline(entries={f.fingerprint: "known debt, see PR 3"})
        Baseline.write(bl_path, [f], old=old)
        data = json.loads(bl_path.read_text())
        assert data["findings"][0]["justification"] == "known debt, see PR 3"

    def test_parse_error_becomes_a_finding(self, tmp_path):
        found = run_rule(tmp_path, CompatRoutingRule(),
                         "def broken(:\n    pass\n")
        assert len(found) == 1
        assert found[0].rule == "parse-error"


# ---------------------------------------------------------------------------
# CLI: --rule validation mirrors benchmarks/run.py --only
# ---------------------------------------------------------------------------

class TestCLI:
    def test_rule_catalog_is_complete(self):
        assert sorted(rule_names()) == ["bin-shape", "checkpoint-aliasing",
                                        "compat-routing", "obs-routing",
                                        "pallas-budget", "precision-drift",
                                        "shard-safety"]
        assert len(ALL_RULES) == 7

    def test_get_rules_unknown_name_fails_loudly(self):
        with pytest.raises(ValueError, match="unknown rule name"):
            get_rules(["compat-routing", "nope"])

    def test_cli_unknown_rule_exits_2(self, capsys):
        with pytest.raises(SystemExit) as exc:
            cli_main(["--rule", "nope"])
        assert exc.value.code == 2
        assert "unknown rule name" in capsys.readouterr().err

    def test_cli_list_rules(self, capsys):
        assert cli_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for name in rule_names():
            assert name in out

    def test_cli_fails_on_seeded_violation_and_emits_json(self, tmp_path,
                                                          capsys):
        bad = tmp_path / "src" / "mod.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("from jax.experimental.shard_map import shard_map\n")
        (tmp_path / "pyproject.toml").write_text("[project]\n")
        out_json = tmp_path / "findings.json"
        rc = cli_main([str(bad), "--root", str(tmp_path),
                       "--rule", "compat-routing", "--json", str(out_json)])
        assert rc == 1
        payload = json.loads(out_json.read_text())
        assert payload["rules"] == ["compat-routing"]
        assert len(payload["new"]) >= 1
        assert payload["new"][0]["path"] == "src/mod.py"

    def test_cli_repo_is_clean_at_merge(self):
        # the acceptance criterion: python -m repro.analysis exits 0
        assert cli_main(["--root", str(REPO)]) == 0


# ---------------------------------------------------------------------------
# budgets: the declared contract agrees with the mesh model
# ---------------------------------------------------------------------------

class TestBudgets:
    def test_vmem_mirror_matches_launch_mesh(self):
        from repro.kernels import budgets
        from repro.launch import mesh
        assert budgets.VMEM_BYTES == mesh.VMEM_BYTES

    def test_every_budget_fits_the_chip(self):
        from repro.kernels.budgets import BUDGETS, VMEM_BYTES
        for name, b in BUDGETS.items():
            assert 0 < b.vmem_limit <= VMEM_BYTES, name
            assert b.dim_bounds, name
            assert b.note, name
