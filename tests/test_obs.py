"""Observability layer tests (ISSUE 7): tracer semantics, export schema,
metrics, and the streaming-driver span contract.

The layer's two promises are covered head-on: (a) the exported file is a
valid Chrome-trace object Perfetto loads — required keys, non-negative
microsecond times, per-thread *nested* spans, named thread tracks; (b)
with no tracer installed the instrumentation is a no-op — the default
``current_tracer()`` hands out one shared constant span and records
nothing.  The streaming regression at the end pins the cross-layer
contract the docs advertise: one ``solve`` span per consumed wave, so
``count(cat="solve") == StreamTelemetry.waves_run``.
"""
import json
import threading

import pytest

from repro.obs import (DEFAULT_LATENCY_BUCKETS, Histogram, MetricsRegistry,
                       NOOP_SPAN, Tracer, chrome_trace, current_tracer,
                       load_and_validate, set_tracer, span_counts,
                       validate_chrome_trace, write_trace)
from repro.obs.trace import NULL_TRACER, phase


@pytest.fixture(autouse=True)
def _restore_process_tracer():
    """Never leak a test's tracer into the rest of the suite."""
    prev = current_tracer()
    yield
    set_tracer(prev)


# ---------------------------------------------------------------------------
# tracer semantics
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_records_category_args_and_duration(self):
        tr = Tracer()
        with tr.span("work", cat="solve", wave=3):
            pass
        (ev,) = tr.events
        assert ev.name == "work" and ev.cat == "solve"
        assert ev.args == {"wave": 3}
        assert ev.ts >= 0 and ev.dur >= 0

    def test_nested_spans_nest_in_time(self):
        tr = Tracer()
        with tr.span("outer", cat="half"):
            with tr.span("inner", cat="solve"):
                pass
        inner, outer = tr.spans()   # recorded at exit: inner closes first
        assert inner.name == "inner" and outer.name == "outer"
        assert outer.ts <= inner.ts
        assert inner.ts + inner.dur <= outer.ts + outer.dur + 1e-6

    def test_spans_filter_by_category(self):
        tr = Tracer()
        with tr.span("a", cat="solve"):
            pass
        with tr.span("b", cat="reduce"):
            pass
        assert [e.name for e in tr.spans(cat="solve")] == ["a"]

    def test_thread_names_are_captured(self):
        tr = Tracer()

        def work():
            with tr.span("w", cat="prefetch_load"):
                pass

        t = threading.Thread(target=work, name="prefetch-worker")
        t.start()
        t.join()
        with tr.span("m", cat="solve"):
            pass
        assert "prefetch-worker" in tr.thread_names.values()
        tids = {e.tid for e in tr.events}
        assert len(tids) == 2

    def test_disabled_tracer_is_shared_noop(self):
        # the default process tracer records nothing and allocates nothing:
        # every span() call returns the one module-level constant
        assert current_tracer() is NULL_TRACER
        s1 = NULL_TRACER.span("x", cat="solve", big_arg=list(range(100)))
        s2 = NULL_TRACER.span("y", cat="reduce")
        assert s1 is s2 is NOOP_SPAN
        with s1:
            pass
        assert NULL_TRACER.spans() == []

    def test_set_tracer_installs_and_returns_previous(self):
        tr = Tracer()
        prev = set_tracer(tr)
        assert prev is NULL_TRACER
        assert current_tracer() is tr
        assert set_tracer(None) is tr           # None -> back to null
        assert current_tracer() is NULL_TRACER


class TestPhase:
    def test_phase_feeds_registry_and_tracer(self):
        tr, reg = Tracer(), MetricsRegistry()
        with phase("als.wave_x", cat="solve", tracer=tr, registry=reg,
                   wave=0):
            pass
        assert len(tr.spans(cat="solve")) == 1
        assert reg.counter("phase_seconds/solve").value > 0
        assert reg.histogram("solve_seconds").count == 1

    def test_phase_with_null_tracer_still_meters(self):
        reg = MetricsRegistry()
        with phase("x", cat="half", tracer=NULL_TRACER, registry=reg):
            pass
        assert reg.phase_seconds().keys() == {"half"}

    def test_phase_propagates_exceptions_but_records(self):
        tr, reg = Tracer(), MetricsRegistry()
        with pytest.raises(RuntimeError):
            with phase("boom", cat="solve", tracer=tr, registry=reg):
                raise RuntimeError("boom")
        assert len(tr.spans(cat="solve")) == 1
        assert reg.histogram("solve_seconds").count == 1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("waves_run").inc()
        reg.counter("waves_run").inc(2)
        reg.gauge("peak_bytes").set(10)
        reg.gauge("peak_bytes").set(4)
        assert reg.counter("waves_run").value == 3
        assert reg.gauge("peak_bytes").value == 4
        assert reg.gauge("peak_bytes").max == 10

    def test_histogram_bucket_edges_are_le_inclusive(self):
        h = Histogram(edges=(0.1, 1.0, 10.0))
        # exactly on an edge lands in that edge's bucket (le semantics)
        for v in (0.05, 0.1):
            h.observe(v)
        h.observe(1.0)
        h.observe(5.0)
        h.observe(100.0)        # beyond the last edge -> overflow bucket
        assert h.counts == [2, 1, 1, 1]
        assert h.count == 5
        assert h.mean == pytest.approx((0.05 + 0.1 + 1.0 + 5.0 + 100.0) / 5)

    def test_histogram_rejects_unsorted_edges(self):
        with pytest.raises(AssertionError):
            Histogram(edges=(1.0, 0.5))
        with pytest.raises(AssertionError):
            Histogram(edges=(1.0, 1.0))

    def test_default_buckets_cover_smoke_and_scale(self):
        assert DEFAULT_LATENCY_BUCKETS[0] <= 0.001
        assert DEFAULT_LATENCY_BUCKETS[-1] >= 100.0

    def test_snapshot_is_json_ready(self):
        reg = MetricsRegistry()
        reg.counter("bytes_streamed").inc(42)
        reg.gauge("peak_bytes").set(7)
        reg.histogram("solve_seconds").observe(0.5)
        snap = json.loads(json.dumps(reg.snapshot()))
        assert snap["counters"]["bytes_streamed"] == 42
        assert snap["gauges"]["peak_bytes"]["value"] == 7
        assert snap["histograms"]["solve_seconds"]["count"] == 1


# ---------------------------------------------------------------------------
# Chrome-trace export schema
# ---------------------------------------------------------------------------

class TestExport:
    def _traced_run(self):
        tr, reg = Tracer(), MetricsRegistry()
        with phase("driver", cat="driver", tracer=tr, registry=reg):
            for w in range(3):
                with phase("wave", cat="solve", tracer=tr, registry=reg,
                           wave=w):
                    pass
        tr.instant("resume", cat="driver", step=4)
        tr.counter("queue_depth", 2)
        return tr, reg

    def test_round_trip_validates(self, tmp_path):
        tr, reg = self._traced_run()
        path = str(tmp_path / "trace.json")
        write_trace(path, tr, registry=reg)
        stats = load_and_validate(path)
        assert stats["spans"] == 4                  # driver + 3 waves
        assert set(stats["cats"]) >= {"driver", "solve"}
        # the file is the object flavor both Perfetto and chrome load
        obj = json.loads(open(path).read())
        assert isinstance(obj["traceEvents"], list)
        assert obj["displayTimeUnit"] == "ms"
        # the registry snapshot rides along in otherData
        counters = obj["otherData"]["metrics"]["counters"]
        assert counters["phase_seconds/solve"] > 0
        names = {e["name"] for e in obj["traceEvents"]}
        assert {"process_name", "thread_name"} <= names

    def test_span_nesting_is_monotonic_per_thread(self):
        tr, _ = self._traced_run()
        obj = chrome_trace(tr)
        stats = validate_chrome_trace(obj)
        # every span sits on the recording thread's track
        assert len(stats["tids"]) == 1

    def test_validator_rejects_partial_overlap(self):
        obj = {"traceEvents": [
            {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0,
             "dur": 10},
            {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5,
             "dur": 10},
        ]}
        with pytest.raises(ValueError, match="partially overlaps"):
            validate_chrome_trace(obj)

    def test_validator_rejects_missing_keys(self):
        with pytest.raises(ValueError, match="traceEvents"):
            validate_chrome_trace({"events": []})
        with pytest.raises(ValueError, match="missing 'ts'"):
            validate_chrome_trace({"traceEvents": [
                {"name": "a", "ph": "X", "pid": 1, "tid": 1, "dur": 1}]})
        with pytest.raises(ValueError, match="'dur'"):
            validate_chrome_trace({"traceEvents": [
                {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 1}]})

    def test_span_counts_by_cat_and_name(self):
        tr, _ = self._traced_run()
        obj = chrome_trace(tr)
        assert span_counts(obj)["solve"] == 3
        assert span_counts(obj, by="name")["wave"] == 3

    def test_worker_thread_gets_its_own_named_track(self):
        tr = Tracer()

        def load():
            with tr.span("load", cat="prefetch_load"):
                pass

        t = threading.Thread(target=load, name="prefetch-worker")
        t.start()
        t.join()
        with tr.span("solve", cat="solve"):
            pass
        obj = chrome_trace(tr)
        meta = {e["args"]["name"] for e in obj["traceEvents"]
                if e["ph"] == "M" and e["name"] == "thread_name"}
        assert "prefetch-worker" in meta
        assert len(validate_chrome_trace(obj)["tids"]) == 2


# ---------------------------------------------------------------------------
# streaming-driver regression: spans match telemetry
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestStreamingSpans:
    def test_streaming_als_solve_spans_equal_waves_run(self):
        from repro.core import als as als_mod
        from repro.core.partition import plan_for
        from repro.outofcore import (RatingStore, build_schedule,
                                     run_streaming_als)
        from repro.sparse import synth

        spec = synth.SynthSpec("obs-oc", 96, 40, 1500, 8, 0.05)
        r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
        store = RatingStore(r, q=4)
        acc_eps = spec.n * (spec.f * spec.f + 3 * spec.f + 1) * 4
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=4, n_data=2,
                        fill=store.worst_fill, eps=acc_eps, buffers=4,
                        hbm_bytes=1 << 22)
        sched = build_schedule(plan, spec.m, spec.n, n_data=2)
        assert len(sched.waves) >= 2
        cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=2, mode="ref")

        tr, reg = Tracer(), MetricsRegistry()
        _, hist, tel = run_streaming_als(store, sched, cfg, tracer=tr,
                                         registry=reg)

        # THE span contract: one cat="solve" span per consumed wave
        assert len(tr.spans(cat="solve")) == tel.waves_run
        assert tel.waves_run == 2 * cfg.iters * len(sched.waves)
        # structural spans: one driver, per-iteration + two halves each
        assert len(tr.spans(cat="driver")) == 1
        assert len(tr.spans(cat="iteration")) == cfg.iters
        assert len(tr.spans(cat="half")) == 2 * cfg.iters
        # telemetry is the registry view; wall time is the driver phase
        assert tel.wall_seconds == reg.phase_seconds()["driver"]
        assert tel.wall_seconds > 0
        assert set(tel.phase_seconds) >= {"driver", "iteration", "half",
                                          "solve", "prefetch"}
        # per-iteration breakdowns ride in history
        assert all("phase_seconds" in rec for rec in hist)
        assert all(rec["phase_seconds"].get("solve", 0) > 0 for rec in hist)
        # the whole run exports as a valid Chrome trace with the same count
        obj = chrome_trace(tr, registry=reg)
        stats = validate_chrome_trace(obj)
        assert span_counts(obj)["solve"] == tel.waves_run
        assert len(stats["tids"]) >= 2      # prefetch worker tracks exist

    def test_streaming_sgd_solve_spans_equal_waves_run(self):
        from repro.outofcore import (TileStore, build_sgd_schedule,
                                     run_streaming_sgd)
        from repro.sgd import SgdConfig, block_ell
        from repro.sparse import synth

        spec = synth.SynthSpec("obs-sgd", 96, 40, 1500, 8, 0.05)
        r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
        grid = block_ell(r, g=4)
        sched = build_sgd_schedule(grid, spec.f, n_workers=2)
        cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=2,
                        mode="ref", seed=1)

        tr, reg = Tracer(), MetricsRegistry()
        _, hist, tel = run_streaming_sgd(TileStore(grid), sched, cfg,
                                         tracer=tr, registry=reg)
        assert len(tr.spans(cat="solve")) == tel.waves_run
        assert tel.waves_run == cfg.epochs * sched.waves_per_epoch
        assert len(tr.spans(cat="epoch")) == cfg.epochs
        assert tel.wall_seconds > 0
        assert all(rec["phase_seconds"].get("solve", 0) > 0 for rec in hist)
        validate_chrome_trace(chrome_trace(tr, registry=reg))

    def test_untraced_run_still_reports_telemetry(self):
        """Tracing off (the default): no spans exist anywhere, but the
        always-on registry still yields full telemetry."""
        from repro.outofcore import (TileStore, build_sgd_schedule,
                                     run_streaming_sgd)
        from repro.sgd import SgdConfig, block_ell
        from repro.sparse import synth

        spec = synth.SynthSpec("obs-off", 96, 40, 1500, 8, 0.05)
        r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
        grid = block_ell(r, g=4)
        sched = build_sgd_schedule(grid, spec.f, n_workers=2)
        cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=1,
                        mode="ref", seed=1)
        assert current_tracer() is NULL_TRACER
        _, _, tel = run_streaming_sgd(TileStore(grid), sched, cfg)
        assert tel.waves_run == sched.waves_per_epoch
        assert tel.wall_seconds > 0
        assert tel.phase_seconds["solve"] > 0
