"""Sparse substrate: PaddedELL round trips, partitioning invariants."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.partition import (batch_ranges, export_schedule, plan_for,
                                  plan_partitions)
from repro.sparse import padded, synth


def _random_coo(rng, m, n, nnz):
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    key = rows * n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


def _to_dense(ell: padded.PaddedELL) -> np.ndarray:
    d = np.zeros((ell.m, ell.n_cols), np.float32)
    for u in range(ell.m):
        for k in range(int(ell.cnt[u])):
            d[u, ell.idx[u, k]] += ell.val[u, k]
    return d


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 32), n=st.integers(4, 32),
       nnz=st.integers(1, 200), seed=st.integers(0, 1000))
def test_pad_csr_fast_equals_slow(m, n, nnz, seed):
    rng = np.random.default_rng(seed)
    rows, cols, vals = _random_coo(rng, m, n, nnz)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    a = padded.pad_csr(ptr, cc, vv, n)
    b = padded.pad_csr_fast(ptr, cc, vv, n)
    np.testing.assert_array_equal(a.idx, b.idx)
    np.testing.assert_array_equal(a.val, b.val)
    np.testing.assert_array_equal(a.cnt, b.cnt)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), p=st.sampled_from([2, 4]))
def test_partition_preserves_matrix(seed, p):
    """Property: the p column shards reassemble exactly to the original R
    (paper eq. 5: partial sums over shards == full sum)."""
    rng = np.random.default_rng(seed)
    m, n = 16, 8 * p
    rows, cols, vals = _random_coo(rng, m, n, 120)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    ell = padded.pad_csr_fast(ptr, cc, vv, n)
    parts = padded.partition_padded(ell, p)
    dense = _to_dense(ell)
    reassembled = np.zeros_like(dense)
    npp = n // p
    for i in range(p):
        shard = padded.PaddedELL(parts.idx[i], parts.val[i], parts.cnt[i], npp)
        reassembled[:, i * npp:(i + 1) * npp] += _to_dense(shard)
    np.testing.assert_allclose(dense, reassembled, atol=1e-6)
    # counts decompose too
    np.testing.assert_array_equal(parts.cnt.sum(axis=0), ell.cnt)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 32), n=st.integers(4, 32),
       nnz=st.integers(1, 200), seed=st.integers(0, 1000))
def test_transpose_coo_roundtrip(m, n, nnz, seed):
    """coo -> ELL -> transpose -> ELL -> transpose == original nnz set."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = _random_coo(rng, m, n, nnz)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    ell = padded.pad_csr_fast(ptr, cc, vv, n)
    tr, tc, tv = ell.transpose_coo()
    ptr_t, cc_t, vv_t = padded.csr_from_coo(tr, tc, tv, n)
    ell_t = padded.pad_csr_fast(ptr_t, cc_t, vv_t, m)
    rr, rc, rv = ell_t.transpose_coo()      # transpose of the transpose
    want = sorted(zip(rows.tolist(), cols.tolist(), vals.tolist()))
    got = sorted(zip(rr.tolist(), rc.tolist(), rv.tolist()))
    assert [(a, b) for a, b, _ in want] == [(a, b) for a, b, _ in got]
    np.testing.assert_allclose([v for *_, v in want], [v for *_, v in got],
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), dense_rows=st.integers(1, 3))
def test_pad_csr_fast_equals_slow_on_ragged(seed, dense_rows):
    """Deliberately ragged degrees — a few near-dense rows, many sparse
    ones, and guaranteed empty rows — must produce identical layouts."""
    rng = np.random.default_rng(seed)
    m, n = 24, 64
    rows_l, cols_l = [], []
    for u in range(dense_rows):                   # near-dense head rows
        cc = rng.choice(n, size=n - 2, replace=False)
        rows_l.append(np.full(len(cc), u)), cols_l.append(cc)
    for u in range(dense_rows, m - 4):            # sparse tail, skewed
        deg = int(rng.integers(0, 5))
        cc = rng.choice(n, size=deg, replace=False)
        rows_l.append(np.full(deg, u)), cols_l.append(cc)
    rows = np.concatenate(rows_l).astype(np.int64)   # rows m-4..m-1 empty
    cols = np.concatenate(cols_l).astype(np.int64)
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    a = padded.pad_csr(ptr, cc, vv, n)
    b = padded.pad_csr_fast(ptr, cc, vv, n)
    assert a.K == b.K
    np.testing.assert_array_equal(a.idx, b.idx)
    np.testing.assert_array_equal(a.val, b.val)
    np.testing.assert_array_equal(a.cnt, b.cnt)
    assert int(a.cnt[-1]) == 0                       # empty rows survived


def test_synthetic_ratings_shapes_and_split():
    spec = synth.scaled(synth.DATASETS["netflix"], 0.003, f=8)
    r, rt, rte, (xs, ts) = synth.make_synthetic_ratings(spec, seed=0)
    assert r.m == spec.m and rt.m == spec.n
    assert r.nnz + rte.nnz > 0
    assert abs(rte.nnz / max(r.nnz + rte.nnz, 1) - 0.1) < 0.05
    # R^T has the same nonzeros
    assert r.nnz == rt.nnz


def test_planner_netflix_single_device():
    """Paper §4.3 best practice 1: Netflix (f=100) fits one 12-16GB device
    with p=1 (MO-ALS)."""
    s = synth.DATASETS["netflix"]
    plan = plan_partitions(s.m, s.n, s.nnz, s.f)
    assert plan.fits and plan.p == 1


def test_planner_huge_needs_partitioning():
    """Facebook-scale (f=100) cannot fit p=1/q=1 — the planner must split."""
    s = synth.DATASETS["cumf_max"]
    plan = plan_partitions(s.m, s.n, s.nnz, s.f)
    assert plan.fits
    assert plan.q > 1
    # memory constraint actually honored
    assert plan.bytes_per_device < 16 * (1 << 30)


def test_planner_monotone_in_hbm():
    s = synth.DATASETS["hugewiki"]
    small = plan_partitions(s.m, s.n, s.nnz, s.f, hbm_bytes=8 << 30)
    big = plan_partitions(s.m, s.n, s.nnz, s.f, hbm_bytes=64 << 30)
    assert small.q >= big.q


# ---------------------------------------------------------------------------
# row_slice / pad_rows: the out-of-core wave unit must preserve the
# cnt/padding/masking invariants (ISSUE 2 satellite)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 32), n=st.integers(4, 32),
       nnz=st.integers(1, 200), seed=st.integers(0, 1000))
def test_row_slice_preserves_invariants(m, n, nnz, seed):
    rng = np.random.default_rng(seed)
    rows, cols, vals = _random_coo(rng, m, n, nnz)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    ell = padded.pad_csr_fast(ptr, cc, vv, n)
    dense = _to_dense(ell)
    lo, hi = m // 4, max(m // 4, m - m // 4)
    sl = padded.row_slice(ell, lo, hi)
    # shape/layout invariants: K and n_cols survive, rows match the range
    assert sl.K == ell.K and sl.n_cols == ell.n_cols and sl.m == hi - lo
    np.testing.assert_array_equal(sl.cnt, ell.cnt[lo:hi])
    # masking invariant: slots at position >= cnt carry idx = 0, val = 0
    dead = ~sl.mask().astype(bool)
    assert (sl.idx[dead] == 0).all() and (sl.val[dead] == 0).all()
    # round trip against the dense reference
    np.testing.assert_allclose(_to_dense(sl), dense[lo:hi], atol=1e-6)
    # slices are copies: mutating one must not alias the parent
    if sl.m and sl.K:
        sl.val[0, 0] += 1.0
        np.testing.assert_allclose(_to_dense(ell), dense, atol=1e-6)


def test_row_slice_edge_ranges():
    rng = np.random.default_rng(0)
    rows, cols, vals = _random_coo(rng, 8, 8, 30)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, 8)
    ell = padded.pad_csr_fast(ptr, cc, vv, 8)
    assert padded.row_slice(ell, 0, 8).m == 8
    assert padded.row_slice(ell, 3, 3).m == 0
    with pytest.raises(AssertionError):
        padded.row_slice(ell, 0, 9)


def test_pad_rows_appends_empty_rows():
    rng = np.random.default_rng(1)
    rows, cols, vals = _random_coo(rng, 10, 8, 40)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, 10)
    ell = padded.pad_csr_fast(ptr, cc, vv, 8)
    p = padded.pad_rows(ell, 16)
    assert p.m == 16 and p.nnz == ell.nnz
    assert (p.cnt[10:] == 0).all()
    np.testing.assert_allclose(_to_dense(p)[:10], _to_dense(ell), atol=1e-6)
    assert padded.pad_rows(ell, 10) is ell


# ---------------------------------------------------------------------------
# Wave math (ISSUE 2 satellite): the exported schedule covers every row
# exactly once per iteration, and waves * data_axis >= q
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 500), q=st.integers(1, 32),
       n_data=st.integers(1, 8))
def test_export_schedule_covers_rows_exactly_once(m, q, n_data):
    plan = plan_for(m, 64, 10 * m, 8, p=1, q=q, n_data=n_data)
    waves = export_schedule(plan, m, n_data)
    assert len(waves) * n_data >= q
    assert len(waves) == -(-q // n_data) == plan.waves
    covered = np.zeros(m, np.int32)
    seen_batches = []
    for wave in waves:
        assert 1 <= len(wave) <= n_data
        for b in wave:
            covered[b.row_start:b.row_stop] += 1
            seen_batches.append(b.index)
    assert (covered == 1).all()
    assert seen_batches == list(range(q))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 300), q=st.integers(1, 16))
def test_batch_ranges_balanced(m, q):
    batches = batch_ranges(m, q)
    sizes = [b.rows for b in batches]
    assert sum(sizes) == m and len(batches) == q
    assert max(sizes) - min(sizes) <= 1
    assert batches[0].row_start == 0 and batches[-1].row_stop == m


def test_waves_cover_q_for_plans_that_dont_fit():
    """A plan that does not fit still exports a q-covering wave schedule."""
    s = synth.DATASETS["yahoomusic"]
    plan = plan_for(s.m, s.n, s.nnz, s.f, p=1, q=64, n_data=4,
                    hbm_bytes=1 << 20)
    assert not plan.fits
    assert plan.waves * 4 >= plan.q
    waves = export_schedule(plan, s.m, 4)
    assert len(waves) == plan.waves
    assert waves[-1][-1].row_stop == s.m


def test_export_schedule_default_ndata_reconstructs_plan_waves():
    plan = plan_for(1000, 64, 5000, 8, p=1, q=8, n_data=2)
    assert plan.waves == 4
    waves = export_schedule(plan, 1000)
    assert len(waves) == plan.waves


# ---------------------------------------------------------------------------
# Degree-binned layout (ISSUE 9)
# ---------------------------------------------------------------------------

def _power_law_coo(rng, m, n, nnz, alpha=1.2):
    """COO with power-law row degrees — the regime binning exists for."""
    ranks = np.arange(1, m + 1, dtype=np.float64)
    p = ranks ** -alpha
    rows = rng.choice(m, size=nnz, p=p / p.sum())
    cols = rng.integers(0, n, nnz)
    key = rows * n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), dense_rows=st.integers(1, 3),
       k_cap=st.sampled_from([1, 4, 16]))
def test_pad_csr_k_cap_matches_oracle_on_ragged(seed, dense_rows, k_cap):
    """``k_cap`` truncation (keep each row's first k_cap ratings) must be
    bit-identical between the readable oracle and the vectorized path, on
    deliberately ragged degrees including rows above and below the cap."""
    rng = np.random.default_rng(seed)
    m, n = 24, 64
    rows_l, cols_l = [], []
    for u in range(dense_rows):
        cc = rng.choice(n, size=n - 2, replace=False)
        rows_l.append(np.full(len(cc), u)), cols_l.append(cc)
    for u in range(dense_rows, m - 4):
        deg = int(rng.integers(0, 5))
        cc = rng.choice(n, size=deg, replace=False)
        rows_l.append(np.full(deg, u)), cols_l.append(cc)
    rows = np.concatenate(rows_l).astype(np.int64)
    cols = np.concatenate(cols_l).astype(np.int64)
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    a = padded.pad_csr(ptr, cc, vv, n, k_cap=k_cap)
    b = padded.pad_csr_fast(ptr, cc, vv, n, k_cap=k_cap)
    assert a.K == b.K and a.K <= padded.round_k(k_cap)
    np.testing.assert_array_equal(a.idx, b.idx)
    np.testing.assert_array_equal(a.val, b.val)
    np.testing.assert_array_equal(a.cnt, b.cnt)
    assert int(a.cnt.max()) <= k_cap


def test_bin_rows_single_bin_is_bit_exact():
    """n_bins=1 reproduces today's layout bit-for-bit (the compat gate the
    whole binned refactor hides behind)."""
    rng = np.random.default_rng(0)
    rows, cols, vals = _power_law_coo(rng, 64, 40, 800)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, 64)
    ell = padded.pad_csr_fast(ptr, cc, vv, 40)
    binned = padded.bin_rows(ptr, cc, vv, 40, n_bins=1)
    assert binned.n_bins == 1
    np.testing.assert_array_equal(binned.perm, np.arange(64))
    np.testing.assert_array_equal(binned.bins[0].idx, ell.idx)
    np.testing.assert_array_equal(binned.bins[0].val, ell.val)
    np.testing.assert_array_equal(binned.bins[0].cnt, ell.cnt)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_bins=st.sampled_from([2, 4, 6]))
def test_bin_rows_perm_roundtrip_and_parity(seed, n_bins):
    """Permutation round-trips (``inv_perm[perm] == arange``), the binned
    layout stores the same matrix (dense equality through ``to_padded``),
    and each row lands in exactly one bin."""
    rng = np.random.default_rng(seed)
    m, n = 48, 32
    rows, cols, vals = _power_law_coo(rng, m, n, 600)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    ell = padded.pad_csr_fast(ptr, cc, vv, n)
    binned = padded.bin_rows(ptr, cc, vv, n, n_bins=n_bins)
    np.testing.assert_array_equal(binned.inv_perm[binned.perm], np.arange(m))
    assert sorted(binned.perm.tolist()) == list(range(m))
    assert binned.nnz == ell.nnz
    np.testing.assert_allclose(_to_dense(binned.to_padded()), _to_dense(ell),
                               atol=1e-6)
    for r in binned.rows:                # stable grouping => ascending
        assert np.all(np.diff(r) > 0) or r.size <= 1


def test_bin_rows_fill_beats_uniform_on_power_law():
    """On power-law degrees, per-bin padding is strictly cheaper than the
    single grid-wide K — the whole point of cuMF's degree binning."""
    rng = np.random.default_rng(7)
    rows, cols, vals = _power_law_coo(rng, 256, 64, 4000, alpha=1.2)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, 256)
    ell = padded.pad_csr_fast(ptr, cc, vv, 64)
    prev_slots = ell.padded_slots
    assert ell.fill > 1.5, "synthetic degrees not skewed enough to test"
    for n_bins in (2, 4, 8):
        binned = padded.bin_rows(ptr, cc, vv, 64, n_bins=n_bins)
        assert binned.padded_slots < ell.padded_slots
        assert binned.fill < ell.fill
        # per-bin fill is also <= the uniform fill, bin by bin
        for b in binned.bins:
            assert b.fill <= ell.fill + 1e-9
        assert binned.padded_slots <= prev_slots  # more bins never hurt
        prev_slots = binned.padded_slots
    # re-binning an existing PaddedELL agrees with binning from CSR
    rebinned = padded.bin_padded(ell, 4)
    direct = padded.bin_rows(ptr, cc, vv, 64, n_bins=4)
    assert rebinned.K_list == direct.K_list
    for a, b in zip(rebinned.bins, direct.bins):
        np.testing.assert_array_equal(a.idx, b.idx)
        np.testing.assert_array_equal(a.val, b.val)
        np.testing.assert_array_equal(a.cnt, b.cnt)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_bins=st.sampled_from([1, 3, 4]),
       q=st.sampled_from([2, 3, 4]))
def test_binned_row_slice_reassembles(seed, n_bins, q):
    """Slicing a BinnedELL into q contiguous row ranges loses nothing: the
    slices' spans tile each bin exactly and per-slice dense blocks stack
    back to the full matrix."""
    rng = np.random.default_rng(seed)
    m, n = 40, 24
    rows, cols, vals = _power_law_coo(rng, m, n, 400)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    binned = padded.bin_rows(ptr, cc, vv, n, n_bins=n_bins)
    dense = _to_dense(binned.to_padded())
    edges = batch_ranges(m, q)
    got = np.concatenate(
        [_to_dense(binned.row_slice(b.row_start, b.row_stop).to_padded())
         for b in edges], axis=0)
    np.testing.assert_allclose(got, dense, atol=1e-6)
    # slots decompose exactly (the wave-prediction identity)
    assert sum(binned.row_slice(b.row_start, b.row_stop).padded_slots
               for b in edges) == binned.padded_slots
