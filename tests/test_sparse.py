"""Sparse substrate: PaddedELL round trips, partitioning invariants."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.partition import plan_partitions
from repro.sparse import padded, synth


def _random_coo(rng, m, n, nnz):
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    key = rows * n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


def _to_dense(ell: padded.PaddedELL) -> np.ndarray:
    d = np.zeros((ell.m, ell.n_cols), np.float32)
    for u in range(ell.m):
        for k in range(int(ell.cnt[u])):
            d[u, ell.idx[u, k]] += ell.val[u, k]
    return d


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 32), n=st.integers(4, 32),
       nnz=st.integers(1, 200), seed=st.integers(0, 1000))
def test_pad_csr_fast_equals_slow(m, n, nnz, seed):
    rng = np.random.default_rng(seed)
    rows, cols, vals = _random_coo(rng, m, n, nnz)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    a = padded.pad_csr(ptr, cc, vv, n)
    b = padded.pad_csr_fast(ptr, cc, vv, n)
    np.testing.assert_array_equal(a.idx, b.idx)
    np.testing.assert_array_equal(a.val, b.val)
    np.testing.assert_array_equal(a.cnt, b.cnt)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), p=st.sampled_from([2, 4]))
def test_partition_preserves_matrix(seed, p):
    """Property: the p column shards reassemble exactly to the original R
    (paper eq. 5: partial sums over shards == full sum)."""
    rng = np.random.default_rng(seed)
    m, n = 16, 8 * p
    rows, cols, vals = _random_coo(rng, m, n, 120)
    ptr, cc, vv = padded.csr_from_coo(rows, cols, vals, m)
    ell = padded.pad_csr_fast(ptr, cc, vv, n)
    parts = padded.partition_padded(ell, p)
    dense = _to_dense(ell)
    reassembled = np.zeros_like(dense)
    npp = n // p
    for i in range(p):
        shard = padded.PaddedELL(parts.idx[i], parts.val[i], parts.cnt[i], npp)
        reassembled[:, i * npp:(i + 1) * npp] += _to_dense(shard)
    np.testing.assert_allclose(dense, reassembled, atol=1e-6)
    # counts decompose too
    np.testing.assert_array_equal(parts.cnt.sum(axis=0), ell.cnt)


def test_synthetic_ratings_shapes_and_split():
    spec = synth.scaled(synth.DATASETS["netflix"], 0.003, f=8)
    r, rt, rte, (xs, ts) = synth.make_synthetic_ratings(spec, seed=0)
    assert r.m == spec.m and rt.m == spec.n
    assert r.nnz + rte.nnz > 0
    assert abs(rte.nnz / max(r.nnz + rte.nnz, 1) - 0.1) < 0.05
    # R^T has the same nonzeros
    assert r.nnz == rt.nnz


def test_planner_netflix_single_device():
    """Paper §4.3 best practice 1: Netflix (f=100) fits one 12-16GB device
    with p=1 (MO-ALS)."""
    s = synth.DATASETS["netflix"]
    plan = plan_partitions(s.m, s.n, s.nnz, s.f)
    assert plan.fits and plan.p == 1


def test_planner_huge_needs_partitioning():
    """Facebook-scale (f=100) cannot fit p=1/q=1 — the planner must split."""
    s = synth.DATASETS["cumf_max"]
    plan = plan_partitions(s.m, s.n, s.nnz, s.f)
    assert plan.fits
    assert plan.q > 1
    # memory constraint actually honored
    assert plan.bytes_per_device < 16 * (1 << 30)


def test_planner_monotone_in_hbm():
    s = synth.DATASETS["hugewiki"]
    small = plan_partitions(s.m, s.n, s.nnz, s.f, hbm_bytes=8 << 30)
    big = plan_partitions(s.m, s.n, s.nnz, s.f, hbm_bytes=64 << 30)
    assert small.q >= big.q
