"""End-to-end behaviour tests for the full system (paper workload + LM
substrate + serving engine + data pipeline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import als as als_mod
from repro.core.objective import rmse_padded
from repro.data.prefetch import Prefetcher
from repro.data.tokens import TokenDataset, synthetic_lm_batches
from repro.models import lm as lm_mod
from repro.models import transformer as T
from repro.serving.engine import Request, ServeEngine
from repro.sparse import synth
from repro.training.optimizer import OptConfig


def test_full_mf_pipeline_recovers_planted_factors():
    """The paper's end-to-end claim at laptop scale: synthesize ratings from
    a planted low-rank model, factorize with ALS, and reach the noise-floor
    RMSE on held-out entries."""
    # yahoomusic's lambda=1.4 targets 0-100-scale ratings; the planted
    # model emits ~N(0,1), so the mini-scale equivalent is lambda/10
    spec = synth.SynthSpec("yahoomusic-mini", m=1024, n=256, nnz=60_000,
                           f=8, lam=0.14)
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=7, noise=0.05)
    cfg = als_mod.AlsConfig(f=8, lam=spec.lam, iters=10, mode="ref")
    state, hist = als_mod.als_train(
        als_mod.ell_triplet(r), als_mod.ell_triplet(rt), r.m, rt.m, cfg,
        test=als_mod.ell_triplet(rte))
    # yahoomusic lambda=1.4 is heavy regularization; just demand progress
    assert hist[-1]["test_rmse"] < 0.7 * hist[0]["test_rmse"]


def test_serving_engine_generates():
    cfg = registry.smoke_config("phi3-mini-3.8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=np.arange(5) + i, max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert all(all(0 <= t < cfg.vocab for t in r.out) for r in reqs)


def test_serving_engine_matches_pure_decode():
    """Engine output == straight prefill+decode for a single request."""
    cfg = registry.smoke_config("qwen3-4b")
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    prompt = np.asarray([1, 2, 3, 4], np.int32)

    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    req = Request(rid=0, prompt=prompt, max_new_tokens=3)
    eng.submit(req)
    eng.run()

    prefill = lm_mod.make_prefill_step(cfg)
    decode = lm_mod.make_decode_step(cfg)
    tok, cache = prefill(params, {"tokens": prompt[None]})
    # engine caches are padded to max_seq=32: rebuild at the same size
    cache32 = T.init_cache(cfg, 1, 32, jnp.float32)
    _, cache32 = _replay(cfg, params, prompt, cache32)
    toks = []
    lengths = jnp.asarray([len(prompt)], jnp.int32)
    t = jnp.asarray([_replay_last(cfg, params, prompt)], jnp.int32)
    for _ in range(3):
        t, cache32, lengths = decode(params, cache32, t, lengths)
        toks.append(int(t[0]))
    assert req.out == toks, (req.out, toks)


def _replay(cfg, params, prompt, cache):
    decode = lm_mod.make_decode_step(cfg)
    lengths = jnp.zeros((1,), jnp.int32)
    t = None
    for p in prompt:
        t, cache, lengths = decode(params, cache,
                                   jnp.asarray([p], jnp.int32), lengths)
    return t, cache


def _replay_last(cfg, params, prompt):
    cache = T.init_cache(cfg, 1, 32, jnp.float32)
    t, _ = _replay(cfg, params, prompt, cache)
    return int(t[0])


def test_token_dataset_roundtrip(tmp_path):
    data = (np.arange(1000) % 97).astype(np.uint16)
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    ds = TokenDataset(str(path), seq_len=32, vocab=97)
    batches = list(ds.batches(batch=4, seed=0))
    assert len(batches) >= 1
    b = batches[0]
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_token_dataset_host_sharding(tmp_path):
    data = (np.arange(4000) % 97).astype(np.uint16)
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    ds = TokenDataset(str(path), seq_len=16, vocab=97)
    rows0 = sum(b["tokens"].shape[0] for b in ds.batches(2, host_id=0, n_hosts=2))
    rows1 = sum(b["tokens"].shape[0] for b in ds.batches(2, host_id=1, n_hosts=2))
    assert rows0 + rows1 >= len(ds) - 4      # full coverage minus remainder
    assert abs(rows0 - rows1) <= 2


def test_prefetcher_preserves_order_and_errors():
    it = iter(range(10))
    pf = Prefetcher((({"x": np.asarray([i])}) for i in range(10)), depth=3)
    got = [int(b["x"][0]) for b in pf]
    assert got == list(range(10))

    def boom():
        yield {"x": np.zeros(1)}
        raise RuntimeError("io error")
    pf2 = Prefetcher(boom(), depth=2)
    next(pf2)
    with pytest.raises(RuntimeError):
        next(pf2)


def test_synthetic_lm_stream_is_learnable_structure():
    it = synthetic_lm_batches(32, 16, 4, seed=0)
    b = next(it)
    # deterministic rule holds for ~90% of tokens
    tok, lab = b["tokens"], b["labels"]
    pred = (31 * tok[:, 1:] + 17 * tok[:, :-1]) % 32
    frac = (pred == lab[:, 1:]).mean()
    assert frac > 0.7, frac
