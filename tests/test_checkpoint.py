"""Fault tolerance: atomic checkpoint commit, restart-from-latest (paper
§4.4), failure injection mid-write."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, (8, 4)),
            "nested": {"theta": jax.random.normal(k, (6, 4)),
                       "step": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    got = restore_checkpoint(str(tmp_path), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_allclose(a, b)


def test_latest_pointer_advances(tmp_path):
    t = _tree()
    for s in (1, 2, 5):
        save_checkpoint(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 5


def test_interrupted_write_leaves_previous_intact(tmp_path):
    """A crash mid-write (tmp dir left behind) must not corrupt recovery —
    the atomic-rename protocol guarantees LATEST points at a complete
    checkpoint."""
    t0 = _tree(0)
    save_checkpoint(str(tmp_path), 1, t0)
    # simulate a crash: a stale .tmp directory with garbage
    os.makedirs(tmp_path / "step_00000002.tmp")
    (tmp_path / "step_00000002.tmp" / "junk").write_text("partial")
    assert latest_step(str(tmp_path)) == 1
    got = restore_checkpoint(str(tmp_path), t0)
    np.testing.assert_allclose(jax.tree.leaves(t0)[0],
                               jax.tree.leaves(got)[0])
    # and a later save cleans up + commits fine
    save_checkpoint(str(tmp_path), 2, t0)
    assert latest_step(str(tmp_path)) == 2


def test_manager_restart_path(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    t = _tree(1)
    tree, step = mgr.restore_or_init(t, lambda: t)
    assert step == 0
    mgr.save(10, t)
    mgr.wait()
    t2, step2 = mgr.restore_or_init(t, lambda: pytest.fail("should restore"))
    assert step2 == 10
    np.testing.assert_allclose(jax.tree.leaves(t)[0], jax.tree.leaves(t2)[0])


def test_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    mgr.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_manager_async_write(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    t = _tree()
    mgr.save(1, t)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_als_restart_resumes_convergence(tmp_path):
    """End-to-end: kill ALS after 2 iters, restart from checkpoint, final
    RMSE matches an uninterrupted run."""
    from repro.core import als as als_mod
    from repro.sparse import synth

    spec = synth.scaled(synth.DATASETS["netflix"], 0.004, f=8)
    r_tr, r_tr_T, r_te, _ = synth.make_synthetic_ratings(spec, seed=5)
    r = als_mod.ell_triplet(r_tr)
    rt = als_mod.ell_triplet(r_tr_T)
    cfg = als_mod.AlsConfig(f=8, lam=0.05, iters=4, mode="ref")

    # uninterrupted
    s = als_mod.als_init(r_tr.m, r_tr_T.m, cfg)
    for _ in range(4):
        s = als_mod.als_iteration(s, r, rt, cfg)

    # interrupted at 2, checkpoint, "crash", restore, finish
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    s2 = als_mod.als_init(r_tr.m, r_tr_T.m, cfg)
    for _ in range(2):
        s2 = als_mod.als_iteration(s2, r, rt, cfg)
    mgr.save(2, {"x": s2.x, "theta": s2.theta})
    del s2  # crash
    restored, step = mgr.restore_or_init(
        {"x": jnp.zeros((r_tr.m, 8)), "theta": jnp.zeros((r_tr_T.m, 8))},
        lambda: pytest.fail("must restore"))
    assert step == 2
    s3 = als_mod.AlsState(x=jnp.asarray(restored["x"]),
                          theta=jnp.asarray(restored["theta"]),
                          iteration=jnp.int32(2))
    for _ in range(2):
        s3 = als_mod.als_iteration(s3, r, rt, cfg)
    np.testing.assert_allclose(s.x, s3.x, atol=1e-4, rtol=1e-4)
