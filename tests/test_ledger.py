"""Plan-vs-actual ledger + bench history/regression gate (ISSUE 8).

Fast tier: record verdict semantics, validate_ledger's recompute-and-reject
behavior, merge_ledgers, the history append/compare round-trip, the regress
exit codes, the report CLI, and footprint_bytes against the budgets-file
docstring numbers.  Slow tier: real streaming ALS/SGD runs whose emitted
ledgers must validate with every exact record holding; a seeded
mis-prediction must exit nonzero through ``repro.obs.regress --ledger``.
Mesh tier: the 2x2-mesh run's ledger carries exact reduce fast/slow rows.
"""
import json
import os
import sys

import pytest

from repro.obs.ledger import (LEDGER_SCHEMA, Ledger, merge_ledgers,
                              validate_ledger)
from repro.obs.regress import (check_ledger, classify, compare_history,
                               load_history)
from repro.obs.regress import main as regress_main
from repro.obs.report import main as report_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)          # for benchmarks.history (no PYTHONPATH)


def make_ledger(**overrides):
    led = Ledger(solver="test", waves=3)
    led.record("bytes_streamed", 1000, overrides.get("measured_bytes", 1000),
               unit="bytes", check="exact")
    led.record("peak_device_bytes", 4096, overrides.get("measured_peak", 2048),
               unit="bytes", check="le")
    led.record("fill_waste_ratio", 1.25, overrides.get("measured_fill", 1.25),
               unit="ratio", check="rel", rel_tol=1e-9)
    return led


class TestLedgerRecords:
    def test_exact_check(self):
        led = Ledger()
        ok = led.record("a", 10, 10, unit="bytes")
        bad = led.record("b", 10, 11, unit="bytes")
        assert ok["ok"] and not bad["ok"]
        assert bad["drift"] == pytest.approx(0.1)
        assert not led.ok
        assert led.flags == ["error:b"]

    def test_le_check_is_a_bound_not_a_value(self):
        led = Ledger()
        under = led.record("peak", 100, 60, unit="bytes", check="le")
        at = led.record("cap", 100, 100, unit="bytes", check="le")
        over = led.record("blown", 100, 101, unit="bytes", check="le")
        assert under["ok"] and at["ok"] and not over["ok"]
        assert under["drift"] == pytest.approx(-0.4)

    def test_rel_check_tolerance(self):
        led = Ledger()
        inside = led.record("r1", 2.0, 2.0 + 1e-12, unit="x",
                            check="rel", rel_tol=1e-9)
        outside = led.record("r2", 2.0, 2.2, unit="x",
                             check="rel", rel_tol=0.05)
        assert inside["ok"] and not outside["ok"]

    def test_warn_severity_reports_but_does_not_fail(self):
        led = Ledger()
        led.record("hard", 5, 5, unit="n")
        led.record("soft", 1.0, 9.0, unit="s", check="rel", rel_tol=0.1,
                   severity="warn")
        assert led.ok                       # warn records never decide ok
        assert led.flags == ["warn:soft"]   # but they are still flagged
        obj = led.to_obj()
        summary = validate_ledger(obj)
        assert summary == {"records": 2, "errors": 0, "warnings": 1,
                           "ok": True}

    def test_zero_prediction_drift_is_null(self):
        led = Ledger()
        both_zero = led.record("z0", 0, 0, unit="bytes")
        surprise = led.record("z1", 0, 7, unit="bytes")
        assert both_zero["drift"] == 0.0 and both_zero["ok"]
        assert surprise["drift"] is None and not surprise["ok"]
        validate_ledger(led.to_obj())       # null drift round-trips

    def test_records_survive_json_round_trip(self):
        obj = json.loads(json.dumps(make_ledger().to_obj()))
        assert obj["schema"] == LEDGER_SCHEMA
        assert validate_ledger(obj)["ok"]


class TestValidateLedger:
    def test_rejects_wrong_schema(self):
        obj = make_ledger().to_obj()
        obj["schema"] = "nope"
        with pytest.raises(ValueError, match="schema"):
            validate_ledger(obj)

    def test_rejects_missing_record_key(self):
        obj = make_ledger().to_obj()
        del obj["records"][0]["drift"]
        with pytest.raises(ValueError, match="missing key"):
            validate_ledger(obj)

    def test_recomputes_verdicts_so_tampering_fails(self):
        """A hand-flipped ok is REJECTED (ValueError), not reported as a
        drift — the gate trusts the numbers, never the stored verdict."""
        obj = make_ledger(measured_bytes=999).to_obj()
        assert not obj["ok"]
        obj["records"][0]["ok"] = True       # tamper the record verdict
        with pytest.raises(ValueError, match="inconsistent"):
            validate_ledger(obj)

    def test_rejects_stale_overall_ok(self):
        obj = make_ledger().to_obj()
        obj["ok"] = False                    # numbers say True
        with pytest.raises(ValueError, match="overall ok"):
            validate_ledger(obj)

    def test_rejects_tampered_drift(self):
        obj = make_ledger().to_obj()
        obj["records"][1]["drift"] = 0.0     # peak drift is really -0.5
        with pytest.raises(ValueError, match="drift"):
            validate_ledger(obj)

    def test_rejects_non_numeric_measurement(self):
        obj = make_ledger().to_obj()
        obj["records"][0]["measured"] = "1000"
        with pytest.raises(ValueError, match="not a number"):
            validate_ledger(obj)


class TestMergeLedgers:
    def test_prefixes_and_conjunction(self):
        good = make_ledger().to_obj()
        bad = make_ledger(measured_bytes=1)
        bad.run["solver"] = "sgd"
        merged = merge_ledgers({"als": good, "sgd": bad.to_obj(),
                                "skipped": None})
        assert validate_ledger(merged)["records"] == 6
        names = [r["name"] for r in merged["records"]]
        assert "als/bytes_streamed" in names
        assert "sgd/bytes_streamed" in names
        assert not merged["ok"]
        assert "error:sgd/bytes_streamed" in merged["flags"]
        assert merged["run"]["sgd"]["solver"] == "sgd"


class TestRegressClassify:
    def test_key_taxonomy(self):
        # deterministic: pure shape functions, exact across runs
        for key in ("bytes_streamed_per_iter", "waves", "padded_slots",
                    "nnz_streamed", "n_data", "fits", "fill_waste_ratio"):
            assert classify(key) == "deterministic", key
        # times: warn-only (CI noise)
        for key in ("wall_seconds", "measured_iter_s", "epochs_per_sec",
                    "phase_seconds.solve"):
            assert classify(key) == "time", key
        # metered peaks are prefetch-timing dependent: noisy by override,
        # even though "bytes" would otherwise read deterministic
        assert classify("peak_device_bytes") == "noisy"
        assert classify("rmse") == "noisy"


def _history_entry(bench="bench_x", quick=True, **metrics):
    row = {"name": "row0", "bytes_streamed": 100, "waves": 4,
           "wall_seconds": 1.0, "rmse": 0.91}
    row.update(metrics)
    return {"schema": "repro.obs/bench-history-v1",
            "provenance": {"git_sha": "abc", "timestamp": "t",
                           "quick": quick, "backend": "cpu",
                           "device_count": 1, "jax": "0"},
            "bench": bench, "records": [row]}


class TestHistoryCompare:
    def test_first_run_seeds(self):
        lines, failures = compare_history([_history_entry()])
        assert failures == 0
        assert any(line.startswith("SEED") for line in lines)

    def test_identical_runs_pass(self):
        entries = [_history_entry(), _history_entry()]
        lines, failures = compare_history(entries)
        assert failures == 0
        assert any(line.startswith("OK") for line in lines)

    def test_deterministic_drift_fails(self):
        entries = [_history_entry(), _history_entry(bytes_streamed=101)]
        lines, failures = compare_history(entries)
        assert failures == 1
        assert any("bytes_streamed" in li and li.startswith("FAIL")
                   for li in lines)

    def test_time_jitter_warns_only(self):
        entries = [_history_entry(), _history_entry(wall_seconds=2.5)]
        lines, failures = compare_history(entries)       # 150% > 50% tol
        assert failures == 0
        assert any("wall_seconds" in li and li.startswith("WARN")
                   for li in lines)
        _, strict = compare_history(entries, strict_times=True)
        assert strict == 1

    def test_configs_compared_separately(self):
        # a quick run is never baselined against a full run
        entries = [_history_entry(quick=False, bytes_streamed=999),
                   _history_entry(quick=True)]
        _, failures = compare_history(entries)
        assert failures == 0

    def test_rolling_median_absorbs_one_outlier(self):
        entries = [_history_entry(wall_seconds=s)
                   for s in (1.0, 1.1, 9.0, 1.0, 1.05)]
        lines, failures = compare_history(entries, window=4)
        assert failures == 0
        assert not any(li.startswith("WARN") for li in lines)


class TestHistoryRoundTrip:
    def test_append_load_compare(self, tmp_path):
        from benchmarks.history import append_history, provenance, stamp

        prov = provenance(quick=True)
        assert prov["git_sha"] and prov["timestamp"]
        assert prov["quick"] is True
        records = [{"name": "r", "bytes_streamed": 64, "wall_seconds": 0.5}]
        stamp(records, prov)
        assert records[0]["provenance"] is prov
        path = tmp_path / "hist.jsonl"
        append_history(str(path), "bench_t", records, prov)
        append_history(str(path), "bench_t", records, prov)
        entries = load_history(str(path))
        assert len(entries) == 2
        assert entries[0]["bench"] == "bench_t"
        _, failures = compare_history(entries)
        assert failures == 0

    def test_bad_schema_line_rejected(self, tmp_path):
        path = tmp_path / "hist.jsonl"
        path.write_text('{"schema": "other", "records": []}\n')
        with pytest.raises(ValueError, match="schema"):
            load_history(str(path))


class TestRegressCli:
    def test_clean_ledger_exits_zero(self, tmp_path, capsys):
        path = tmp_path / "led.json"
        path.write_text(json.dumps(make_ledger().to_obj()))
        assert regress_main(["--ledger", str(path)]) == 0
        assert "PASS" in capsys.readouterr().out

    def test_seeded_misprediction_exits_nonzero(self, tmp_path, capsys):
        """THE acceptance check: build a ledger through the real API with a
        wrong prediction and the gate must hard-fail on it."""
        led = make_ledger(measured_bytes=1536)        # predicted 1000
        path = tmp_path / "led.json"
        path.write_text(json.dumps(led.to_obj()))
        lines, failures = check_ledger(str(path))
        assert failures == 1
        assert any("bytes_streamed" in li for li in lines)
        assert regress_main(["--ledger", str(path)]) == 1
        assert "FAIL" in capsys.readouterr().out

    def test_history_gate_exit_codes(self, tmp_path):
        path = tmp_path / "hist.jsonl"
        with open(path, "w") as f:
            f.write(json.dumps(_history_entry()) + "\n")
            f.write(json.dumps(_history_entry()) + "\n")
        assert regress_main(["--history", str(path)]) == 0
        with open(path, "a") as f:
            f.write(json.dumps(_history_entry(waves=5)) + "\n")
        assert regress_main(["--history", str(path)]) == 1

    def test_report_cli_renders_ledger(self, tmp_path, capsys):
        led = make_ledger()
        led.run["phase_seconds"] = {"driver": 2.0, "solve": 1.5}
        path = tmp_path / "led.json"
        path.write_text(json.dumps(led.to_obj()))
        assert report_main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "bytes_streamed" in out and "ok=True" in out
        assert "drift flags: none" in out


class TestVmemFootprints:
    def test_footprints_match_budget_docstring(self):
        """footprint_bytes reproduces the hand-derived MiB numbers the
        BUDGETS docstring records (the reprolint vmem rule's constants)."""
        from repro.kernels.budgets import BUDGETS, footprint_bytes

        mib = 2 ** 20
        cases = {
            "fused_herm_pallas": (dict(tm=8, tk=128, F=128), 2.53),
            "herm_hbm_accum": (dict(tm=8, tk=128, F=128), 2.023),
            "batch_solve_pallas": (dict(tb=8, F=128), 1.02),
            "sgd_tile_pallas": (dict(mb=1024, nb=1024, f=128), 5.02),
        }
        for name, (dims, want_mib) in cases.items():
            assert dims == {k: v for k, v in
                            BUDGETS[name].dim_bounds.items() if k != "K"}
            got = footprint_bytes(name, **dims)
            assert got / mib == pytest.approx(want_mib, abs=5e-3), name
            assert got <= BUDGETS[name].vmem_limit, name
        with pytest.raises(KeyError):
            footprint_bytes("no_such_kernel", tm=1)


def _streaming_als_run():
    from repro.core import als as als_mod
    from repro.core.partition import plan_for
    from repro.outofcore import (RatingStore, build_schedule,
                                 run_streaming_als)
    from repro.sparse import synth

    spec = synth.SynthSpec("obs-oc", 96, 40, 1500, 8, 0.05)
    r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
    store = RatingStore(r, q=4)
    acc_eps = spec.n * (spec.f * spec.f + 3 * spec.f + 1) * 4
    plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=4, n_data=2,
                    fill=store.worst_fill, eps=acc_eps, buffers=4,
                    hbm_bytes=1 << 22)
    sched = build_schedule(plan, spec.m, spec.n, n_data=2)
    assert len(sched.waves) >= 2
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=2, mode="ref")
    return run_streaming_als(store, sched, cfg)


@pytest.mark.slow
class TestStreamingLedgers:
    def test_als_ledger_validates_and_exact_records_hold(self):
        _, _, tel = _streaming_als_run()
        obj = tel.ledger
        summary = validate_ledger(obj)
        assert summary["ok"] and summary["errors"] == 0
        recs = {r["name"]: r for r in obj["records"]}
        # every exact record holds with zero drift: predicted streamed
        # bytes / pad slots / nnz came from shapes alone and matched
        for name in ("bytes_streamed", "padded_slots", "nnz_streamed"):
            assert recs[name]["check"] == "exact"
            assert recs[name]["ok"] and recs[name]["drift"] == 0.0
        assert recs["bytes_streamed"]["measured"] == tel.bytes_streamed
        # power-law fill: waste is real, measured, and under the plan bound
        assert tel.fill_waste_ratio > 1.0
        assert recs["fill_waste_ratio"]["ok"]
        assert recs["worst_fill_bound"]["check"] == "le"
        assert recs["worst_fill_bound"]["ok"]
        assert recs["peak_device_bytes"]["check"] == "le"
        assert recs["peak_device_bytes"]["measured"] == tel.peak_bytes
        # kernel launches stayed inside their static VMEM budgets
        assert recs["vmem/fused_herm_pallas"]["ok"]
        assert recs["vmem/batch_solve_pallas"]["ok"]
        assert obj["run"]["solver"] == "als"
        assert obj["run"]["waves"] >= 2 and obj["run"]["iterations"] == 2

    def test_sgd_ledger_validates(self):
        from repro.outofcore import (TileStore, build_sgd_schedule,
                                     run_streaming_sgd)
        from repro.sgd import SgdConfig, block_ell
        from repro.sparse import synth

        spec = synth.SynthSpec("obs-sgd", 96, 40, 1500, 8, 0.05)
        r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
        grid = block_ell(r, g=4)
        sched = build_sgd_schedule(grid, spec.f, n_workers=2)
        cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=2,
                        mode="ref", seed=1)
        _, _, tel = run_streaming_sgd(TileStore(grid), sched, cfg)
        obj = tel.ledger
        assert validate_ledger(obj)["ok"]
        recs = {r_["name"]: r_ for r_ in obj["records"]}
        for name in ("bytes_streamed", "padded_slots", "nnz_streamed"):
            assert recs[name]["ok"] and recs[name]["drift"] == 0.0
        # one full epoch touches every tile, so the grid fill IS the
        # measured waste — the bound is tight here, not just safe
        assert recs["worst_fill_bound"]["ok"]
        assert recs["fill_waste_ratio"]["ok"]
        assert recs["vmem/sgd_tile_pallas"]["ok"]
        assert obj["run"]["solver"] == "sgd"

    def test_emitted_ledger_file_gates_clean_then_fails_when_seeded(
            self, tmp_path):
        """End-to-end of the CI wiring: serialize a real run's ledger, gate
        it (exit 0); re-emit with one seeded mis-prediction through the
        same Ledger API and the gate must exit 1."""
        _, _, tel = _streaming_als_run()
        clean = tmp_path / "LEDGER_clean.json"
        clean.write_text(json.dumps(tel.ledger))
        assert regress_main(["--ledger", str(clean)]) == 0

        bad = Ledger(**tel.ledger["run"])
        for rec in tel.ledger["records"]:
            predicted = rec["predicted"]
            if rec["name"] == "bytes_streamed":
                predicted += 4096       # the seeded planner bug
            bad.record(rec["name"], predicted, rec["measured"],
                       unit=rec["unit"], check=rec["check"],
                       rel_tol=rec["rel_tol"], severity=rec["severity"])
        seeded = tmp_path / "LEDGER_seeded.json"
        seeded.write_text(json.dumps(bad.to_obj()))
        assert regress_main(["--ledger", str(seeded)]) == 1

    def test_hybrid_ledger_merges_both_phases(self):
        from repro.core import als as als_mod
        from repro.core.partition import plan_for
        from repro.outofcore import (RatingStore, TileStore, build_schedule,
                                     build_sgd_schedule)
        from repro.sgd import SgdConfig, block_ell, run_streaming_hybrid
        from repro.sparse import synth

        spec = synth.SynthSpec("obs-hy", 96, 40, 1500, 8, 0.05)
        r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
        store = RatingStore(r, q=4)
        acc_eps = spec.n * (spec.f * spec.f + 3 * spec.f + 1) * 4
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=1, q=4, n_data=2,
                        fill=store.worst_fill, eps=acc_eps, buffers=4,
                        hbm_bytes=1 << 22)
        als_sched = build_schedule(plan, spec.m, spec.n, n_data=2)
        grid = block_ell(r, g=4)
        sgd_sched = build_sgd_schedule(grid, spec.f, n_workers=2)
        als_cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1,
                                    mode="ref")
        sgd_cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=0.1, epochs=1,
                            mode="ref", seed=1)
        _, _, tel = run_streaming_hybrid(store, als_sched, TileStore(grid),
                                         sgd_sched, als_cfg, sgd_cfg)
        obj = tel.ledger
        assert validate_ledger(obj)["ok"]
        names = {rec["name"] for rec in obj["records"]}
        assert any(n.startswith("als/") for n in names)
        assert any(n.startswith("sgd/") for n in names)
        assert obj["run"]["als"]["solver"] == "als"
        assert obj["run"]["sgd"]["solver"] == "sgd"


@pytest.mark.mesh
def test_mesh_ledger_reduce_records_exact():
    """The acceptance run: --mesh 2,2-equivalent streaming on 8 forced
    host devices emits a validating ledger whose reduce fast/slow wire
    bytes are exact records that hold."""
    from tests.test_distributed import run_script

    out = run_script("""
import json
from repro.core import als as als_mod
from repro.core.partition import plan_for, streaming_acc_bytes
from repro.launch.mesh import make_mesh
from repro.obs.ledger import validate_ledger
from repro.outofcore import RatingStore, build_schedule, run_streaming_als
from repro.sparse import synth

n_data, p, q = 2, 2, 4
spec = synth.SynthSpec('netflix-mesh', 2048, 512, 80_000, 16, 0.05)
r, _, _, _ = synth.make_synthetic_ratings(spec, seed=0)
store = RatingStore(r, q=q, p=p)
plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=p, q=q, n_data=n_data,
                fill=store.worst_fill, eps=0, buffers=4,
                acc_bytes=streaming_acc_bytes(spec.n, spec.f))
sched = build_schedule(plan, spec.m, spec.n, n_data=n_data)
mesh = make_mesh((n_data, p), ('data', 'model'))
cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=2, mode='ref')
_, _, tel = run_streaming_als(store, sched, cfg, mesh=mesh)
obj = tel.ledger
summary = validate_ledger(obj)
assert summary['ok'], obj['flags']
recs = {rec['name']: rec for rec in obj['records']}
for name in ('reduce_fast_bytes', 'reduce_slow_bytes',
             'bytes_streamed', 'padded_slots', 'nnz_streamed'):
    assert recs[name]['check'] == 'exact', name
    assert recs[name]['ok'] and recs[name]['drift'] == 0.0, name
assert recs['reduce_fast_bytes']['measured'] == tel.reduce_fast_bytes
assert recs['reduce_slow_bytes']['measured'] == tel.reduce_slow_bytes
assert obj['run']['p'] == p and obj['run']['mesh'] is True
print('MESH_LEDGER_OK', summary['records'])
""")
    assert "MESH_LEDGER_OK" in out
