"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps via _propcheck (hypothesis when installed, a vendored
deterministic sweep otherwise); every kernel asserts allclose against the
ref.py oracle, per the repo contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.batch_solve import batch_solve_pallas
from repro.kernels.hermitian import fused_herm_pallas, herm_hbm_accum


def _problem(seed, m, n, K, f, frac_empty=0.2):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (m, K)), jnp.int32)
    cnt = jnp.asarray(
        np.where(rng.random(m) < frac_empty, 0, rng.integers(0, K + 1, m)),
        jnp.int32)
    val = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
    val = val * (jnp.arange(K)[None] < cnt[:, None])
    return theta, idx, val, cnt


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24]),
    n=st.sampled_from([16, 40]),
    K=st.sampled_from([8, 16, 32]),
    f=st.sampled_from([4, 8, 12, 16]),
    seed=st.integers(0, 100),
)
def test_fused_herm_matches_oracle(m, n, K, f, seed):
    theta, idx, val, cnt = _problem(seed, m, n, K, f)
    A0, B0 = ops.fused_herm(theta, idx, val, cnt, 0.05, mode="ref")
    A1, B1 = ops.fused_herm(theta, idx, val, cnt, 0.05,
                            mode="kernel_interpret", tm=8, tk=8, f_mult=8)
    np.testing.assert_allclose(A0, A1, atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(B0, B1, atol=2e-4, rtol=1e-4)


def test_fused_herm_weighted_lambda_diagonal():
    """A_u must carry lambda * n_u on the diagonal (paper eq. 2)."""
    theta, idx, val, cnt = _problem(3, 16, 32, 16, 8)
    lam = 0.7
    A, _ = ops.fused_herm(theta, idx, val, cnt, lam, mode="ref")
    g = jnp.take(theta, idx, axis=0)
    mask = kref.mask_from_cnt(cnt, idx.shape[1], jnp.float32)
    gm = g * mask[..., None]
    raw = jnp.einsum("ukf,ukg->ufg", gm, g)
    diag_expect = jnp.where(cnt > 0, lam * cnt.astype(jnp.float32), 1.0)
    got = jnp.diagonal(A - raw, axis1=1, axis2=2)
    np.testing.assert_allclose(
        got, jnp.broadcast_to(diag_expect[:, None], got.shape), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 16]),
    f=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 100),
)
def test_batch_solve_matches_oracle(m, f, seed):
    rng = np.random.default_rng(seed)
    L = rng.standard_normal((m, f, f)) * 0.3
    A = jnp.asarray(L @ np.transpose(L, (0, 2, 1))
                    + 2.0 * np.eye(f)[None], jnp.float32)
    B = jnp.asarray(rng.standard_normal((m, f)), jnp.float32)
    x0 = kref.batch_solve_ref(A, B)
    x1 = ops.batch_solve(A, B, mode="kernel_interpret", tb=8)
    np.testing.assert_allclose(x0, x1, atol=5e-4, rtol=5e-4)


def test_batch_solve_actually_solves():
    rng = np.random.default_rng(1)
    f, m = 12, 16
    L = rng.standard_normal((m, f, f)) * 0.4
    A = jnp.asarray(L @ np.transpose(L, (0, 2, 1)) + 3 * np.eye(f)[None],
                    jnp.float32)
    B = jnp.asarray(rng.standard_normal((m, f)), jnp.float32)
    x = ops.batch_solve(A, B, mode="kernel_interpret", tb=8)
    np.testing.assert_allclose(jnp.einsum("uij,uj->ui", A, x), B,
                               atol=1e-3, rtol=1e-3)


def test_hbm_accum_ablation_matches():
    """Fig. 7 ablation kernel computes the same result (it is only slower)."""
    theta, idx, val, cnt = _problem(7, 16, 40, 24, 8)
    A0, B0 = ops.fused_herm(theta, idx, val, cnt, 0.05, mode="ref")
    g = jnp.take(theta, idx, axis=0)
    mask = kref.mask_from_cnt(cnt, idx.shape[1], jnp.float32)
    diag = jnp.where(cnt > 0, 0.05 * cnt.astype(jnp.float32), 1.0)
    A1, B1 = herm_hbm_accum(g, val, mask, diag, tm=8, tk=8, interpret=True)
    np.testing.assert_allclose(A0, A1, atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(B0, B1, atol=2e-4, rtol=1e-4)


def test_padding_invariance():
    """fused_herm result must not depend on tile padding (tm/tk/f_mult)."""
    theta, idx, val, cnt = _problem(11, 24, 40, 24, 12)
    A0, B0 = ops.fused_herm(theta, idx, val, cnt, 0.05, mode="ref")
    for tm, tk, fm in [(8, 8, 8), (8, 16, 16), (16, 32, 32)]:
        A1, B1 = ops.fused_herm(theta, idx, val, cnt, 0.05,
                                mode="kernel_interpret", tm=tm, tk=tk,
                                f_mult=fm)
        np.testing.assert_allclose(A0, A1, atol=2e-4, rtol=1e-4)
        np.testing.assert_allclose(B0, B1, atol=2e-4, rtol=1e-4)


def test_als_update_factor_end_to_end():
    theta, idx, val, cnt = _problem(5, 16, 32, 16, 8)
    x_ref = kref.batch_solve_ref(*kref.fused_herm_gathered_ref(
        theta, idx, val, cnt, 0.05))
    x_kern = ops.als_update_factor(theta, idx, val, cnt, 0.05,
                                   mode="kernel_interpret",
                                   tm=8, tk=8, tb=8, f_mult=8)
    np.testing.assert_allclose(x_ref, x_kern, atol=2e-3, rtol=2e-3)
