"""Cell builders, input specs, HLO parsers, planner — pure-spec tests (no
multi-device work; everything here runs on the single CPU device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.core.partition import plan_partitions
from repro.distributed.collectives import collective_bytes_reduce
from repro.launch.dryrun import parse_collectives
from repro.models import transformer as T

ARCHS = registry.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_all_shapes(arch):
    cfg = registry.get_arch(arch).model
    for shape in SHAPES.values():
        specs = registry.input_specs(cfg, shape)
        if shape.kind in ("train", "prefill"):
            key = "embeds" if cfg.frontend else "tokens"
            assert key in specs
            assert specs[key].shape[0] == shape.batch
            assert specs[key].shape[1] == shape.seq
            if shape.kind == "train":
                assert specs["labels"].shape == (shape.batch, shape.seq)
        else:
            assert specs["lengths"].shape == (shape.batch,)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_shapes_match_analytic_count(arch):
    """Sum of parameter tensor sizes ~ the analytic params_count (within
    head/vocab padding slack)."""
    cfg = registry.get_arch(arch).model
    shapes = T.param_shapes(cfg)
    leaves = jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))
    total = sum(int(np.prod(s)) for s, _ in leaves)
    analytic = cfg.params_count()
    pad_slack = 1.0 + (cfg.padded_heads / cfg.n_heads - 1.0) + 0.05
    assert analytic * 0.995 <= total <= analytic * pad_slack * 1.15, \
        (arch, analytic, total)


def test_skip_rules():
    assert registry.get_arch("qwen3-4b").skip_reason(SHAPES["long_500k"])
    assert registry.get_arch("rwkv6-7b").skip_reason(SHAPES["long_500k"]) is None
    assert registry.get_arch("recurrentgemma-2b").skip_reason(
        SHAPES["long_500k"]) is None
    for a in ARCHS:
        assert registry.get_arch(a).skip_reason(SHAPES["train_4k"]) is None


def test_parse_collectives_wire_semantics():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(bf16[1,128] %p), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64] %q), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[4,8]{1,0} reduce-scatter(f32[16,8] %r), replica_groups=[64,4]<=[256], dimensions={0}
"""
    out = parse_collectives(hlo)
    assert out["count"] == {"all-gather": 1, "all-reduce": 1,
                            "reduce-scatter": 1}
    # all-gather: r*(g-1)/g with r=16*128*2, g=16
    np.testing.assert_allclose(out["bytes"]["all-gather"],
                               16 * 128 * 2 * 15 / 16)
    # all-reduce: 2*r*(g-1)/g with r=64*4, g=4
    np.testing.assert_allclose(out["bytes"]["all-reduce"],
                               2 * 64 * 4 * 3 / 4)
    # reduce-scatter: r*(g-1) with r=4*8*4, g=4
    np.testing.assert_allclose(out["bytes"]["reduce-scatter"],
                               4 * 8 * 4 * 3)


def test_two_phase_reduction_saves_slow_link():
    r = collective_bytes_reduce(1 << 30, p_fast=16, p_slow=2)
    assert r["hierarchical"]["slow_link"] < r["flat"]["slow_link"] / 4
    assert r["slow_link_saving"] == pytest.approx(8.0, rel=0.01)


def test_planner_all_table5_fit_one_pod():
    """Every Table-5 problem must have a feasible (p, q) plan on one pod."""
    from repro.sparse.synth import DATASETS
    for name, s in DATASETS.items():
        plan = plan_partitions(s.m, s.n, s.nnz, s.f)
        assert plan.fits, (name, plan.describe())


def test_padded_vocab_divisible():
    for a in ARCHS:
        cfg = registry.get_arch(a).model
        assert cfg.padded_vocab % 16 == 0
        if cfg.vocab >= 1024:
            assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab


def test_padded_kv_preserves_gqa_grouping():
    for a in ARCHS:
        cfg = registry.get_arch(a).model
        if cfg.attn_free:
            continue
        assert cfg.padded_heads % cfg.padded_kv == 0, a


def test_cache_specs_layout():
    cfg = registry.get_arch("qwen3-4b").model
    shape = SHAPES["decode_32k"]
    cache = registry.cache_specs(cfg, shape)
    groups = cache["blocks"][0]
    assert isinstance(groups, list) and len(groups) == cfg.n_layers
    k = groups[0]["0"]["k"]
    assert k.shape == (shape.batch, shape.seq, cfg.padded_kv, cfg.d_head)
    stacked = registry.cache_specs(cfg, shape, stacked=True)
    ks = stacked["blocks"][0]["0"]["k"]
    assert ks.shape == (cfg.n_layers,) + k.shape


def test_scan_groups_cover_all_layers():
    for a in ARCHS:
        cfg = registry.get_arch(a).model
        total = sum(len(pat) * rep for pat, rep in T.scan_groups(cfg))
        assert total == cfg.n_layers, a
