"""Train a small LM on the synthetic n-gram stream with the full substrate:
any of the 10 archs (reduced config), AdamW, grad accumulation,
checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 60
    PYTHONPATH=src python examples/train_lm.py --arch rwkv6-7b --steps 40

Loss drops well below the unigram entropy once the linear n-gram rule is
learned.  ``--width`` scales the model up (e.g. --width 512 --layers 8
gives a ~110M-param model for a longer run).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data.prefetch import Prefetcher
from repro.data.tokens import synthetic_lm_batches
from repro.models import lm as lm_mod
from repro.training.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    choices=registry.list_archs())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--width", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="constant",
                    choices=("constant", "inverse_time", "cosine"),
                    help="lr schedule over --steps (training/optimizer)")
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    if args.width:
        cfg = dataclasses.replace(
            cfg, d_model=args.width, d_ff=args.width * 3,
            d_head=args.width // max(cfg.n_heads, 1),
            d_rnn=args.width if cfg.d_rnn else None)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    n_params = cfg.params_count()
    print(f"arch={args.arch} params={n_params/1e6:.1f}M vocab={cfg.vocab}")

    opt = OptConfig(lr=args.lr, schedule=args.schedule,
                    schedule_steps=args.steps)
    state = lm_mod.init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(lm_mod.make_train_step(
        cfg, opt, microbatch=args.microbatch, remat=False))

    start = 0
    mgr = None
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt, keep=2)
        restored, start = mgr.restore_or_init(state, lambda: state)
        if start:
            state = jax.tree.map(jnp.asarray, restored)
            print(f"resumed from step {start}")

    stream = Prefetcher(
        synthetic_lm_batches(cfg.vocab, args.seq, args.batch, seed=0),
        depth=2)
    t0 = time.time()
    for i, batch in zip(range(start, args.steps), stream):
        if cfg.frontend:   # stub-frontend archs consume embeddings
            key = jax.random.fold_in(jax.random.PRNGKey(9), i)
            batch = dict(batch)
            batch["embeds"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model), jnp.float32)
            batch.pop("tokens")
        state, m = step(state, batch)
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i+1:4d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.2f}  "
                  f"({(time.time()-t0)/(i-start+1):.2f}s/step)", flush=True)
        if mgr and (i + 1) % 25 == 0:
            mgr.save(i + 1, state)
    if mgr:
        mgr.wait()
    print("done.")


if __name__ == "__main__":
    main()
