"""Quickstart: factorize a synthetic Netflix-like rating matrix with ALS.

    PYTHONPATH=src python examples/quickstart.py

~30 seconds on CPU.  Prints test RMSE per iteration (paper Fig. 6 protocol);
with the planted noise sigma=0.1 the oracle floor is ~0.1.
"""
import sys

from repro.core import als as als_mod
from repro.sparse import synth


def main():
    spec = synth.SynthSpec("netflix-quickstart", m=2048, n=512,
                           nnz=150_000, f=16, lam=0.05)
    print(f"synthesizing {spec.nnz} ratings ({spec.m}x{spec.n}, f={spec.f})")
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=0, noise=0.1)
    print(f"padded-ELL: K={r.K}, fill={r.fill:.2f}x")

    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=8, mode="ref")
    _, hist = als_mod.als_train(
        als_mod.ell_triplet(r), als_mod.ell_triplet(rt), r.m, rt.m, cfg,
        test=als_mod.ell_triplet(rte),
        callback=lambda st, rec: print(
            f"iter {rec['iteration']:2d}  train_rmse={rec['train_rmse']:.4f}"
            f"  test_rmse={rec['test_rmse']:.4f}"))
    assert hist[-1]["test_rmse"] < 0.3, "did not converge"
    print("converged.")


if __name__ == "__main__":
    main()
