"""Serve a small model with batched requests through the continuous-
batching engine (slot admission, ragged lengths, KV cache reuse).

    PYTHONPATH=src python examples/serve_lm.py --arch phi3-mini-3.8b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as T
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b",
                    choices=registry.list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    if cfg.frontend:
        raise SystemExit("stub-frontend archs serve embeddings; pick a "
                         "token arch for this demo")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=args.slots, max_seq=96)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(3, 9))
        req = Request(rid=i, prompt=prompt.astype(np.int32),
                      max_new_tokens=args.new_tokens)
        reqs.append(req)
        eng.submit(req)
        print(f"req {i}: prompt={prompt.tolist()}")

    t0 = time.time()
    steps = 0
    while eng.step():
        steps += 1
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: generated={r.out}")
    print(f"{total} tokens in {dt:.2f}s over {steps} engine steps "
          f"({total/dt:.1f} tok/s, {args.slots} slots)")


if __name__ == "__main__":
    main()
