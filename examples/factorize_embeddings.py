"""Apply the paper's technique to an LM: ALS-factorize an embedding table.

The vocab x d_model embedding of an LM is the one large matrix the cuMF
solver applies to directly (DESIGN.md §Arch-applicability): factor
E ~ X . Theta^T with rank f << d, giving a (vocab x f + f x d) compressed
embedding.  Dense factorization is the K = d special case of the padded-ELL
path, so the exact production kernels run unmodified.

    PYTHONPATH=src python examples/factorize_embeddings.py --arch recurrentgemma-2b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import als as als_mod
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b",
                    choices=registry.list_archs())
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--iters", type=int, default=6)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    emb = np.asarray(params["embed"], np.float32)      # [V, d]
    V, d = emb.shape
    print(f"{args.arch}: embedding {V}x{d}, rank {args.rank} "
          f"-> {(V*args.rank + args.rank*d) / (V*d):.1%} of original size")

    # dense matrix as PaddedELL: every row rates every column
    idx = np.broadcast_to(np.arange(d, dtype=np.int32)[None], (V, d)).copy()
    val = emb
    cnt = np.full((V,), d, np.int32)
    idxT = np.broadcast_to(np.arange(V, dtype=np.int32)[None], (d, V)).copy()
    valT = emb.T.copy()
    cntT = np.full((d,), V, np.int32)

    cfg_als = als_mod.AlsConfig(f=args.rank, lam=1e-3, iters=1, mode="ref")
    st = als_mod.als_init(V, d, cfg_als)
    r = (jnp.asarray(idx), jnp.asarray(val), jnp.asarray(cnt))
    rt = (jnp.asarray(idxT), jnp.asarray(valT), jnp.asarray(cntT))
    base = float(jnp.sqrt(jnp.mean(jnp.square(jnp.asarray(emb)))))
    for it in range(args.iters):
        st = als_mod.als_iteration(st, r, rt, cfg_als)
        recon = st.x @ st.theta.T
        err = float(jnp.sqrt(jnp.mean(jnp.square(recon - emb))))
        print(f"iter {it+1}: recon RMSE={err:.5f} (rms(E)={base:.5f}, "
              f"relative {err/base:.2%})")
    print("factorized embedding ready: E ~ X @ Theta^T")


if __name__ == "__main__":
    main()
