"""End-to-end driver: ALS matrix factorization at (scaled) Netflix size,
with q-batching, checkpointing, and restart — the paper's workload.

    PYTHONPATH=src python examples/train_als_netflix.py          # ~minutes
    PYTHONPATH=src python examples/train_als_netflix.py --small  # ~30 s

The default run factorizes m=120k x n=17770 with f=32 (a ~4.4M-parameter
factor model; pass --full for the true 480k-row Netflix shape, ~100M model
parameters at f=100 as in the paper — CPU-hours).  Kills mid-run resume
from the latest checkpoint automatically.
"""
import argparse
import os
import time

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import als as als_mod
from repro.core.partition import plan_partitions
from repro.sparse import synth


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--ckpt", default="/tmp/cumf_ckpt")
    args = ap.parse_args()

    if args.full:
        spec = synth.SynthSpec("netflix", 480_189, 17_770, 99_000_000,
                               100, 0.05)
    elif args.small:
        spec = synth.SynthSpec("netflix-small", 8_192, 2_048, 400_000,
                               16, 0.05)
    else:
        spec = synth.SynthSpec("netflix-scaled", 122_880, 17_770,
                               6_000_000, 32, 0.05)

    plan = plan_partitions(spec.m, spec.n, spec.nnz, spec.f)
    print(f"partition plan (eq. 8): {plan.describe()}")

    t0 = time.time()
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=0, noise=0.1)
    print(f"synthesized {r.nnz} ratings in {time.time()-t0:.1f}s "
          f"(K={r.K}, fill={r.fill:.2f}x)")

    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1, mode="ref",
                            batch_rows=16_384)
    mgr = CheckpointManager(args.ckpt, keep=2)
    state, start = mgr.restore_or_init(
        {"x": jnp.zeros((r.m, spec.f)), "theta": jnp.zeros((rt.m, spec.f))},
        lambda: None)
    if start:
        print(f"resuming from checkpoint at iteration {start}")
        st = als_mod.AlsState(x=jnp.asarray(state["x"]),
                              theta=jnp.asarray(state["theta"]),
                              iteration=jnp.int32(start))
    else:
        st = als_mod.als_init(r.m, rt.m, cfg)

    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    from repro.core.objective import rmse_padded
    for it in range(start, args.iters):
        t0 = time.time()
        st = als_mod.als_iteration(st, rr, rtt, cfg)
        rmse = float(rmse_padded(st.x, st.theta, *rtest))
        print(f"iter {it+1:2d}  test_rmse={rmse:.4f}  "
              f"({time.time()-t0:.1f}s)", flush=True)
        mgr.save(it + 1, {"x": st.x, "theta": st.theta})  # async (paper §4.4)
    mgr.wait()
    print(f"done; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
