"""End-to-end driver: ALS matrix factorization at (scaled) Netflix size,
with q-batching, checkpointing, and restart — the paper's workload.

    PYTHONPATH=src python examples/train_als_netflix.py          # ~minutes
    PYTHONPATH=src python examples/train_als_netflix.py --small  # ~30 s

The default run factorizes m=120k x n=17770 with f=32 (a ~4.4M-parameter
factor model; pass --full for the true 480k-row Netflix shape, ~100M model
parameters at f=100 as in the paper — CPU-hours).  Kills mid-run resume
from the latest checkpoint automatically.

``--out-of-core`` switches to the §4.4 wave-streaming driver: the rating
matrix stays host-resident (both orientations), a capped simulated device
(``--device-mb``) forces a waves >= 2 plan, and each wave double-buffers its
shards while the previous one computes, checkpointing per wave:

    PYTHONPATH=src python examples/train_als_netflix.py --small \
        --out-of-core --device-mb 8

Solver selection (``--solver {als,sgd,hybrid}``):

- ``als``    (default) — the paper's memory-optimized ALS: each sweep is a
  closed-form fused-Hermitian + batched-Cholesky solve.  Most progress per
  iteration, most expensive per iteration.
- ``sgd``    — CuMF_SGD-style blocked batch-Hogwild SGD: the ratings are
  partitioned into a ``--g`` x ``--g`` block grid and each epoch walks the
  g conflict-free diagonal block-sets.  Much cheaper per epoch (no f^2
  Hermitian, no solves); needs more epochs and an lr schedule
  (``--sgd-lr``, cosine by default).
- ``hybrid`` — ALS warm start (``--iters`` sweeps) then SGD refinement
  (``--epochs``) on the same shards: ALS's fast early convergence at its
  per-iteration price only while it pays, then cheap SGD epochs to the
  floor.

    PYTHONPATH=src python examples/train_als_netflix.py --small --solver sgd
    PYTHONPATH=src python examples/train_als_netflix.py --small \
        --solver hybrid --iters 2 --epochs 16

``--out-of-core`` composes with every solver (the wave scheduler is
solver-generic — schedules are built from abstract wave work items):

  =========  ==============================================================
  solver     what streams through the capped device
  =========  ==============================================================
  ``als``    R row slices (solve-X half), R^T shards + fresh X slices
             (accumulate-Theta half) — ``run_streaming_als``
  ``sgd``    diagonal-set tile waves of the g x g block grid, up to
             ``--n-data`` tiles per wave, per-epoch shuffled set order —
             ``run_streaming_sgd``
  ``hybrid`` both in sequence under the same budget: streamed ALS warm
             start, then streamed SGD refinement —
             ``run_streaming_hybrid``
  =========  ==============================================================

    PYTHONPATH=src python examples/train_als_netflix.py --small \
        --out-of-core --solver sgd --g 4 --n-data 2
    PYTHONPATH=src python examples/train_als_netflix.py --small \
        --out-of-core --solver hybrid --iters 2 --epochs 16

``--mesh DATA,MODEL`` (requires ``--out-of-core``) runs the waves on a
*real* ``(data, model)`` device mesh instead of one simulated device:
``--mesh 2,2`` streams each wave's batches across 2 data-axis devices with
theta held as p = 2 model shards (each device materializes only its
``[n/p, f]`` shard plus its column block of the wave's R slice), solve-X
waves dispatch through the shard-mapped SU-ALS update, and the
accumulate-Theta partial Hermitians are combined per data shard by the
topology-aware staged reduction (``distributed.reduce``).  The data-axis
size overrides ``--n-data``.  On CPU, force enough host devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_als_netflix.py --small \
        --out-of-core --mesh 2,2 --device-mb 8

``--mesh`` composes with every solver: ``sgd``/``hybrid`` shard each tile
wave one-tile-per-device over the joint (data, model) axes.
"""
import argparse
import os
import time

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import als as als_mod
from repro.core.partition import plan_for, plan_partitions
from repro.sparse import synth


def _build_mesh(args):
    """--mesh DATA,MODEL -> (Mesh, p); (None, 1) when not requested."""
    if not getattr(args, "mesh", None):
        return None, 1
    import jax

    from repro.launch.mesh import make_mesh

    d, p = (int(x) for x in args.mesh.split(","))
    ndev = len(jax.devices())
    if ndev < d * p:
        raise SystemExit(
            f"--mesh {args.mesh} needs {d * p} devices but only {ndev} "
            f"visible; on CPU export XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={d * p} (or more) first")
    args.n_data = d                # the data axis IS the wave width
    print(f"mesh: data={d} x model={p} on {ndev} visible devices")
    return make_mesh((d, p), ("data", "model")), p


def _tune_cache(args):
    """--autotune: a persistent TuneCache under the checkpoint dir, so a
    restarted run reuses the sweep's winner instead of re-sweeping."""
    if not getattr(args, "autotune", False):
        return None
    os.makedirs(args.ckpt, exist_ok=True)
    return os.path.join(args.ckpt, "tune_cache.json")


def _als_store_and_schedule(spec, r, args, p=1):
    """Capped-capacity ALS wave plan: store + schedule (shared with hybrid)."""
    from repro.core.partition import streaming_acc_bytes
    from repro.outofcore import (RatingStore, build_schedule,
                                 required_capacity_bytes)

    cap = args.device_mb << 20
    if p == 1:
        plan = plan_partitions(spec.m, spec.n, r.nnz, spec.f, hbm_bytes=cap,
                               n_data=args.n_data, fill=r.fill, eps=cap // 8)
    if p > 1 or plan.waves < 2:   # force waves >= 2 (and the requested p)
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=p,
                        q=2 * args.n_data, n_data=args.n_data,
                        hbm_bytes=cap, fill=r.fill, eps=cap // 8, buffers=4)

    if spec.n % p:
        raise SystemExit(f"n={spec.n} is not divisible by the model axis "
                         f"size p={p}; pick a p that divides n")
    n_bins = "auto" if args.autotune else 1
    store = RatingStore(r, q=plan.q, p=p, n_bins=n_bins,
                        tune_cache=_tune_cache(args))
    if store.tune is not None:
        print(f"autotune: n_bins={store.n_bins} "
              f"k_multiple={store.tune['config']['k_multiple']} "
              f"predicted {store.tune['score']} {store.tune['unit']}/iter "
              f"(cache_{'hit' if store.tune['cache_hit'] else 'miss'})")
    # re-cost the chosen (p, q) with the store's real padding fills and the
    # double-buffer count (depth=2 queued + loader-held + consumed): that
    # total is the budget the meter reports against.  p > 1 prices the
    # Hermitian accumulators as their own p-sharded term; a binned store
    # prices its per-bin pairs instead of the scalar worst fill.
    fill_kw = (dict(bin_fills=store.bin_fill_pairs()) if store.n_bins > 1
               else dict(fill=store.worst_fill))
    if p > 1:
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=p, q=plan.q,
                        n_data=args.n_data, hbm_bytes=cap,
                        eps=cap // 8, buffers=4,
                        acc_bytes=streaming_acc_bytes(spec.n, spec.f),
                        **fill_kw)
    else:
        acc_eps = spec.n * (spec.f * spec.f + 3 * spec.f + 1) * 4
        plan = plan_for(spec.m, spec.n, r.nnz, spec.f, p=plan.p, q=plan.q,
                        n_data=args.n_data, hbm_bytes=cap,
                        eps=acc_eps, buffers=4, **fill_kw)
    print(f"out-of-core plan: {plan.describe()}")
    sched = build_schedule(plan, spec.m, spec.n, n_data=args.n_data)
    need = required_capacity_bytes(store, sched, spec.f)
    print(f"schedule: {sched.describe()} "
          f"(driver needs {need / 2**20:.1f}MiB/device)")
    return store, sched


def _sgd_tiles_and_schedule(spec, r, args):
    """Tile-wave plan: --n-data simulated workers stream the g x g grid
    against the --device-mb budget."""
    from repro.outofcore import (TileStore, build_sgd_schedule,
                                 sgd_required_capacity_bytes)
    from repro.sgd import block_ell

    grid = block_ell(r, g=args.g,
                     per_tile_k="auto" if args.autotune else False,
                     tune_cache=_tune_cache(args))
    if grid.tune is not None:
        print(f"autotune: per_tile_k={grid.tune['config']['per_tile_k']} "
              f"degree_sort={grid.tune['config']['degree_sort']} "
              f"({grid.tune['score']} dispatched slots, "
              f"cache_{'hit' if grid.tune['cache_hit'] else 'miss'})")
    print(f"block grid: g={grid.g} mb={grid.mb} nb={grid.nb} K={grid.K} "
          f"fill={grid.fill:.2f}x")
    cap = args.device_mb << 20
    need = sgd_required_capacity_bytes(grid.mb, grid.nb, grid.K, spec.f)
    if need > cap:
        print(f"WARNING: one worker's tile pipeline needs "
              f"{need/2**20:.1f}MiB > --device-mb {args.device_mb}MiB; "
              f"raise --device-mb or --g (smaller tiles)")
    sched = build_sgd_schedule(grid, spec.f, n_workers=args.n_data,
                               capacity_bytes=cap)
    print(f"schedule: {sched.describe()} "
          f"(driver needs {need/2**20:.1f}MiB/worker)")
    return TileStore(grid), sched


def _tel_summary(tel, ckpt):
    return (f"done in {tel.wall_seconds:.1f}s; resumed_from_step="
            f"{tel.resumed_from_step}; peak {tel.peak_bytes/2**20:.1f}MiB of "
            f"{tel.capacity_bytes/2**20:.1f}MiB budget; "
            f"{tel.bytes_streamed/2**20:.1f}MiB streamed over {tel.waves_run} "
            f"waves; checkpoints in {ckpt}")


def _emit_ledger(tel, args):
    """--ledger OUT.json: serialize the run's plan-vs-actual ledger and
    print the rendered report (the same text `python -m repro.obs.report`
    produces from the file)."""
    if not getattr(args, "ledger", None):
        return
    import json

    from repro.obs.report import render_ledger

    with open(args.ledger, "w") as f:
        json.dump(tel.ledger, f, indent=2)
    print(f"ledger: {len(tel.ledger['records'])} plan-vs-actual records "
          f"-> {args.ledger}")
    print(render_ledger(tel.ledger))


def run_out_of_core(spec, r, rte, args):
    """Wave-streaming path, all solvers (see the module docstring matrix)."""
    rtest = als_mod.ell_triplet(rte)
    mesh, p = _build_mesh(args)

    if args.solver == "als":
        from repro.outofcore import run_streaming_als
        store, sched = _als_store_and_schedule(spec, r, args, p=p)
        cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=args.iters,
                                mode="ref", batch_rows=16_384)

        def progress(it, rec):
            print(f"iter {it+1:2d}  "
                  f"test_rmse={rec.get('test_rmse', float('nan')):.4f}  "
                  f"waves={rec['waves_run']}  "
                  f"peak={rec['peak_bytes']/2**20:.1f}MiB", flush=True)

        # solver-scoped ckpt dir: the streaming tree (factors + Hermitian
        # accumulators) is shaped differently from the in-core ALS one
        ckpt = os.path.join(args.ckpt, "oc_als")
        _, _, tel = run_streaming_als(store, sched, cfg, ckpt_dir=ckpt,
                                      test_eval=rtest, mesh=mesh,
                                      callback=progress)
        print(_tel_summary(tel, ckpt))
        if mesh is not None:
            print(f"reduction {tel.topology}: "
                  f"{tel.reduce_fast_bytes/2**20:.2f}MiB fast-link, "
                  f"{tel.reduce_slow_bytes/2**20:.2f}MiB slow-link")
        _emit_ledger(tel, args)
        return

    def progress(_state, rec):
        tag = rec.get("phase", args.solver)
        step = rec.get("epoch", rec.get("iteration"))
        print(f"{tag} {step:3d}  "
              f"test_rmse={rec.get('test_rmse', float('nan')):.4f}  "
              f"waves={rec.get('waves_run', '-')}  "
              f"peak={rec.get('peak_bytes', 0)/2**20:.1f}MiB", flush=True)

    sgd_cfg_kw = dict(f=spec.f, lam=spec.lam, lr=args.sgd_lr,
                      epochs=args.epochs, schedule=args.schedule, mode="ref")
    # solver-scoped ckpt dir: the trees differ per solver (see run_sgd)
    ckpt = os.path.join(args.ckpt, "oc_" + args.solver)
    if args.solver == "sgd":
        from repro.outofcore import run_streaming_sgd
        from repro.sgd import SgdConfig
        tiles, sched = _sgd_tiles_and_schedule(spec, r, args)
        _, _, tel = run_streaming_sgd(tiles, sched, SgdConfig(**sgd_cfg_kw),
                                      ckpt_dir=ckpt, test_eval=rtest,
                                      mesh=mesh, callback=progress)
        print(_tel_summary(tel, ckpt))
        _emit_ledger(tel, args)
    else:                       # hybrid: both phases stream
        from repro.sgd import SgdConfig, run_streaming_hybrid
        store, als_sched = _als_store_and_schedule(spec, r, args, p=p)
        tiles, sgd_sched = _sgd_tiles_and_schedule(spec, r, args)
        warm = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=args.iters,
                                 mode="ref", batch_rows=16_384)
        _, _, tel = run_streaming_hybrid(
            store, als_sched, tiles, sgd_sched, warm, SgdConfig(**sgd_cfg_kw),
            ckpt_dir=ckpt, test_eval=rtest, mesh=mesh, callback=progress)
        print("[hybrid] " + _tel_summary(tel, ckpt))
        for name, part in sorted(tel.phases.items()):
            print(f"  [{name}] " + _tel_summary(part, ckpt))
        _emit_ledger(tel, args)


def run_sgd(spec, r, rt, rte, args):
    """Blocked batch-Hogwild SGD / ALS->SGD hybrid (see module docstring)."""
    from repro.core import als as als_mod
    from repro.sgd import SgdConfig, block_ell, hybrid_train, sgd_train

    grid = block_ell(r, g=args.g,
                     per_tile_k="auto" if args.autotune else False,
                     tune_cache=_tune_cache(args))
    if grid.tune is not None:
        print(f"autotune: per_tile_k={grid.tune['config']['per_tile_k']} "
              f"degree_sort={grid.tune['config']['degree_sort']} "
              f"({grid.tune['score']} dispatched slots)")
    print(f"block grid: g={grid.g} mb={grid.mb} nb={grid.nb} K={grid.K} "
          f"fill={grid.fill:.2f}x")
    sgd_cfg = SgdConfig(f=spec.f, lam=spec.lam, lr=args.sgd_lr,
                        epochs=args.epochs, schedule=args.schedule,
                        mode="ref")
    # solver-scoped checkpoint dir: the ALS / out-of-core paths write
    # differently-shaped trees into args.ckpt, and resuming a finished
    # run must not be misread as a fresh one
    ckpt = os.path.join(args.ckpt, args.solver)
    rtest = als_mod.ell_triplet(rte)

    def progress(_state, rec):
        tag = rec.get("phase", "sgd")
        step = rec.get("epoch", rec.get("iteration"))
        lr = f"  lr={rec['lr']:.4f}" if "lr" in rec else ""
        print(f"{tag} {step:3d}  "
              f"test_rmse={rec.get('test_rmse', float('nan')):.4f}{lr}",
              flush=True)

    t0 = time.time()
    if args.solver == "hybrid":
        warm = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=args.iters,
                                 mode="ref", batch_rows=16_384)
        rr, rtt = als_mod.ell_triplet(r), als_mod.ell_triplet(rt)
        _, hist = hybrid_train(rr, rtt, grid, warm, sgd_cfg, test=rtest,
                               ckpt_dir=ckpt, callback=progress)
    else:
        _, hist = sgd_train(grid, sgd_cfg, test=rtest, ckpt_dir=ckpt,
                            callback=progress)
    final = (f"final test_rmse={hist[-1]['test_rmse']:.4f}" if hist
             else f"already complete at epoch {sgd_cfg.epochs} (resume)")
    print(f"done in {time.time()-t0:.1f}s; {final}; checkpoints in {ckpt}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--solver", choices=("als", "sgd", "hybrid"),
                    default="als", help="see module docstring")
    ap.add_argument("--epochs", type=int, default=30,
                    help="SGD epochs (sgd / hybrid solvers)")
    ap.add_argument("--sgd-lr", type=float, default=0.15)
    ap.add_argument("--schedule", default="cosine",
                    choices=("constant", "inverse_time", "cosine"))
    ap.add_argument("--g", type=int, default=4,
                    help="block-grid side for the SGD solvers")
    ap.add_argument("--ckpt", default="/tmp/cumf_ckpt")
    ap.add_argument("--autotune", action="store_true",
                    help="pick the layout knobs (ALS n_bins/k_multiple, "
                         "SGD per_tile_k/degree_sort) by the cuMF Alg.-2 "
                         "sweep (repro.core.autotune); the winner is "
                         "cached under --ckpt (see TUNING.md)")
    ap.add_argument("--out-of-core", action="store_true",
                    help="stream waves through a capped simulated device")
    ap.add_argument("--device-mb", type=int, default=64,
                    help="simulated device capacity for --out-of-core")
    ap.add_argument("--n-data", type=int, default=2,
                    help="simulated data-axis size (batches per wave)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="run the --out-of-core waves on a real (data, "
                         "model) device mesh, e.g. --mesh 2,2 (p=2 theta "
                         "shards + topology-aware reduction); overrides "
                         "--n-data with the data-axis size")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record obs spans for the whole run and write a "
                         "Chrome-trace/Perfetto JSON file (load it at "
                         "ui.perfetto.dev)")
    ap.add_argument("--ledger", default=None, metavar="OUT.json",
                    help="with --out-of-core: write the run's plan-vs-"
                         "actual ledger (predicted vs measured peaks, "
                         "streamed/reduce bytes, fill waste) and print the "
                         "repro.obs.report rendering")
    args = ap.parse_args()
    if args.ledger and not args.out_of_core:
        ap.error("--ledger requires --out-of-core (only the streaming "
                 "drivers emit plan-vs-actual ledgers)")
    if args.mesh and not args.out_of_core:
        # checked here, not in _build_mesh: the in-core paths never reach
        # _build_mesh, and silently ignoring --mesh would let a user think
        # they measured the mesh path
        ap.error("--mesh requires --out-of-core (the in-core paths use "
                 "their own sharding entry points)")

    tracer = None
    if args.trace:
        from repro.obs import Tracer, set_tracer
        tracer = Tracer()
        set_tracer(tracer)      # the drivers pick it up via current_tracer

    if args.full:
        spec = synth.SynthSpec("netflix", 480_189, 17_770, 99_000_000,
                               100, 0.05)
    elif args.small:
        spec = synth.SynthSpec("netflix-small", 8_192, 2_048, 400_000,
                               16, 0.05)
    else:
        spec = synth.SynthSpec("netflix-scaled", 122_880, 17_770,
                               6_000_000, 32, 0.05)

    plan = plan_partitions(spec.m, spec.n, spec.nnz, spec.f)
    print(f"partition plan (eq. 8): {plan.describe()}")

    t0 = time.time()
    r, rt, rte, _ = synth.make_synthetic_ratings(spec, seed=0, noise=0.1)
    print(f"synthesized {r.nnz} ratings in {time.time()-t0:.1f}s "
          f"(K={r.K}, fill={r.fill:.2f}x)")

    try:
        if args.out_of_core:
            run_out_of_core(spec, r, rte, args)
            return
        if args.solver != "als":
            run_sgd(spec, r, rt, rte, args)
            return
        run_incore_als(spec, r, rt, rte, args)
    finally:
        if tracer is not None:
            from repro.obs import write_trace
            write_trace(args.trace, tracer)
            print(f"trace: {len(tracer.events)} events -> {args.trace} "
                  f"(load at ui.perfetto.dev)")


def run_incore_als(spec, r, rt, rte, args):
    """The in-core paper loop: full-matrix ALS sweeps with checkpointing."""
    cfg = als_mod.AlsConfig(f=spec.f, lam=spec.lam, iters=1, mode="ref",
                            batch_rows=16_384)
    mgr = CheckpointManager(args.ckpt, keep=2)
    state, start = mgr.restore_or_init(
        {"x": jnp.zeros((r.m, spec.f)), "theta": jnp.zeros((rt.m, spec.f))},
        lambda: None)
    if start:
        print(f"resuming from checkpoint at iteration {start}")
        st = als_mod.AlsState(x=jnp.asarray(state["x"]),
                              theta=jnp.asarray(state["theta"]),
                              iteration=jnp.int32(start))
    else:
        st = als_mod.als_init(r.m, rt.m, cfg)

    rr, rtt, rtest = (als_mod.ell_triplet(e) for e in (r, rt, rte))
    from repro.core.objective import rmse_padded
    for it in range(start, args.iters):
        t0 = time.time()
        st = als_mod.als_iteration(st, rr, rtt, cfg)
        rmse = float(rmse_padded(st.x, st.theta, *rtest))
        print(f"iter {it+1:2d}  test_rmse={rmse:.4f}  "
              f"({time.time()-t0:.1f}s)", flush=True)
        mgr.save(it + 1, {"x": st.x, "theta": st.theta})  # async (paper §4.4)
    mgr.wait()
    print(f"done; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
