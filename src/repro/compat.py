"""Version-compatibility shims for every version-sensitive JAX surface.

This module is the *only* place in the repo allowed to reference JAX APIs
that were renamed, added, or removed across the versions we support
(floor: JAX 0.4.37, the pinned CI environment).  Everything else imports
from here, so the next API drift is a one-file fix plus a green
import-sweep test — not a call-site hunt.

Shimmed surfaces (see tests/test_compat.py for both branches of each):

- ``AxisType`` / ``axis_types=`` mesh construction: ``jax.sharding.AxisType``
  and the ``axis_types`` kwarg of ``jax.make_mesh`` appeared after 0.4.37;
  :func:`make_mesh` passes them through when present and silently drops
  them when not (all axes are Auto on old JAX anyway).
- ``shard_map``: new JAX exposes ``jax.shard_map(..., axis_names=,
  check_vma=)``; 0.4.37 only has ``jax.experimental.shard_map.shard_map(...,
  auto=, check_rep=)``.  :func:`shard_map` accepts the *new* vocabulary and
  translates (``axis_names`` = manual axes -> ``auto`` = mesh axes minus
  manual; ``check_vma`` -> ``check_rep``).
- ``pltpu.CompilerParams``: renamed from ``TPUCompilerParams``;
  :func:`tpu_compiler_params` resolves whichever exists and drops kwargs
  the resolved dataclass doesn't know.
- ``pallas_call``: :func:`pallas_call` transparently degrades to
  ``interpret=True`` when the default backend has no Mosaic compiler
  (CPU-only hosts), so the kernel path runs everywhere tests run.
- ``pltpu.VMEM`` scratch allocation via :func:`vmem`, gated on the
  ``jax.experimental.pallas.tpu`` import itself succeeding.

All resolution happens through module-level attributes looked up at call
time, so tests can monkeypatch a branch (present / absent) without owning
a second JAX install.
"""
from __future__ import annotations

import inspect
import re
from typing import Any

import jax
from jax.experimental import pallas as pl

try:  # absent on builds without the Mosaic/TPU pallas backend
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:  # pragma: no cover - present on every pinned CI env
    _pltpu = None

try:
    from jax.sharding import AxisType as _axis_type
except ImportError:
    _axis_type = None

# New-style shard_map (axis_names/check_vma vocabulary).
_NEW_SHARD_MAP = getattr(jax, "shard_map", None)

# Legacy shard_map (auto/check_rep vocabulary); removed in newest JAX.
try:
    from jax.experimental.shard_map import shard_map as _LEGACY_SHARD_MAP
except ImportError:  # pragma: no cover - still present on 0.4.37
    _LEGACY_SHARD_MAP = None

_JAX_MAKE_MESH = getattr(jax, "make_mesh", None)
_PALLAS_CALL = pl.pallas_call

# Public probe results (read-only convenience; the functions below re-derive
# their branch from the module attributes so monkeypatching works).
AxisType = _axis_type
HAS_AXIS_TYPE = _axis_type is not None


def jax_version() -> tuple[int, ...]:
    """``jax.__version__`` as a comparable int tuple (rc/dev tags dropped)."""
    return tuple(int(p) for p in re.findall(r"\d+", jax.__version__)[:3])


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def _make_mesh_kwargs(fn) -> set:
    try:
        return set(inspect.signature(fn).parameters)
    except (TypeError, ValueError):  # pragma: no cover - C-level callables
        return set()


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types="auto"):
    """Build a ``Mesh`` portably.

    ``axis_types``: ``"auto"`` / ``"explicit"`` / ``None``.  Honored only
    when both ``jax.sharding.AxisType`` and the ``axis_types`` kwarg of
    ``jax.make_mesh`` exist; on older JAX every axis is implicitly Auto,
    which is exactly what this repo's meshes want, so dropping the kwarg
    is semantics-preserving.
    """
    if axis_types not in (None, "auto", "explicit"):
        raise ValueError(
            f"axis_types must be 'auto', 'explicit', or None; got "
            f"{axis_types!r}")
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    fn = _JAX_MAKE_MESH
    if fn is not None:
        if (axis_types is not None and AxisType is not None
                and "axis_types" in _make_mesh_kwargs(fn)):
            kind = {"auto": AxisType.Auto,
                    "explicit": AxisType.Explicit}[axis_types]
            return fn(axis_shapes, axis_names, devices=devices,
                      axis_types=(kind,) * len(axis_names))
        return fn(axis_shapes, axis_names, devices=devices)
    # Pre-``jax.make_mesh`` fallback: plain Mesh over a device grid.  Like
    # jax.make_mesh, take the first prod(axis_shapes) devices when none are
    # given (create_device_mesh requires an exact count).
    from jax.experimental import mesh_utils
    if devices is None:
        n = 1
        for s in axis_shapes:
            n *= s
        devices = jax.devices()[:n]
    devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return jax.sharding.Mesh(devs, axis_names)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """``shard_map`` in the new vocabulary, on any supported JAX.

    ``axis_names``: mesh axes to run manually (None = all of them —
    fully-manual, the new API's default).  ``check_vma`` maps onto the
    legacy ``check_rep``.
    """
    if _NEW_SHARD_MAP is not None:
        kwargs: dict[str, Any] = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma,
                              **kwargs)
    if _LEGACY_SHARD_MAP is None:  # pragma: no cover
        raise ImportError(
            "no shard_map implementation found in this JAX install "
            f"({jax.__version__}); need jax.shard_map or "
            "jax.experimental.shard_map")
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _LEGACY_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             auto=auto)


# ---------------------------------------------------------------------------
# Pallas
# ---------------------------------------------------------------------------

def has_pallas_tpu() -> bool:
    return _pltpu is not None


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` under either of its historical names.

    Unknown kwargs are dropped (the param set also drifts between
    versions); returns None when no TPU pallas backend is importable, which
    ``pallas_call`` accepts.
    """
    if _pltpu is None:
        return None
    cls = getattr(_pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(_pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    try:
        known = set(inspect.signature(cls).parameters)
        kwargs = {k: v for k, v in kwargs.items() if k in known}
    except (TypeError, ValueError):  # pragma: no cover
        pass
    return cls(**kwargs)


def vmem(shape, dtype):
    """A VMEM scratch allocation (``pltpu.VMEM(shape, dtype)``).

    Without the TPU pallas backend the kernels only ever run interpreted
    (see :func:`pallas_call`), where scratch needs nothing more than
    shape/dtype — a generic ANY-space ``MemoryRef`` stands in so the
    kernel path degrades instead of crashing.
    """
    if _pltpu is not None:
        return _pltpu.VMEM(shape, dtype)
    if hasattr(pl, "MemoryRef") and hasattr(pl, "ANY"):
        return pl.MemoryRef(tuple(shape), dtype, pl.ANY)
    raise RuntimeError(  # pragma: no cover - no known JAX hits this
        "no VMEM-like scratch allocator found in this JAX install")


def _backend() -> str:
    return jax.default_backend()


def interpret_default() -> bool:
    """True when Pallas kernels must run interpreted (no Mosaic compiler)."""
    return _backend() not in ("tpu",)


def pallas_call(kernel, *, interpret=False, **kwargs):
    """``pl.pallas_call`` that degrades to ``interpret=True`` off-TPU.

    Compiled Mosaic lowering only exists on TPU backends; everywhere else
    (the CPU-only CI host in particular) the same kernel runs through the
    Pallas interpreter so the whole kernel path stays exercised.
    """
    if not interpret and interpret_default():
        interpret = True
    return _PALLAS_CALL(kernel, interpret=interpret, **kwargs)
