"""Checkpoint manager: async writes, retention, restart-from-latest.

Paper §4.4: "During ALS execution we asynchronously checkpoint X and Theta
generated from the latest iteration ... When the machine fails, the latest
X or Theta (whichever is more recent) is used to restart ALS."

The manager reproduces that protocol for any pytree (ALS factors or LM
TrainState): ``save`` snapshots to host memory synchronously (cheap) and
commits to disk on a background thread; ``restore_or_init`` implements the
restart path.  ``keep`` bounds disk usage.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree: Any):
        """Snapshot to host then commit (async unless configured otherwise)."""
        self.wait()
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)

        def commit():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:   # surfaced on next wait()/save()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=commit, daemon=True)
            self._thread.start()
        else:
            commit()

    def restore_or_init(self, tree_like: Any, init_fn: Callable[[], Any]):
        """The restart path: latest checkpoint if one committed, else init."""
        step = latest_step(self.directory) if os.path.isdir(self.directory) else None
        if step is None:
            return init_fn(), 0
        return restore_checkpoint(self.directory, tree_like, step), step
