"""Checkpoint store: sharded npz files + atomic-rename commit.

Layout (one directory per step)::

    <dir>/step_000042.tmp/ -> (write) -> <dir>/step_000042/   (atomic rename)
        meta.json              treedef + leaf names + shapes + step
        shard_<host>.npz       this host's leaf arrays (local shards)
    <dir>/LATEST               text file holding the last committed step

Multi-host semantics: every host writes only the addressable shards of its
arrays (`arr.addressable_shards`); restore re-assembles via
``jax.make_array_from_single_device_arrays`` when a mesh is given, or plain
numpy on one host.  The commit protocol (write tmp, fsync, rename, update
LATEST last) means a failure at any point leaves the previous checkpoint
intact — restart picks up LATEST exactly as the paper's GPFS scheme does.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_names(tree) -> list[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        names.append("/".join(
            p.key if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx")
            else str(p) for p in path))
    return names


def save_checkpoint(directory: str, step: int, tree: Any,
                    host_id: int = 0) -> str:
    """Write one checkpoint atomically; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree.leaves(tree)
    names = _leaf_names(tree)
    arrs = {}
    for i, leaf in enumerate(leaves):
        arrs[f"leaf_{i}"] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrs)
    meta = {
        "step": step,
        "names": names,
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(jax.device_get(l)).dtype) for l in leaves],
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)                     # atomic commit
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None, host_id: int = 0) -> Any:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, f"shard_{host_id:05d}.npz"))
    leaves, treedef = jax.tree.flatten(tree_like)
    out = [data[f"leaf_{i}"] for i in range(len(leaves))]
    restored = []
    for ref, arr in zip(leaves, out):
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype)
        restored.append(arr)
    return jax.tree.unflatten(treedef, restored)
