"""Sharded, fault-tolerant checkpointing (paper §4.4 'Fault tolerance')."""

from repro.checkpoint.store import save_checkpoint, restore_checkpoint, latest_step
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]
