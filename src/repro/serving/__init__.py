"""Serving: batched prefill/decode engine with continuous batching slots."""

from repro.serving.engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
