"""Batched serving engine: slot-based continuous batching over one shared
KV cache.

The engine owns a fixed batch of ``n_slots`` sequences.  Requests queue up;
free slots are prefix-filled one request at a time (prefill writes that
slot's cache rows), then all active slots decode in lockstep — the standard
static-batch serving loop, with per-slot lengths so ragged sequences are
handled by masking rather than padding-restarts.

The batching loop is instrumented through ``repro.obs``: per-request
prefill and per-step decode run in ``serve`` spans, an ``active_slots``
gauge tracks occupancy, and ``serve/tokens_decoded`` counts throughput —
enough to see admission stalls vs decode time in a trace.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm as lm_mod
from repro.models import transformer as T
from repro.obs.trace import current_tracer, phase


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S0] int32
    max_new_tokens: int
    out: Optional[list] = None
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_seq: int = 512, mesh=None, serve_seq_shard=False,
                 tracer=None, registry=None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.mesh = mesh
        self.tracer = tracer if tracer is not None else current_tracer()
        self.registry = registry
        self.cache = T.init_cache(cfg, n_slots, max_seq, jnp.float32)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        self._decode = jax.jit(lm_mod.make_decode_step(
            cfg, mesh=mesh, serve_seq_shard=serve_seq_shard))
        self.last_tok = jnp.zeros((n_slots,), jnp.int32)

    def submit(self, req: Request):
        req.out = []
        self.pending.append(req)

    def _admit(self):
        """Prefill pending requests into free slots (token-by-token prefill
        through the decode path keeps one compiled program; a bulk-prefill
        fast path exists in launch/serve.py)."""
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[slot] = req
                with phase("serve.prefill", cat="serve",
                           tracer=self.tracer, registry=self.registry,
                           rid=req.rid, slot=slot,
                           prompt_len=len(req.prompt)):
                    for t in np.asarray(req.prompt, np.int32):
                        tok = self.last_tok.at[slot].set(int(t))
                        nxt, self.cache, lens = self._decode(
                            self.params, self.cache, tok, self.lengths)
                        self.lengths = self.lengths.at[slot].set(
                            int(self.lengths[slot]) + 1)
                        self.last_tok = self.last_tok.at[slot].set(
                            int(np.asarray(nxt)[slot]))

    def step(self):
        """One decode step for all active slots; retire finished requests."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if self.registry is not None:
            self.registry.gauge("active_slots").set(len(active))
        if not active:
            return False
        with phase("serve.decode_step", cat="serve", tracer=self.tracer,
                   registry=self.registry, active=len(active)):
            nxt, self.cache, self.lengths = self._decode(
                self.params, self.cache, self.last_tok, self.lengths)
            nxt_np = np.asarray(nxt)
        if self.registry is not None:
            self.registry.counter("serve/tokens_decoded").inc(len(active))
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt_np[s]))
            if len(req.out) >= req.max_new_tokens:
                req.done = True
                self.slot_req[s] = None
                self.lengths = self.lengths.at[s].set(0)
        self.last_tok = nxt
        return True

    def run(self):
        while self.pending or any(r is not None for r in self.slot_req):
            self.step()
