"""Sparse-matrix substrate for cuMF-on-TPU.

The paper stores R in CSR and relies on GPU texture caches to make random
column gathers cheap.  TPUs want contiguous tile traffic, so this package
provides:

- :class:`PaddedELL` — rows padded to a common nnz budget K (cuMF's *bin*
  concept applied at the data-layout level).  The gather of rated feature
  columns happens as one XLA gather (TPU DMA-gather), after which all kernel
  traffic is dense tiles.
- partitioners that produce the per-device shards consumed by SU-ALS
  (column shards over the "model" axis == cuMF's p, row shards over the
  "data" axis == cuMF's q).
- synthetic data generators reproducing the scale recipes of the paper's
  data sets (Netflix / YahooMusic / Hugewiki / SparkALS / Factorbird /
  Facebook).
"""

from repro.sparse.padded import PaddedELL, pad_csr, csr_from_coo, partition_padded
from repro.sparse.synth import (
    SynthSpec,
    DATASETS,
    make_synthetic_ratings,
    make_rating_batches,
)

__all__ = [
    "PaddedELL",
    "pad_csr",
    "csr_from_coo",
    "partition_padded",
    "SynthSpec",
    "DATASETS",
    "make_synthetic_ratings",
    "make_rating_batches",
]
