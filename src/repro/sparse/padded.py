"""Padded-ELL sparse layout (TPU-native replacement for cuMF's CSR+texture).

A sparse rating matrix R (m x n, Nz nonzeros) is stored as three dense
arrays::

    idx  [m, K] int32   column index of each nonzero, rows padded to K
    val  [m, K] float32 rating value, 0 in padding slots
    cnt  [m]    int32   true nnz per row (n_{x_u} of the paper, used by the
                        weighted-lambda regularizer)

Padding slots carry ``idx = 0`` and ``val = 0`` and are additionally masked
by position >= cnt, so gathered garbage never contributes.  A single
``PaddedELL`` pads every row to one K; :class:`BinnedELL` groups rows into
~log-spaced degree bins (cuMF's degree binning, Tan 1603.03820 §4.1 /
1808.03843's memory-optimized layout) so each bin pads to its own, much
tighter K — the kernels then run once per bin, one compiled shape per bin.
Because padding slots are exact zeros, re-padding a row at any K >= its
degree changes no f32 sum: binned and unbinned layouts are numerically
identical, not merely close.

Everything here is host-side preprocessing (numpy) + a few jnp helpers; the
hot path lives in repro/kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class PaddedELL:
    """Dense-padded sparse matrix, row-major semantics R[u, idx[u, k]] = val[u, k]."""

    idx: np.ndarray  # [m, K] int32
    val: np.ndarray  # [m, K] float32
    cnt: np.ndarray  # [m]    int32
    n_cols: int      # n — number of columns of the logical matrix

    @property
    def m(self) -> int:
        return self.idx.shape[0]

    @property
    def K(self) -> int:
        return self.idx.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.cnt.sum())

    @property
    def fill(self) -> float:
        """Padding overhead of THIS component: stored slots / true nonzeros
        (>= 1).  A store that holds several padded components (R, R^T, model
        shards) has one fill per component — aggregate with an explicit
        policy (`RatingStore.worst_fill` maxes for capacity bounds,
        ``fill_breakdown()`` exposes each for attribution), never by reusing
        a single component's scalar."""
        nnz = self.nnz
        return float(self.padded_slots) / max(nnz, 1)

    @property
    def padded_slots(self) -> int:
        """Stored slots (real + padding): the numerator of ``fill``."""
        return int(self.idx.shape[0]) * int(self.K) if self.idx.ndim == 2 \
            else int(np.prod(self.idx.shape[:-1])) * int(self.K)

    def mask(self) -> np.ndarray:
        """[m, K] float32 1.0 where a slot holds a real nonzero."""
        k = np.arange(self.K, dtype=np.int32)[None, :]
        return (k < self.cnt[:, None]).astype(np.float32)

    def transpose_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (rows, cols, vals) of R^T — used to build the update-Theta side."""
        k = np.arange(self.K, dtype=np.int32)[None, :]
        live = k < self.cnt[:, None]
        rows = np.broadcast_to(np.arange(self.m, dtype=np.int64)[:, None], self.idx.shape)[live]
        cols = self.idx[live].astype(np.int64)
        vals = self.val[live]
        return cols, rows, vals  # transposed: col becomes row


def csr_from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 m: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort COO by row; return (row_ptr, cols, vals) CSR triplet."""
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    cnt = np.bincount(rows, minlength=m).astype(np.int64)
    ptr = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(cnt, out=ptr[1:])
    return ptr, cols.astype(np.int32), vals.astype(np.float32)


def pad_csr(ptr: np.ndarray, cols: np.ndarray, vals: np.ndarray,
            n_cols: int, k_multiple: int = 8, k_cap: int | None = None) -> PaddedELL:
    """CSR -> PaddedELL, reference implementation (python row loop).

    K = max row degree rounded up to ``k_multiple``.  ``k_cap`` optionally
    truncates pathological rows (keeps the first k_cap ratings); the dropped
    tail is reported by the caller via fill/cnt deltas.

    This is the ORACLE: every production path routes through
    :func:`pad_csr_fast`, and the property test in tests/test_sparse.py
    pins the two to identical ``(idx, val, cnt)`` on ragged inputs.  Keep
    this loop dumb and obviously correct; optimize the fast variant.
    """
    m = ptr.shape[0] - 1
    cnt = (ptr[1:] - ptr[:-1]).astype(np.int32)
    if k_cap is not None:
        cnt = np.minimum(cnt, np.int32(k_cap))
    kmax = int(cnt.max()) if m else 0
    K = max(k_multiple, -(-kmax // k_multiple) * k_multiple)
    idx = np.zeros((m, K), dtype=np.int32)
    val = np.zeros((m, K), dtype=np.float32)
    for u in range(m):  # host-side, one-time preprocessing
        c = int(cnt[u])
        lo = int(ptr[u])
        idx[u, :c] = cols[lo:lo + c]
        val[u, :c] = vals[lo:lo + c]
    return PaddedELL(idx=idx, val=val, cnt=cnt, n_cols=n_cols)


def pad_csr_fast(ptr: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 n_cols: int, k_multiple: int = 8,
                 k_cap: int | None = None) -> PaddedELL:
    """Vectorized pad_csr (no python loop) for large matrices.

    Bit-identical to :func:`pad_csr` on every input (including ``k_cap``
    truncation, which keeps each row's first ``k_cap`` ratings) — the
    property test in tests/test_sparse.py enforces this.
    """
    m = ptr.shape[0] - 1
    full = (ptr[1:] - ptr[:-1]).astype(np.int32)
    cnt = np.minimum(full, np.int32(k_cap)) if k_cap is not None else full
    kmax = int(cnt.max()) if m else 0
    K = max(k_multiple, -(-kmax // k_multiple) * k_multiple)
    # position of each nonzero within its row
    pos = np.arange(len(cols), dtype=np.int64) - np.repeat(ptr[:-1], full)
    rows = np.repeat(np.arange(m, dtype=np.int64), full)
    if k_cap is not None:
        keep = pos < cnt[rows]         # drop each row's truncated tail
        pos, rows = pos[keep], rows[keep]
        cols, vals = cols[keep], vals[keep]
    idx = np.zeros((m, K), dtype=np.int32)
    val = np.zeros((m, K), dtype=np.float32)
    idx[rows, pos] = cols
    val[rows, pos] = vals
    return PaddedELL(idx=idx, val=val, cnt=cnt, n_cols=n_cols)


def row_slice(ell: PaddedELL, start: int, stop: int) -> PaddedELL:
    """Host-side contiguous row slice ``ell[start:stop]`` — the wave unit.

    K and ``n_cols`` are preserved (NOT re-tightened to the slice's max
    degree) so every wave of an out-of-core run presents the same device
    buffer shape; the cnt/padding/masking invariants carry over unchanged
    because each row's (idx, val, cnt) triplet is copied verbatim.  Arrays
    are materialized contiguous: a slice outlives transfers of its parent.
    """
    assert 0 <= start <= stop <= ell.m, (start, stop, ell.m)
    # .copy(), not ascontiguousarray: a row slice of a C-order array is
    # already contiguous, and ascontiguousarray would hand back a VIEW —
    # the slice must own its memory so transfers never alias the parent
    return PaddedELL(
        idx=ell.idx[start:stop].copy(),
        val=ell.val[start:stop].copy(),
        cnt=ell.cnt[start:stop].copy(),
        n_cols=ell.n_cols,
    )


def pad_rows(ell: PaddedELL, m_to: int) -> PaddedELL:
    """Append empty rows (cnt = 0, all slots masked) up to ``m_to`` rows.

    Used to round the row count up to a multiple of q so every q-batch has
    identical shape; padded rows contribute nothing (the masking invariant)
    and solve to x_u = 0 under the empty-row diagonal fallback.
    """
    assert m_to >= ell.m, (m_to, ell.m)
    extra = m_to - ell.m
    if extra == 0:
        return ell
    return PaddedELL(
        idx=np.pad(ell.idx, ((0, extra), (0, 0))),
        val=np.pad(ell.val, ((0, extra), (0, 0))),
        cnt=np.pad(ell.cnt, (0, extra)),
        n_cols=ell.n_cols,
    )


def partition_padded(ell: PaddedELL, p: int, k_multiple: int = 8) -> PaddedELL:
    """Column-partition a PaddedELL into ``p`` shards (SU-ALS data parallelism).

    Returns a PaddedELL whose arrays carry a leading shard axis:
        idx [p, m, K_loc], val [p, m, K_loc], cnt [p, m]
    Shard i holds the nonzeros with column in [i*n/p, (i+1)*n/p), with the
    column index re-based to the shard-local coordinate — exactly eq. (5)-(7)
    of the paper: each device observes only its local theta_v columns.
    """
    assert ell.n_cols % p == 0, f"n={ell.n_cols} not divisible by p={p}"
    npp = ell.n_cols // p
    m, K = ell.m, ell.K
    live = ell.mask().astype(bool)
    shard_of = ell.idx // npp          # [m, K] which shard owns each nonzero
    local_col = ell.idx % npp
    cnt_p = np.zeros((p, m), dtype=np.int32)
    for i in range(p):
        cnt_p[i] = ((shard_of == i) & live).sum(axis=1)
    kmax = int(cnt_p.max()) if m else 0
    K_loc = max(k_multiple, -(-kmax // k_multiple) * k_multiple)
    idx_p = np.zeros((p, m, K_loc), dtype=np.int32)
    val_p = np.zeros((p, m, K_loc), dtype=np.float32)
    for i in range(p):
        sel = (shard_of == i) & live                       # [m, K]
        pos = np.cumsum(sel, axis=1) - 1                   # slot within shard row
        uu, kk = np.nonzero(sel)
        idx_p[i, uu, pos[uu, kk]] = local_col[uu, kk]
        val_p[i, uu, pos[uu, kk]] = ell.val[uu, kk]
    out = PaddedELL(idx=idx_p, val=val_p, cnt=cnt_p, n_cols=npp)
    return out


# ---------------------------------------------------------------------------
# Degree-binned layout (cuMF §4.1 / Tan 1808.03843 memory-optimized batching)
# ---------------------------------------------------------------------------

def round_k(k: int, k_multiple: int = 8) -> int:
    """Round a degree up to the kernel lane multiple (min one lane)."""
    return max(k_multiple, -(-int(k) // k_multiple) * k_multiple)


def bin_caps(kmax: int, n_bins: int, k_multiple: int = 8) -> list[int]:
    """Ascending ~log-spaced per-bin degree caps ending at ``kmax`` rounded.

    Log spacing is the right ladder for power-law degrees: each bin's K is a
    roughly constant factor above the previous one's, so per-row overshoot
    (K_bin / degree) is bounded by that factor regardless of the skew.
    Duplicate rungs collapse, so the result may hold fewer than ``n_bins``
    caps on low-degree data.
    """
    top = round_k(kmax, k_multiple)
    if n_bins <= 1 or top <= k_multiple:
        return [top]
    grid = np.exp(np.linspace(np.log(k_multiple), np.log(top), n_bins))
    # clamp each rung to top: exp(log(top)) can land epsilon above top and
    # ceil would then mint a phantom rung one lane past the real maximum
    return sorted({min(round_k(int(np.ceil(g)), k_multiple), top)
                   for g in grid})


@dataclasses.dataclass
class BinnedELL:
    """Rows of one logical sparse matrix, grouped into degree bins.

    ``bins[b]`` is a :class:`PaddedELL` holding the rows assigned to bin b
    (those whose degree rounds into ``(caps[b-1], caps[b]]``), padded to that
    bin's own tight K.  ``rows[b]`` maps bin-local row u back to the original
    row index; rows keep their original relative order inside each bin
    (stable grouping), so each ``rows[b]`` is strictly ascending and any
    original-row range ``[start, stop)`` cuts every bin in one contiguous
    span (see :meth:`bin_spans`) — the property the wave scheduler relies on.

    ``perm``/``inv_perm`` are the full row permutation induced by the
    grouping: ``perm = concat(rows)``, ``inv_perm[perm] == arange(m)``.
    Factors are always kept in ORIGINAL row order; the permutation never
    leaves this module's consumers — solvers scatter per-bin results back
    through ``rows[b]``.
    """

    bins: Tuple[PaddedELL, ...]
    rows: Tuple[np.ndarray, ...]   # per-bin original row indices, ascending
    n_cols: int
    m: int                         # original (unbinned) row count

    @property
    def n_bins(self) -> int:
        return len(self.bins)

    @property
    def K_list(self) -> Tuple[int, ...]:
        return tuple(b.K for b in self.bins)

    @property
    def nnz(self) -> int:
        return sum(b.nnz for b in self.bins)

    @property
    def padded_slots(self) -> int:
        return sum(b.padded_slots for b in self.bins)

    @property
    def fill(self) -> float:
        """Stored slots / true nonzeros, summed OVER bins — the aggregate
        the planner prices (each bin also exposes its own ``.fill``)."""
        return float(self.padded_slots) / max(self.nnz, 1)

    @property
    def perm(self) -> np.ndarray:
        return np.concatenate([r for r in self.rows]) if self.rows \
            else np.zeros(0, dtype=np.int64)

    @property
    def inv_perm(self) -> np.ndarray:
        inv = np.empty(self.m, dtype=np.int64)
        inv[self.perm] = np.arange(self.m, dtype=np.int64)
        return inv

    def bin_spans(self, start: int, stop: int) -> list[Tuple[int, int]]:
        """Per-bin contiguous (lo, hi) bin-local spans covering original
        rows ``[start, stop)`` — exact because each ``rows[b]`` ascends."""
        return [(int(np.searchsorted(r, start)), int(np.searchsorted(r, stop)))
                for r in self.rows]

    def row_slice(self, start: int, stop: int) -> "BinnedELL":
        """Bin-wise cut of original rows ``[start, stop)``; row indices are
        rebased to the slice so the result is a self-contained BinnedELL
        over ``stop - start`` rows (empty bins are kept: slice shapes stay
        congruent with the parent's bin structure)."""
        spans = self.bin_spans(start, stop)
        return BinnedELL(
            bins=tuple(row_slice(b, lo, hi)
                       for b, (lo, hi) in zip(self.bins, spans)),
            rows=tuple((r[lo:hi] - start).astype(np.int64)
                       for r, (lo, hi) in zip(self.rows, spans)),
            n_cols=self.n_cols, m=stop - start)

    def to_padded(self) -> PaddedELL:
        """Reassemble one uniform-K PaddedELL in original row order (the
        parity oracle; K = max over bins, re-padding adds only zero slots)."""
        K = max(self.K_list) if self.bins else 8
        idx = np.zeros((self.m, K), dtype=np.int32)
        val = np.zeros((self.m, K), dtype=np.float32)
        cnt = np.zeros(self.m, dtype=np.int32)
        for b, r in zip(self.bins, self.rows):
            idx[r, :b.K] = b.idx
            val[r, :b.K] = b.val
            cnt[r] = b.cnt
        return PaddedELL(idx=idx, val=val, cnt=cnt, n_cols=self.n_cols)


def bin_rows(ptr: np.ndarray, cols: np.ndarray, vals: np.ndarray,
             n_cols: int, n_bins: int = 1, k_multiple: int = 8) -> BinnedELL:
    """CSR -> :class:`BinnedELL`: stable-group rows into ~log-spaced degree
    bins, each padded by :func:`pad_csr_fast` at its own tight K.

    ``n_bins=1`` reproduces today's layout bit-for-bit: one bin whose
    PaddedELL equals ``pad_csr_fast(ptr, cols, vals, n_cols)`` exactly and
    ``perm == arange(m)``.  Empty bins are dropped (every kernel dispatch
    maps to a non-empty bin); at least one bin always remains.
    """
    m = ptr.shape[0] - 1
    cnt = (ptr[1:] - ptr[:-1]).astype(np.int64)
    kmax = int(cnt.max()) if m else 0
    caps = bin_caps(kmax, n_bins, k_multiple)
    # row -> first cap covering its rounded degree (cnt=0 rows -> bin 0)
    assign = np.searchsorted(np.asarray(caps, dtype=np.int64),
                             np.maximum(cnt, 1), side="left")
    bins: list[PaddedELL] = []
    rows: list[np.ndarray] = []
    for b in range(len(caps)):
        rb = np.nonzero(assign == b)[0].astype(np.int64)
        if rb.size == 0:
            continue
        cnt_b = cnt[rb]
        # gather this bin's CSR entries (rows keep original relative order)
        off = np.cumsum(cnt_b) - cnt_b
        take = np.repeat(ptr[:-1][rb] - off, cnt_b) \
            + np.arange(int(cnt_b.sum()), dtype=np.int64)
        ptr_b = np.zeros(rb.size + 1, dtype=np.int64)
        np.cumsum(cnt_b, out=ptr_b[1:])
        bins.append(pad_csr_fast(ptr_b, cols[take], vals[take], n_cols,
                                 k_multiple=k_multiple))
        rows.append(rb)
    if not bins:       # m == 0: keep one (empty) bin so consumers never
        bins.append(pad_csr_fast(ptr, cols, vals, n_cols,   # see zero bins
                                 k_multiple=k_multiple))
        rows.append(np.zeros(0, dtype=np.int64))
    return BinnedELL(bins=tuple(bins), rows=tuple(rows),
                     n_cols=n_cols, m=m)


def bin_padded(ell: PaddedELL, n_bins: int,
               k_multiple: int = 8,
               caps: "list[int] | None" = None) -> BinnedELL:
    """Re-bin an existing PaddedELL (e.g. after :func:`pad_rows`) without a
    round trip through COO: rows are grouped by ``cnt`` and each bin is
    re-padded at its own tight K by dropping all-padding columns.

    ``caps`` overrides the ~log-spaced ladder with explicit ascending degree
    caps — the batch-uniform binning hook: several shards binned with the
    SAME caps produce congruent bin structures (membership may differ per
    shard, the cap ladder never does), which is what mesh streaming stacks.
    """
    cnt = ell.cnt.astype(np.int64)
    kmax = int(cnt.max()) if ell.m else 0
    if caps is None:
        caps = bin_caps(kmax, n_bins, k_multiple)
    else:
        caps = sorted(int(c) for c in caps)
        assert caps and caps[-1] >= kmax, (caps, kmax)
    assign = np.searchsorted(np.asarray(caps, dtype=np.int64),
                             np.maximum(cnt, 1), side="left")
    bins: list[PaddedELL] = []
    rows: list[np.ndarray] = []
    for b in range(len(caps)):
        rb = np.nonzero(assign == b)[0].astype(np.int64)
        if rb.size == 0:
            continue
        kb = min(round_k(int(cnt[rb].max()), k_multiple), ell.K)
        bins.append(PaddedELL(idx=ell.idx[rb, :kb].copy(),
                              val=ell.val[rb, :kb].copy(),
                              cnt=ell.cnt[rb].copy(), n_cols=ell.n_cols))
        rows.append(rb)
    if not bins:       # m == 0
        bins.append(PaddedELL(idx=ell.idx.copy(), val=ell.val.copy(),
                              cnt=ell.cnt.copy(), n_cols=ell.n_cols))
        rows.append(np.zeros(0, dtype=np.int64))
    return BinnedELL(bins=tuple(bins), rows=tuple(rows),
                     n_cols=ell.n_cols, m=ell.m)


@dataclasses.dataclass
class BinShardStack:
    """One degree bin of a q-partitioned matrix, stacked batch-uniform.

    Mesh streaming feeds the accumulate-Theta half one ``[n_data, rows, K]``
    stack per wave (``distributed.su_als.make_wave_herm_fn`` shards the row
    dim over the model axis), which requires every batch's bin to present
    the SAME shape.  The caps are therefore chosen globally across all q
    batches (batch-uniform item bins) while per-batch *membership* stays
    free: batch ``j``'s members occupy the leading ``cnt[j] > 0`` rows and
    the tail is padding rows (``cnt = 0``, exact-zero partials under the
    weighted-lambda Hermitian with ``diag_fallback=False``).

    ``items[j, u]`` is the global row (item) id stored at stacked slot
    ``(j, u)`` — the host-side scatter coordinate for per-bin partials;
    padding slots carry item 0 with all-zero contributions, so scattering
    them through ``np.add.at`` is exact.  ``rows`` is always a multiple of
    the model-axis size the stack was built for.
    """

    idx: np.ndarray    # [q, rows, K] int32, batch-local columns
    val: np.ndarray    # [q, rows, K] float32
    cnt: np.ndarray    # [q, rows]    int32 (0 on padding rows)
    items: np.ndarray  # [q, rows]    int64 global row ids (0 on padding)
    cap: int           # assignment cap of this bin (degree ladder rung)

    @property
    def q(self) -> int:
        return self.idx.shape[0]

    @property
    def rows(self) -> int:
        return self.idx.shape[1]

    @property
    def K(self) -> int:
        return self.idx.shape[2]

    @property
    def nnz(self) -> int:
        return int(self.cnt.sum())

    @property
    def padded_slots(self) -> int:
        return int(self.q) * int(self.rows) * int(self.K)

    @property
    def nbytes(self) -> int:
        """Streamed bytes across all q batches (idx + val + cnt — ``items``
        is host-side scatter bookkeeping, never transferred)."""
        return int(self.idx.nbytes + self.val.nbytes + self.cnt.nbytes)


def stack_binned_parts(parts: PaddedELL, n_bins: int,
                       k_multiple: int = 8, p: int = 1,
                       caps: "list[int] | None" = None
                       ) -> Tuple[BinShardStack, ...]:
    """Batch-uniform degree binning of a ``partition_padded`` output.

    ``parts`` carries a leading batch axis (idx ``[q, n, K_loc]``); bin caps
    come from the GLOBAL max batch-local degree so all q batches share one
    cap ladder, then each bin is stacked ``[q, rows_b, K_b]`` with
    ``rows_b`` = the max per-batch member count rounded up to a multiple of
    ``p`` (the mesh model-axis row sharding constraint) and ``K_b`` = the
    tight rounded max member degree (never above the parent K, so the
    column cut drops only all-padding slots — the stack holds exactly the
    parent's nonzeros).  Bins empty in EVERY batch are dropped.
    """
    assert parts.idx.ndim == 3, parts.idx.shape
    q, n, K_loc = parts.idx.shape
    cnt = parts.cnt.astype(np.int64)                     # [q, n]
    kmax = int(cnt.max()) if n else 0
    if caps is None:
        caps = bin_caps(kmax, n_bins, k_multiple)
    else:
        caps = sorted(int(c) for c in caps)
        assert caps and caps[-1] >= kmax, (caps, kmax)
    assign = np.searchsorted(np.asarray(caps, dtype=np.int64),
                             np.maximum(cnt, 1), side="left")   # [q, n]
    stacks: list[BinShardStack] = []
    for b, cap in enumerate(caps):
        members = [np.nonzero(assign[j] == b)[0].astype(np.int64)
                   for j in range(q)]
        max_members = max((int(mb.size) for mb in members), default=0)
        if max_members == 0:
            continue
        kb = min(round_k(int(max(int(cnt[j][mb].max()) if mb.size else 0
                                 for j, mb in enumerate(members))),
                         k_multiple), K_loc)
        rows_b = -(-max_members // p) * p
        idx = np.zeros((q, rows_b, kb), dtype=np.int32)
        val = np.zeros((q, rows_b, kb), dtype=np.float32)
        cnt_b = np.zeros((q, rows_b), dtype=np.int32)
        items = np.zeros((q, rows_b), dtype=np.int64)
        for j, mb in enumerate(members):
            idx[j, :mb.size] = parts.idx[j, mb, :kb]
            val[j, :mb.size] = parts.val[j, mb, :kb]
            cnt_b[j, :mb.size] = parts.cnt[j, mb]
            items[j, :mb.size] = mb
        stacks.append(BinShardStack(idx=idx, val=val, cnt=cnt_b,
                                    items=items, cap=int(cap)))
    if not stacks:       # n == 0: one all-padding stack keeps shapes legal
        stacks.append(BinShardStack(
            idx=np.zeros((q, p, k_multiple), np.int32),
            val=np.zeros((q, p, k_multiple), np.float32),
            cnt=np.zeros((q, p), np.int32),
            items=np.zeros((q, p), np.int64), cap=k_multiple))
    return tuple(stacks)


def row_partition(ell: PaddedELL, q: int) -> PaddedELL:
    """Row-partition into q shards (SU-ALS model parallelism): arrays get a
    leading q axis; rows must divide evenly (pad rows upstream)."""
    assert ell.m % q == 0, f"m={ell.m} not divisible by q={q}"
    mq = ell.m // q
    return PaddedELL(
        idx=ell.idx.reshape(q, mq, ell.K),
        val=ell.val.reshape(q, mq, ell.K),
        cnt=ell.cnt.reshape(q, mq),
        n_cols=ell.n_cols,
    )
