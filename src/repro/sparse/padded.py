"""Padded-ELL sparse layout (TPU-native replacement for cuMF's CSR+texture).

A sparse rating matrix R (m x n, Nz nonzeros) is stored as three dense
arrays::

    idx  [m, K] int32   column index of each nonzero, rows padded to K
    val  [m, K] float32 rating value, 0 in padding slots
    cnt  [m]    int32   true nnz per row (n_{x_u} of the paper, used by the
                        weighted-lambda regularizer)

Padding slots carry ``idx = 0`` and ``val = 0`` and are additionally masked
by position >= cnt, so gathered garbage never contributes.  K is chosen per
row *bucket* (rows sorted by degree, cuMF's binning made static) so the
padding overhead on power-law data stays bounded; the single-K variant is
what the jitted kernels consume.

Everything here is host-side preprocessing (numpy) + a few jnp helpers; the
hot path lives in repro/kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class PaddedELL:
    """Dense-padded sparse matrix, row-major semantics R[u, idx[u, k]] = val[u, k]."""

    idx: np.ndarray  # [m, K] int32
    val: np.ndarray  # [m, K] float32
    cnt: np.ndarray  # [m]    int32
    n_cols: int      # n — number of columns of the logical matrix

    @property
    def m(self) -> int:
        return self.idx.shape[0]

    @property
    def K(self) -> int:
        return self.idx.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.cnt.sum())

    @property
    def fill(self) -> float:
        """Padding overhead: stored slots / true nonzeros (>= 1)."""
        nnz = self.nnz
        return float(self.m * self.K) / max(nnz, 1)

    def mask(self) -> np.ndarray:
        """[m, K] float32 1.0 where a slot holds a real nonzero."""
        k = np.arange(self.K, dtype=np.int32)[None, :]
        return (k < self.cnt[:, None]).astype(np.float32)

    def transpose_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (rows, cols, vals) of R^T — used to build the update-Theta side."""
        k = np.arange(self.K, dtype=np.int32)[None, :]
        live = k < self.cnt[:, None]
        rows = np.broadcast_to(np.arange(self.m, dtype=np.int64)[:, None], self.idx.shape)[live]
        cols = self.idx[live].astype(np.int64)
        vals = self.val[live]
        return cols, rows, vals  # transposed: col becomes row


def csr_from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 m: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort COO by row; return (row_ptr, cols, vals) CSR triplet."""
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    cnt = np.bincount(rows, minlength=m).astype(np.int64)
    ptr = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(cnt, out=ptr[1:])
    return ptr, cols.astype(np.int32), vals.astype(np.float32)


def pad_csr(ptr: np.ndarray, cols: np.ndarray, vals: np.ndarray,
            n_cols: int, k_multiple: int = 8, k_cap: int | None = None) -> PaddedELL:
    """CSR -> PaddedELL.  K = max row degree rounded up to ``k_multiple``.

    ``k_cap`` optionally truncates pathological rows (keeps the first k_cap
    ratings); the dropped tail is reported by the caller via fill/cnt deltas.
    """
    m = ptr.shape[0] - 1
    cnt = (ptr[1:] - ptr[:-1]).astype(np.int32)
    if k_cap is not None:
        cnt = np.minimum(cnt, np.int32(k_cap))
    kmax = int(cnt.max()) if m else 0
    K = max(k_multiple, -(-kmax // k_multiple) * k_multiple)
    idx = np.zeros((m, K), dtype=np.int32)
    val = np.zeros((m, K), dtype=np.float32)
    for u in range(m):  # host-side, one-time preprocessing
        c = int(cnt[u])
        lo = int(ptr[u])
        idx[u, :c] = cols[lo:lo + c]
        val[u, :c] = vals[lo:lo + c]
    return PaddedELL(idx=idx, val=val, cnt=cnt, n_cols=n_cols)


def pad_csr_fast(ptr: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 n_cols: int, k_multiple: int = 8) -> PaddedELL:
    """Vectorized pad_csr (no python loop) for large matrices."""
    m = ptr.shape[0] - 1
    cnt = (ptr[1:] - ptr[:-1]).astype(np.int32)
    kmax = int(cnt.max()) if m else 0
    K = max(k_multiple, -(-kmax // k_multiple) * k_multiple)
    # position of each nonzero within its row
    pos = np.arange(len(cols), dtype=np.int64) - np.repeat(ptr[:-1], cnt)
    rows = np.repeat(np.arange(m, dtype=np.int64), cnt)
    idx = np.zeros((m, K), dtype=np.int32)
    val = np.zeros((m, K), dtype=np.float32)
    idx[rows, pos] = cols
    val[rows, pos] = vals
    return PaddedELL(idx=idx, val=val, cnt=cnt, n_cols=n_cols)


def row_slice(ell: PaddedELL, start: int, stop: int) -> PaddedELL:
    """Host-side contiguous row slice ``ell[start:stop]`` — the wave unit.

    K and ``n_cols`` are preserved (NOT re-tightened to the slice's max
    degree) so every wave of an out-of-core run presents the same device
    buffer shape; the cnt/padding/masking invariants carry over unchanged
    because each row's (idx, val, cnt) triplet is copied verbatim.  Arrays
    are materialized contiguous: a slice outlives transfers of its parent.
    """
    assert 0 <= start <= stop <= ell.m, (start, stop, ell.m)
    # .copy(), not ascontiguousarray: a row slice of a C-order array is
    # already contiguous, and ascontiguousarray would hand back a VIEW —
    # the slice must own its memory so transfers never alias the parent
    return PaddedELL(
        idx=ell.idx[start:stop].copy(),
        val=ell.val[start:stop].copy(),
        cnt=ell.cnt[start:stop].copy(),
        n_cols=ell.n_cols,
    )


def pad_rows(ell: PaddedELL, m_to: int) -> PaddedELL:
    """Append empty rows (cnt = 0, all slots masked) up to ``m_to`` rows.

    Used to round the row count up to a multiple of q so every q-batch has
    identical shape; padded rows contribute nothing (the masking invariant)
    and solve to x_u = 0 under the empty-row diagonal fallback.
    """
    assert m_to >= ell.m, (m_to, ell.m)
    extra = m_to - ell.m
    if extra == 0:
        return ell
    return PaddedELL(
        idx=np.pad(ell.idx, ((0, extra), (0, 0))),
        val=np.pad(ell.val, ((0, extra), (0, 0))),
        cnt=np.pad(ell.cnt, (0, extra)),
        n_cols=ell.n_cols,
    )


def partition_padded(ell: PaddedELL, p: int, k_multiple: int = 8) -> PaddedELL:
    """Column-partition a PaddedELL into ``p`` shards (SU-ALS data parallelism).

    Returns a PaddedELL whose arrays carry a leading shard axis:
        idx [p, m, K_loc], val [p, m, K_loc], cnt [p, m]
    Shard i holds the nonzeros with column in [i*n/p, (i+1)*n/p), with the
    column index re-based to the shard-local coordinate — exactly eq. (5)-(7)
    of the paper: each device observes only its local theta_v columns.
    """
    assert ell.n_cols % p == 0, f"n={ell.n_cols} not divisible by p={p}"
    npp = ell.n_cols // p
    m, K = ell.m, ell.K
    live = ell.mask().astype(bool)
    shard_of = ell.idx // npp          # [m, K] which shard owns each nonzero
    local_col = ell.idx % npp
    cnt_p = np.zeros((p, m), dtype=np.int32)
    for i in range(p):
        cnt_p[i] = ((shard_of == i) & live).sum(axis=1)
    kmax = int(cnt_p.max()) if m else 0
    K_loc = max(k_multiple, -(-kmax // k_multiple) * k_multiple)
    idx_p = np.zeros((p, m, K_loc), dtype=np.int32)
    val_p = np.zeros((p, m, K_loc), dtype=np.float32)
    for i in range(p):
        sel = (shard_of == i) & live                       # [m, K]
        pos = np.cumsum(sel, axis=1) - 1                   # slot within shard row
        uu, kk = np.nonzero(sel)
        idx_p[i, uu, pos[uu, kk]] = local_col[uu, kk]
        val_p[i, uu, pos[uu, kk]] = ell.val[uu, kk]
    out = PaddedELL(idx=idx_p, val=val_p, cnt=cnt_p, n_cols=npp)
    return out


def row_partition(ell: PaddedELL, q: int) -> PaddedELL:
    """Row-partition into q shards (SU-ALS model parallelism): arrays get a
    leading q axis; rows must divide evenly (pad rows upstream)."""
    assert ell.m % q == 0, f"m={ell.m} not divisible by q={q}"
    mq = ell.m // q
    return PaddedELL(
        idx=ell.idx.reshape(q, mq, ell.K),
        val=ell.val.reshape(q, mq, ell.K),
        cnt=ell.cnt.reshape(q, mq),
        n_cols=ell.n_cols,
    )
