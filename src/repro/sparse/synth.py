"""Synthetic rating matrices at the paper's data-set scales.

The paper evaluates on Netflix / YahooMusic / Hugewiki and synthesizes the
SparkALS / Factorbird / Facebook scales (Table 5).  We reproduce the same
recipe: draw a planted low-rank model X*, Theta*, sample Nz (user, item)
pairs from a power-law item popularity (real rating matrices are heavily
skewed), observe r_uv = x_u . theta_v + noise, and hold out a test split.

A planted factorization gives us a *known* achievable RMSE, so convergence
tests have an oracle, which public data would not give us offline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.sparse.padded import (BinnedELL, PaddedELL, bin_rows,
                                 csr_from_coo, pad_csr_fast)


@dataclasses.dataclass(frozen=True)
class SynthSpec:
    """Scale recipe for one paper data set (Table 5)."""

    name: str
    m: int              # rows (users)
    n: int              # cols (items)
    nnz: int            # number of ratings
    f: int              # latent dimension used by the paper
    lam: float          # lambda (weighted-lambda regularization)

    @property
    def bytes_R(self) -> int:
        # CSR: 2*Nz + m + 1 fp32/int32 words (paper Table 3)
        return 4 * (2 * self.nnz + self.m + 1)

    @property
    def bytes_factors(self) -> int:
        return 4 * self.f * (self.m + self.n)

    @property
    def bytes_hermitian_all(self) -> int:
        return 4 * self.m * self.f * self.f


# Table 5 of the paper, verbatim.
DATASETS: Dict[str, SynthSpec] = {
    "netflix":    SynthSpec("netflix",    480_189,       17_770,    99_000_000,       100, 0.05),
    "yahoomusic": SynthSpec("yahoomusic", 1_000_990,     624_961,   252_800_000,      100, 1.4),
    "hugewiki":   SynthSpec("hugewiki",   50_082_603,    39_780,    3_100_000_000,    100, 0.05),
    "sparkals":   SynthSpec("sparkals",   660_000_000,   2_400_000, 3_500_000_000,    10,  0.05),
    "factorbird": SynthSpec("factorbird", 229_000_000,   195_000_000, 38_500_000_000, 5,   0.05),
    "facebook":   SynthSpec("facebook",   1_000_000_000, 48_000_000, 112_000_000_000, 16,  0.05),
    "cumf_max":   SynthSpec("cumf_max",   1_056_000_000, 48_000_000, 112_000_000_000, 100, 0.05),
}


def scaled(spec: SynthSpec, scale: float, f: int | None = None) -> SynthSpec:
    """Shrink a recipe by ``scale`` in every dimension (CPU-fit testing)."""
    return SynthSpec(
        name=f"{spec.name}@{scale:g}",
        m=max(16, int(spec.m * scale)),
        n=max(16, int(spec.n * scale)),
        nnz=max(64, int(spec.nnz * scale * scale)),
        f=f if f is not None else spec.f,
        lam=spec.lam,
    )


def _power_law_probs(n: int, alpha: float, rng: np.random.Generator) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    rng.shuffle(p)
    return p / p.sum()


def make_synthetic_ratings(
    spec: SynthSpec,
    seed: int = 0,
    noise: float = 0.1,
    alpha: float = 0.8,
    test_frac: float = 0.1,
    k_multiple: int = 8,
    alpha_user: float = 0.0,
) -> Tuple[PaddedELL, PaddedELL, np.ndarray, np.ndarray]:
    """Return (R_train as PaddedELL rows=users, R_train^T as PaddedELL rows=items,
    X*, Theta*) for a planted low-rank model.

    Ratings are r_uv = <x*_u, theta*_v>/sqrt(f) + noise; items power-law
    (``alpha``) — the skew that motivates cuMF's degree-binning.  Users are
    uniform by default; ``alpha_user > 0`` draws them power-law too (real
    rating matrices skew on both axes).  ``alpha_user=0.0`` keeps the exact
    historical RNG call sequence, so existing seeds reproduce bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    f = spec.f
    x_star = rng.standard_normal((spec.m, f)).astype(np.float32)
    t_star = rng.standard_normal((spec.n, f)).astype(np.float32)

    if alpha_user > 0.0:
        user_p = _power_law_probs(spec.m, alpha_user, rng)
        rows = rng.choice(spec.m, size=spec.nnz, p=user_p).astype(np.int64)
    else:
        rows = rng.integers(0, spec.m, size=spec.nnz, dtype=np.int64)
    item_p = _power_law_probs(spec.n, alpha, rng)
    cols = rng.choice(spec.n, size=spec.nnz, p=item_p).astype(np.int64)
    # de-duplicate (u, v) pairs
    key = rows * spec.n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = (
        np.einsum("kf,kf->k", x_star[rows], t_star[cols]) / np.sqrt(f)
        + noise * rng.standard_normal(len(rows))
    ).astype(np.float32)

    n_test = int(len(rows) * test_frac)
    perm = rng.permutation(len(rows))
    test_sel, train_sel = perm[:n_test], perm[n_test:]

    def _build(r, c, v, m, n):
        ptr, cc, vv = csr_from_coo(r, c, v, m)
        return pad_csr_fast(ptr, cc, vv, n, k_multiple=k_multiple)

    r_tr = _build(rows[train_sel], cols[train_sel], vals[train_sel], spec.m, spec.n)
    r_tr_T = _build(cols[train_sel], rows[train_sel], vals[train_sel], spec.n, spec.m)
    r_te = _build(rows[test_sel], cols[test_sel], vals[test_sel], spec.m, spec.n)
    return r_tr, r_tr_T, r_te, (x_star, t_star)


def make_synthetic_ratings_binned(
    spec: SynthSpec,
    n_bins: int,
    seed: int = 0,
    noise: float = 0.1,
    alpha: float = 0.8,
    test_frac: float = 0.1,
    k_multiple: int = 8,
    alpha_user: float = 0.0,
) -> Tuple[BinnedELL, BinnedELL, PaddedELL, Tuple[np.ndarray, np.ndarray]]:
    """Degree-binned construction path: the same planted problem as
    :func:`make_synthetic_ratings` (identical RNG sequence, identical COO),
    but R and R^T come back as :class:`BinnedELL` built straight from CSR
    via :func:`bin_rows` — no uniform-K intermediate is ever materialized.
    The test split stays a PaddedELL (evaluation gathers, never solves).
    """
    rng = np.random.default_rng(seed)
    f = spec.f
    x_star = rng.standard_normal((spec.m, f)).astype(np.float32)
    t_star = rng.standard_normal((spec.n, f)).astype(np.float32)

    if alpha_user > 0.0:
        user_p = _power_law_probs(spec.m, alpha_user, rng)
        rows = rng.choice(spec.m, size=spec.nnz, p=user_p).astype(np.int64)
    else:
        rows = rng.integers(0, spec.m, size=spec.nnz, dtype=np.int64)
    item_p = _power_law_probs(spec.n, alpha, rng)
    cols = rng.choice(spec.n, size=spec.nnz, p=item_p).astype(np.int64)
    key = rows * spec.n + cols
    _, uniq = np.unique(key, return_index=True)
    rows, cols = rows[uniq], cols[uniq]
    vals = (
        np.einsum("kf,kf->k", x_star[rows], t_star[cols]) / np.sqrt(f)
        + noise * rng.standard_normal(len(rows))
    ).astype(np.float32)

    n_test = int(len(rows) * test_frac)
    perm = rng.permutation(len(rows))
    test_sel, train_sel = perm[:n_test], perm[n_test:]

    def _build_binned(r, c, v, m, n):
        ptr, cc, vv = csr_from_coo(r, c, v, m)
        return bin_rows(ptr, cc, vv, n, n_bins=n_bins, k_multiple=k_multiple)

    r_tr = _build_binned(rows[train_sel], cols[train_sel], vals[train_sel],
                         spec.m, spec.n)
    r_tr_T = _build_binned(cols[train_sel], rows[train_sel], vals[train_sel],
                           spec.n, spec.m)
    ptr, cc, vv = csr_from_coo(rows[test_sel], cols[test_sel], vals[test_sel],
                               spec.m)
    r_te = pad_csr_fast(ptr, cc, vv, spec.n, k_multiple=k_multiple)
    return r_tr, r_tr_T, r_te, (x_star, t_star)


def make_rating_batches(ell: PaddedELL, batch_rows: int):
    """Yield (row_offset, idx, val, cnt) batches of ``batch_rows`` rows —
    cuMF's q-batching / out-of-core streaming unit."""
    m = ell.m
    for lo in range(0, m, batch_rows):
        hi = min(lo + batch_rows, m)
        yield lo, ell.idx[lo:hi], ell.val[lo:hi], ell.cnt[lo:hi]
