"""MO-ALS: the single-device ALS driver (paper Alg. 1 / Alg. 2).

The alternating structure is exactly the paper's: update X with Theta fixed
(eq. 2), then update Theta with X fixed (eq. 3), both through the fused
hermitian kernel + batched Cholesky solve.  The q-batching ("solve X in
batches when X is big and Theta fits", paper §3.4 'Limitation of MO-ALS')
is a ``lax.map`` over row blocks so memory stays bounded at m_b f^2.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import rmse_padded
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class AlsConfig:
    f: int                    # latent dimension
    lam: float                # weighted-lambda regularization strength
    iters: int = 10           # full ALS iterations (each = update-X + update-Theta)
    batch_rows: int = 0       # q-batch size; 0 = solve all rows at once
    mode: str = "ref"         # kernel dispatch: ref | kernel | kernel_interpret
    tm: int = 8
    tk: int = 128
    tb: int = 8
    f_mult: int = 128
    seed: int = 0
    init_scale: float = 0.3   # paper initializes factors U[0, 1]; we scale down


class AlsState(NamedTuple):
    x: jax.Array        # [m, f]
    theta: jax.Array    # [n, f]
    iteration: jax.Array  # scalar int32


def als_init(m: int, n: int, cfg: AlsConfig) -> AlsState:
    kx, kt = jax.random.split(jax.random.PRNGKey(cfg.seed))
    x = jax.random.uniform(kx, (m, cfg.f), jnp.float32) * cfg.init_scale
    theta = jax.random.uniform(kt, (n, cfg.f), jnp.float32) * cfg.init_scale
    return AlsState(x=x, theta=theta, iteration=jnp.int32(0))


def _map_row_blocks(solve_block, arrays, batch_rows: int, pad_vals=None):
    """Row-block scaffolding shared by the q-batched solves: pad the leading
    axis up to a multiple of ``batch_rows`` (with zeros, or a broadcast
    ``pad_vals[i]`` per array — e.g. I for Hermitians so padded solves stay
    nonsingular), ``lax.map`` over the blocks, unpad the result."""
    m = arrays[0].shape[0]
    nb = -(-m // batch_rows)
    pad = nb * batch_rows - m
    blocked = []
    for i, a in enumerate(arrays):
        pv = None if pad_vals is None else pad_vals[i]
        if pad:
            if pv is None:
                a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
            else:
                a = jnp.concatenate(
                    [a, jnp.broadcast_to(pv, (pad,) + a.shape[1:])])
        blocked.append(a.reshape((nb, batch_rows) + a.shape[1:]))
    out = jax.lax.map(solve_block, tuple(blocked))
    return out.reshape((nb * batch_rows,) + out.shape[2:])[:m]


def _update_factor(theta, idx, val, cnt, cfg: AlsConfig) -> jax.Array:
    """Solve every row of one factor given the other side fixed."""
    solve = functools.partial(
        kops.als_update_factor, lam=cfg.lam, mode=cfg.mode,
        tm=cfg.tm, tk=cfg.tk, tb=cfg.tb, f_mult=cfg.f_mult)
    m = idx.shape[0]
    if cfg.batch_rows and cfg.batch_rows < m:
        return _map_row_blocks(
            lambda b: solve(theta, b[0].astype(jnp.int32),
                            b[1], b[2].astype(jnp.int32)),
            (idx.astype(jnp.int32), val, cnt.astype(jnp.int32)),
            cfg.batch_rows)
    return solve(theta, idx, val, cnt)


def update_rows(fixed, idx, val, cnt, cfg: AlsConfig) -> jax.Array:
    """Per-slice update entry point (out-of-core wave driver, solve side).

    Solves the rows of one factor slice given the ``fixed`` other factor —
    identical math to a full ``_update_factor`` call restricted to the slice,
    so streaming a factor in row slices reproduces the in-core trajectory.
    """
    return _update_factor(fixed, idx, val, cnt, cfg)


def partial_herm(x_batch, idx_loc, val_loc, cnt_loc, cfg: AlsConfig):
    """Per-batch partial Hermitian (out-of-core wave driver, accumulate side).

    ``idx_loc`` indexes into ``x_batch`` (batch-local user coordinates, the
    output of ``partition_padded`` on R^T).  Returns (A_j, B_j) partial sums;
    summing over all q batches reproduces the full-gather Hermitian because
    the weighted-lambda diagonal ``lam * cnt_loc`` also telescopes to
    ``lam * cnt_total`` — the same partial-sum scheme SU-ALS reduces over the
    "model" axis (eq. 5-7), serialized over waves instead.
    """
    return kops.fused_herm(
        x_batch, idx_loc, val_loc, cnt_loc, cfg.lam,
        mode=cfg.mode, tm=cfg.tm, tk=cfg.tk, f_mult=cfg.f_mult,
        diag_fallback=False)


def solve_accumulated(A, B, cnt_total, cfg: AlsConfig) -> jax.Array:
    """Solve a factor from accumulated partial Hermitians.

    Applies the globally-empty-row guard post-accumulation (a row empty in
    every batch gets A = I, B = 0 -> x = 0, matching ``fused_herm``'s
    ``diag_fallback``) then runs the batched Cholesky solve, in row blocks of
    ``cfg.batch_rows`` when set so the solve working set stays bounded.
    """
    f = A.shape[-1]
    empty = (cnt_total <= 0).astype(A.dtype)
    A = A + empty[:, None, None] * jnp.eye(f, dtype=A.dtype)[None, :, :]
    solve = functools.partial(kops.batch_solve, mode=cfg.mode, tb=cfg.tb)
    m = A.shape[0]
    if cfg.batch_rows and cfg.batch_rows < m:
        return _map_row_blocks(
            lambda ab: solve(ab[0], ab[1]), (A, B), cfg.batch_rows,
            pad_vals=(jnp.eye(f, dtype=A.dtype), None))
    return solve(A, B)


def als_iteration(state: AlsState, r, rt, cfg: AlsConfig) -> AlsState:
    """One full ALS iteration.  ``r`` / ``rt`` are (idx, val, cnt) triplets of
    R in row-major (users) and of R^T (items) respectively."""
    x = _update_factor(state.theta, r[0], r[1], r[2], cfg)
    theta = _update_factor(x, rt[0], rt[1], rt[2], cfg)
    return AlsState(x=x, theta=theta, iteration=state.iteration + 1)


def als_train(
    r, rt, m: int, n: int, cfg: AlsConfig,
    test: Optional[tuple] = None,
    callback=None,
) -> tuple[AlsState, list[dict]]:
    """Full training driver.  Returns (final state, per-iteration history).

    ``test`` is an optional (idx, val, cnt) triplet evaluated after every
    iteration (paper Fig. 6 protocol: test RMSE vs iteration)."""
    state = als_init(m, n, cfg)
    history: list[dict] = []
    for it in range(cfg.iters):
        state = als_iteration(state, r, rt, cfg)
        rec = {"iteration": it + 1}
        if test is not None:
            rec["test_rmse"] = float(
                rmse_padded(state.x, state.theta, test[0], test[1], test[2]))
        rec["train_rmse"] = float(
            rmse_padded(state.x, state.theta, r[0], r[1], r[2]))
        history.append(rec)
        if callback is not None:
            callback(state, rec)
    return state, history


def ell_triplet(ell) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PaddedELL -> device triplet (idx, val, cnt)."""
    return (jnp.asarray(np.asarray(ell.idx), jnp.int32),
            jnp.asarray(np.asarray(ell.val), jnp.float32),
            jnp.asarray(np.asarray(ell.cnt), jnp.int32))


# ---------------------------------------------------------------------------
# Degree-binned dispatch: the same kernels, once per bin at that bin's K.
# One compiled shape per bin (bounded by n_bins); padding slots are exact
# zeros, so per-bin K changes no f32 sum and binned == unbinned numerically.
# ---------------------------------------------------------------------------

def update_factor_binned(fixed, binned, cfg: AlsConfig) -> jax.Array:
    """Solve one factor from a :class:`~repro.sparse.padded.BinnedELL`:
    dispatch ``als_update_factor`` once per degree bin at the bin's own K,
    scatter results back to original row order through ``binned.rows``."""
    out = jnp.zeros((binned.m, cfg.f), jnp.float32)
    for b, rows in zip(binned.bins, binned.rows):
        if b.m == 0:
            continue
        idx, val, cnt = ell_triplet(b)
        xb = _update_factor(fixed, idx, val, cnt, cfg)
        out = out.at[jnp.asarray(np.asarray(rows), jnp.int32)].set(xb)
    return out


def update_rows_binned(fixed, binned, cfg: AlsConfig) -> jax.Array:
    """Binned per-slice update (out-of-core wave driver, solve side): the
    slice arrives as a BinnedELL with slice-local row indices; results come
    back in slice row order, exactly like :func:`update_rows` on the
    uniform layout."""
    return update_factor_binned(fixed, binned, cfg)


def partial_herm_binned(x_batch, binned_loc, cfg: AlsConfig):
    """Binned per-batch partial Hermitian (accumulate side): run
    :func:`partial_herm` once per bin of the batch-local R^T shard and
    scatter-add into full-size (A_j, B_j), so the caller's per-batch
    ``A += A_j`` accumulation is layout-blind."""
    n, f = binned_loc.m, cfg.f
    A = jnp.zeros((n, f, f), jnp.float32)
    B = jnp.zeros((n, f), jnp.float32)
    for b, rows in zip(binned_loc.bins, binned_loc.rows):
        if b.m == 0:
            continue
        idx, val, cnt = ell_triplet(b)
        Ab, Bb = partial_herm(x_batch, idx, val, cnt, cfg)
        r = jnp.asarray(np.asarray(rows), jnp.int32)
        A = A.at[r].add(Ab)
        B = B.at[r].add(Bb)
    return A, B


def rmse_binned(x, theta, binned) -> float:
    """RMSE over the nonzeros of a BinnedELL (per-bin SSE, one sqrt)."""
    from repro.core.objective import _sq_err_padded

    sse, nnz = 0.0, 0
    for b, rows in zip(binned.bins, binned.rows):
        if b.m == 0:
            continue
        idx, val, cnt = ell_triplet(b)
        s, k = _sq_err_padded(x[jnp.asarray(np.asarray(rows), jnp.int32)],
                              theta, idx, val, cnt)
        sse += float(s)
        nnz += int(k)
    return (sse / max(nnz, 1)) ** 0.5


def als_train_binned(
    rb, rtb, cfg: AlsConfig,
    test: Optional[tuple] = None,
    callback=None,
) -> tuple[AlsState, list[dict]]:
    """In-core training driver over binned layouts: the same alternating
    schedule as :func:`als_train` with both half-updates dispatched per bin.
    ``rb`` / ``rtb`` are BinnedELLs of R (rows=users) and R^T (rows=items).
    """
    state = als_init(rb.m, rtb.m, cfg)
    history: list[dict] = []
    for it in range(cfg.iters):
        x = update_factor_binned(state.theta, rb, cfg)
        theta = update_factor_binned(x, rtb, cfg)
        state = AlsState(x=x, theta=theta, iteration=state.iteration + 1)
        rec = {"iteration": it + 1}
        if test is not None:
            rec["test_rmse"] = float(
                rmse_padded(state.x, state.theta, test[0], test[1], test[2]))
        rec["train_rmse"] = rmse_binned(state.x, state.theta, rb)
        history.append(rec)
        if callback is not None:
            callback(state, rec)
    return state, history
