"""MO-ALS: the single-device ALS driver (paper Alg. 1 / Alg. 2).

The alternating structure is exactly the paper's: update X with Theta fixed
(eq. 2), then update Theta with X fixed (eq. 3), both through the fused
hermitian kernel + batched Cholesky solve.  The q-batching ("solve X in
batches when X is big and Theta fits", paper §3.4 'Limitation of MO-ALS')
is a ``lax.map`` over row blocks so memory stays bounded at m_b f^2.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import rmse_padded
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class AlsConfig:
    f: int                    # latent dimension
    lam: float                # weighted-lambda regularization strength
    iters: int = 10           # full ALS iterations (each = update-X + update-Theta)
    batch_rows: int = 0       # q-batch size; 0 = solve all rows at once
    mode: str = "ref"         # kernel dispatch: ref | kernel | kernel_interpret
    tm: int = 8
    tk: int = 128
    tb: int = 8
    f_mult: int = 128
    seed: int = 0
    init_scale: float = 0.3   # paper initializes factors U[0, 1]; we scale down


class AlsState(NamedTuple):
    x: jax.Array        # [m, f]
    theta: jax.Array    # [n, f]
    iteration: jax.Array  # scalar int32


def als_init(m: int, n: int, cfg: AlsConfig) -> AlsState:
    kx, kt = jax.random.split(jax.random.PRNGKey(cfg.seed))
    x = jax.random.uniform(kx, (m, cfg.f), jnp.float32) * cfg.init_scale
    theta = jax.random.uniform(kt, (n, cfg.f), jnp.float32) * cfg.init_scale
    return AlsState(x=x, theta=theta, iteration=jnp.int32(0))


def _update_factor(theta, idx, val, cnt, cfg: AlsConfig) -> jax.Array:
    """Solve every row of one factor given the other side fixed."""
    solve = functools.partial(
        kops.als_update_factor, lam=cfg.lam, mode=cfg.mode,
        tm=cfg.tm, tk=cfg.tk, tb=cfg.tb, f_mult=cfg.f_mult)
    m = idx.shape[0]
    if cfg.batch_rows and cfg.batch_rows < m:
        nb = -(-m // cfg.batch_rows)
        pad = nb * cfg.batch_rows - m
        idx_b = jnp.pad(idx, ((0, pad), (0, 0))).reshape(nb, cfg.batch_rows, -1)
        val_b = jnp.pad(val, ((0, pad), (0, 0))).reshape(nb, cfg.batch_rows, -1)
        cnt_b = jnp.pad(cnt, (0, pad)).reshape(nb, cfg.batch_rows)
        x = jax.lax.map(lambda b: solve(theta, b[0].astype(jnp.int32),
                                        b[1], b[2].astype(jnp.int32)),
                        (idx_b.astype(jnp.int32), val_b, cnt_b.astype(jnp.int32)))
        return x.reshape(nb * cfg.batch_rows, -1)[:m]
    return solve(theta, idx, val, cnt)


def als_iteration(state: AlsState, r, rt, cfg: AlsConfig) -> AlsState:
    """One full ALS iteration.  ``r`` / ``rt`` are (idx, val, cnt) triplets of
    R in row-major (users) and of R^T (items) respectively."""
    x = _update_factor(state.theta, r[0], r[1], r[2], cfg)
    theta = _update_factor(x, rt[0], rt[1], rt[2], cfg)
    return AlsState(x=x, theta=theta, iteration=state.iteration + 1)


def als_train(
    r, rt, m: int, n: int, cfg: AlsConfig,
    test: Optional[tuple] = None,
    callback=None,
) -> tuple[AlsState, list[dict]]:
    """Full training driver.  Returns (final state, per-iteration history).

    ``test`` is an optional (idx, val, cnt) triplet evaluated after every
    iteration (paper Fig. 6 protocol: test RMSE vs iteration)."""
    state = als_init(m, n, cfg)
    history: list[dict] = []
    for it in range(cfg.iters):
        state = als_iteration(state, r, rt, cfg)
        rec = {"iteration": it + 1}
        if test is not None:
            rec["test_rmse"] = float(
                rmse_padded(state.x, state.theta, test[0], test[1], test[2]))
        rec["train_rmse"] = float(
            rmse_padded(state.x, state.theta, r[0], r[1], r[2]))
        history.append(rec)
        if callback is not None:
            callback(state, rec)
    return state, history


def ell_triplet(ell) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PaddedELL -> device triplet (idx, val, cnt)."""
    return (jnp.asarray(np.asarray(ell.idx), jnp.int32),
            jnp.asarray(np.asarray(ell.val), jnp.float32),
            jnp.asarray(np.asarray(ell.cnt), jnp.int32))
