"""Partition planner — paper eq. (8), generalized to a TPU mesh.

cuMF chooses p (Theta column shards == data parallelism) and q (X row
batches == model parallelism) so that a single device holds::

    m f / q  +  n f / p  +  |R^(ij)|  +  (m/q) f^2  +  (m/q) f  +  eps  <  C

with the best practices of §4.3:
  1. if p = 1 fits, stay on one device (SU-ALS degenerates to MO-ALS),
  2. stop growing q once p = 1 fits,
  3. otherwise start from p with n f / p ~ C/2 and pick the smallest q.

On a mesh, p maps to the "model" axis (and "pod" x "model" when multi-pod)
and q to the "data" axis; q larger than the data axis runs in waves
(elasticity, §4.4) — `waves` reports how many.
"""
from __future__ import annotations

import dataclasses

GiB = 1 << 30


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    p: int                  # column shards of Theta (data parallelism)
    q: int                  # row shards/batches of X (model parallelism)
    bytes_per_device: int
    terms: dict
    fits: bool
    waves: int = 1          # q-batches executed per device wave (elasticity)

    def describe(self) -> str:
        t = ", ".join(f"{k}={v / GiB:.3f}GiB" for k, v in self.terms.items())
        return (f"p={self.p} q={self.q} waves={self.waves} "
                f"total={self.bytes_per_device / GiB:.3f}GiB fits={self.fits} [{t}]")


def _bytes_per_device(m, n, nnz, f, p, q, fill=1.5, dtype_bytes=4, eps=512 << 20):
    terms = {
        "X_batch": m * f * dtype_bytes // q,
        "Theta_shard": n * f * dtype_bytes // p,
        "R_shard": int(2 * nnz * dtype_bytes * fill) // (p * q),  # idx+val, padded
        "A_batch": m * f * f * dtype_bytes // q,
        "B_batch": m * f * dtype_bytes // q,
        "eps": eps,
    }
    return sum(terms.values()), terms


def plan_partitions(
    m: int, n: int, nnz: int, f: int,
    hbm_bytes: int = 16 * GiB,
    n_model: int = 16,          # devices on the "model" axis (p candidates)
    n_data: int = 16,           # devices on the "data" axis (q waves base)
    fill: float = 1.5,
    dtype_bytes: int = 4,
    eps: int = 512 << 20,
) -> PartitionPlan:
    """Choose (p, q) per paper §4.3 for the given problem and mesh."""
    # Best practice 1/2: smallest q with p=1, if Theta fits a device.
    def fits(p, q):
        total, terms = _bytes_per_device(m, n, nnz, f, p, q, fill, dtype_bytes, eps)
        return total < hbm_bytes, total, terms

    if n * f * dtype_bytes + eps < hbm_bytes // 2:
        p = 1
        q = 1
        while True:
            ok, total, terms = fits(p, q)
            if ok:
                waves = -(-q // n_data)
                return PartitionPlan(p, q, total, terms, True, waves)
            q *= 2
            if q > 1 << 24:
                break

    # Best practice 3: p so that Theta shard ~ C/2, then smallest q.
    p = 1
    while n * f * dtype_bytes / p > hbm_bytes / 2 and p < n_model:
        p *= 2
    p = min(p, n_model)
    q = 1
    while q <= 1 << 24:
        ok, total, terms = fits(p, q)
        if ok:
            waves = -(-q // n_data)
            return PartitionPlan(p, q, total, terms, True, waves)
        q *= 2
    total, terms = _bytes_per_device(m, n, nnz, f, p, q, fill, dtype_bytes, eps)
    return PartitionPlan(p, q, total, terms, False, -(-q // n_data))
