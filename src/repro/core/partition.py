"""Partition planner — paper eq. (8), generalized to a TPU mesh.

cuMF chooses p (Theta column shards == data parallelism) and q (X row
batches == model parallelism) so that a single device holds::

    m f / q  +  n f / p  +  |R^(ij)|  +  (m/q) f^2  +  (m/q) f  +  eps  <  C

with the best practices of §4.3:
  1. if p = 1 fits, stay on one device (SU-ALS degenerates to MO-ALS),
  2. stop growing q once p = 1 fits,
  3. otherwise start from p with n f / p ~ C/2 and pick the smallest q.

On a mesh, p maps to the "model" axis (and "pod" x "model" when multi-pod)
and q to the "data" axis; q larger than the data axis runs in waves
(elasticity, §4.4) — `waves` reports how many.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

GiB = 1 << 30


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    p: int                  # column shards of Theta (data parallelism)
    q: int                  # row shards/batches of X (model parallelism)
    bytes_per_device: int
    terms: dict
    fits: bool
    waves: int = 1          # q-batches executed per device wave (elasticity)

    def describe(self) -> str:
        t = ", ".join(f"{k}={v / GiB:.3f}GiB" for k, v in self.terms.items())
        return (f"p={self.p} q={self.q} waves={self.waves} "
                f"total={self.bytes_per_device / GiB:.3f}GiB fits={self.fits} [{t}]")


def streaming_acc_bytes(n: int, f: int, dtype_bytes: int = 4) -> int:
    """Resident accumulate-Theta state of a streaming run: the A [n, f, f],
    B [n, f], c [n] Hermitian accumulators (GLOBAL size — the planner
    divides by p, each model shard owning only its theta rows' systems)."""
    return n * (f * f + f + 1) * dtype_bytes


def _bytes_per_device(m, n, nnz, f, p, q, fill=1.5, dtype_bytes=4, eps=512 << 20,
                      buffers=1, acc_bytes=0):
    terms = {
        "X_batch": m * f * dtype_bytes // q,
        "Theta_shard": n * f * dtype_bytes // p,
        # idx+val, padded; ``buffers`` > 1 models the §4.4 preload buffers an
        # out-of-core run keeps resident (current shard + prefetched next ones)
        "R_shard": int(2 * nnz * dtype_bytes * fill) // (p * q) * buffers,
        "A_batch": m * f * f * dtype_bytes // q,
        "B_batch": m * f * dtype_bytes // q,
        "eps": eps,
    }
    if acc_bytes:
        # streaming accumulate-Theta residents, p-sharded like Theta: each
        # model shard holds only its own theta rows' accumulated systems
        terms["Herm_acc"] = acc_bytes // p
    return sum(terms.values()), terms


def plan_partitions(
    m: int, n: int, nnz: int, f: int,
    hbm_bytes: int = 16 * GiB,
    n_model: int = 16,          # devices on the "model" axis (p candidates)
    n_data: int = 16,           # devices on the "data" axis (q waves base)
    fill: float = 1.5,
    dtype_bytes: int = 4,
    eps: int = 512 << 20,
) -> PartitionPlan:
    """Choose (p, q) per paper §4.3 for the given problem and mesh."""
    # Best practice 1/2: smallest q with p=1, if Theta fits a device.
    def fits(p, q):
        total, terms = _bytes_per_device(m, n, nnz, f, p, q, fill, dtype_bytes, eps)
        return total < hbm_bytes, total, terms

    if n * f * dtype_bytes + eps < hbm_bytes // 2:
        p = 1
        q = 1
        while True:
            ok, total, terms = fits(p, q)
            if ok:
                waves = -(-q // n_data)
                return PartitionPlan(p, q, total, terms, True, waves)
            q *= 2
            if q > 1 << 24:
                break

    # Best practice 3: p so that Theta shard ~ C/2, then smallest q.
    p = 1
    while n * f * dtype_bytes / p > hbm_bytes / 2 and p < n_model:
        p *= 2
    p = min(p, n_model)
    q = 1
    while q <= 1 << 24:
        ok, total, terms = fits(p, q)
        if ok:
            waves = -(-q // n_data)
            return PartitionPlan(p, q, total, terms, True, waves)
        q *= 2
    total, terms = _bytes_per_device(m, n, nnz, f, p, q, fill, dtype_bytes, eps)
    return PartitionPlan(p, q, total, terms, False, -(-q // n_data))


def plan_for(
    m: int, n: int, nnz: int, f: int,
    p: int, q: int,
    *,
    n_data: int = 16,
    hbm_bytes: int = 16 * GiB,
    fill: float = 1.5,
    dtype_bytes: int = 4,
    eps: int = 512 << 20,
    buffers: int = 1,
    acc_bytes: int = 0,
    bin_fills: Optional[Sequence[Tuple[int, int]]] = None,
    auto: bool = False,
    degrees=None,
    tune_cache=None,
    k_multiple: int = 8,
) -> PartitionPlan:
    """Cost a *given* (p, q) choice — the forced-plan entry point.

    ``plan_partitions`` searches for (p, q); this prices one the caller picked
    (tests force ``waves >= 2`` plans on in-core-sized data; the out-of-core
    example caps the simulated device).  ``buffers`` counts how many R-shard
    buffers stay device-resident at once: 1 is the in-core bound of eq. (8),
    an out-of-core run double-buffering ``depth`` shards ahead needs
    ``depth + 1`` (§4.4 preload).  ``acc_bytes`` prices the streaming
    accumulate-Theta residents (``streaming_acc_bytes(n, f)``) as their own
    p-sharded term — each model shard owns 1/p of the accumulated systems —
    instead of overloading the flat ``eps`` headroom.

    ``bin_fills`` prices a degree-binned layout: per-bin ``(padded_slots,
    nnz)`` pairs (e.g. ``RatingStore.bin_fill_pairs()``) whose aggregate
    ``sum(slots) / sum(nnz)`` — the fill a binned store actually streams —
    overrides the scalar ``fill``.  On power-law data this is a multi-x
    reduction of the R_shard term, which is exactly where binning buys its
    capacity headroom.

    ``auto=True`` derives ``bin_fills`` itself: ``degrees`` (the per-row
    nnz counts) is swept through ``repro.core.autotune.tune_plan_fills`` —
    argmin of padded slots over the (n_bins, k_multiple) ladder, cached in
    ``tune_cache`` — and the winning rung's per-bin pairs price R_shard.
    """
    if auto:
        from repro.core import autotune as _autotune
        assert degrees is not None, \
            "plan_for(auto=True) needs degrees= (per-row nnz counts)"
        res = _autotune.tune_plan_fills(
            m, n, nnz, f, p, q, degrees=degrees, k_multiple=k_multiple,
            cache=tune_cache)
        want = res.config.to_obj()
        for cand in res.candidates:
            if cand["config"] == want:
                bin_fills = cand["bin_fills"]
                break
    if bin_fills:
        slots = sum(int(s) for s, _ in bin_fills)
        true_nnz = sum(int(z) for _, z in bin_fills)
        fill = slots / max(true_nnz, 1)
    total, terms = _bytes_per_device(
        m, n, nnz, f, p, q, fill, dtype_bytes, eps, buffers, acc_bytes)
    return PartitionPlan(p, q, total, terms, total < hbm_bytes, -(-q // n_data))


# ---------------------------------------------------------------------------
# Schedule export: the planner's (q, waves) turned into explicit row ranges.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QBatch:
    """One of the q X-row batches (the §4.4 streaming unit)."""

    index: int       # global batch number in [0, q)
    row_start: int   # first X row of the batch (inclusive)
    row_stop: int    # one past the last X row (exclusive)

    @property
    def rows(self) -> int:
        return self.row_stop - self.row_start


def batch_ranges(m: int, q: int) -> Tuple[QBatch, ...]:
    """Split ``m`` rows into ``q`` balanced contiguous batches.

    Sizes differ by at most one row and every row lands in exactly one batch
    (the invariant the wave-coverage property test pins down).
    """
    assert m >= 0 and q >= 1, (m, q)
    base, rem = divmod(m, q)
    out = []
    start = 0
    for b in range(q):
        size = base + (1 if b < rem else 0)
        out.append(QBatch(index=b, row_start=start, row_stop=start + size))
        start += size
    assert start == m
    return tuple(out)


def export_schedule(
    plan: PartitionPlan, m: int, n_data: Optional[int] = None,
) -> Tuple[Tuple[QBatch, ...], ...]:
    """Explicit per-iteration wave schedule for a plan's q batches.

    Returns one tuple of QBatches per wave: wave ``w`` streams batches
    ``[w * n_data, min((w+1) * n_data, q))`` through the data axis — each
    device on the axis takes one batch per wave, so ``len(waves) * n_data >=
    q`` always, and ``len(waves) == plan.waves`` when ``n_data`` matches the
    axis size the plan was computed for (the default reconstructs it from
    ``plan.waves``).
    """
    q = plan.q
    if n_data is None:
        n_data = -(-q // plan.waves)
    assert n_data >= 1
    batches = batch_ranges(m, q)
    n_waves = -(-q // n_data)
    return tuple(
        batches[w * n_data:(w + 1) * n_data] for w in range(n_waves))
