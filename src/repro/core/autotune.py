"""Layout autotuner: cuMF Algorithm-2 sweep over the binning knobs.

cuMF tunes its tile sizes by *measuring* a ladder of candidates and keeping
the argmin (Alg. 2 "try the ladder, keep the argmin"); Tan 1808.03843
generalizes the same loop into autotuned memory-optimized layouts.  This
module applies that loop to the knobs PR 9's degree-binned layout left
hand-picked:

- ALS streaming: ``n_bins`` (degree bins per orientation) and the bin
  ``k_multiple`` (ELL lane rounding of each bin's K),
- SGD blocking: ``per_tile_k`` / ``degree_sort`` on the ``BlockGrid``.

The default mode is **analytic**: each candidate is priced by the exact
per-iteration streamed bytes the planner/schedule layer would predict for
it — the same integers ``predicted_stream_stats`` derives from a real
``RatingStore``, computed here from degree vectors alone so no candidate
store is ever materialized.  The optional **measured** mode additionally
builds the candidate layout and times one real solve-X wave through
``obs.phase(cat="autotune")``, scoring by seconds instead of bytes (the
paper's measured sweep; analytic remains the tie-free default because it is
deterministic and exact).

Winners are cached in a JSON :class:`TuneCache` keyed by (shape bucket,
degree-skew quantiles, topology, backend) and stamped with provenance like
``BENCH_HISTORY.jsonl`` rows, so repeated runs of the same problem class
skip the sweep; a shape or skew change misses the key and re-tunes.

Wired through the stack: ``plan_for(auto=True, degrees=...)``,
``RatingStore(n_bins="auto")`` and ``block_ell(per_tile_k="auto")`` consult
the cache, the streaming drivers record the chosen config + cache hit/miss
in the ledger run context, and the example/benches grow ``--autotune``.
See TUNING.md for the workflow.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import plan_for
from repro.sparse.padded import bin_caps, round_k

TUNECACHE_SCHEMA = "repro.core/tunecache-v1"

#: default ALS sweep ladder: bin counts x bin lane multiples (n_bins = 1 is
#: the unbinned baseline, where the lane multiple is inert)
ALS_N_BINS_LADDER: Tuple[int, ...] = (1, 2, 4, 8)
#: default SGD sweep ladder: (per_tile_k, degree_sort) — sorted-without-
#: per-tile-K is pointless (sorting only changes which tiles get a small K)
SGD_LADDER: Tuple[Tuple[bool, bool], ...] = (
    (False, False), (True, False), (True, True))


@dataclasses.dataclass(frozen=True)
class LayoutConfig:
    """One rung of the sweep ladder — the knobs PR 9 left hand-picked."""

    n_bins: int = 1
    k_multiple: int = 8
    per_tile_k: bool = False
    degree_sort: bool = False

    def to_obj(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_obj(cls, obj: dict) -> "LayoutConfig":
        return cls(**{k: obj[k] for k in
                      ("n_bins", "k_multiple", "per_tile_k", "degree_sort")
                      if k in obj})


@dataclasses.dataclass
class TuneResult:
    """Outcome of one sweep (or one cache hit)."""

    config: LayoutConfig
    score: float             # predicted streamed bytes/iter (analytic),
    #                        # dispatched slots (SGD), or seconds (measured)
    unit: str                # "bytes" | "slots" | "seconds"
    key: str                 # TuneCache key the result lives under
    cache_hit: bool
    mode: str                # "analytic" | "measured"
    candidates: list = dataclasses.field(default_factory=list)
    grid = None              # measured/SGD side-channel, never serialized

    def to_obj(self) -> dict:
        """Ledger/JSON form — what the drivers record as run context."""
        return {"config": self.config.to_obj(), "score": self.score,
                "unit": self.unit, "key": self.key,
                "cache_hit": self.cache_hit, "mode": self.mode}


def provenance() -> dict:
    """Cache-entry provenance, mirroring ``benchmarks/history.py``."""
    import datetime

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    try:
        import jax
        backend = jax.default_backend()
        jax_ver = jax.__version__
    except Exception:                      # tuning works without devices
        backend, jax_ver = "none", "none"
    return {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "jax": jax_ver,
        "backend": backend,
        "schema": TUNECACHE_SCHEMA,
    }


def _backend_tag() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "none"


def skew_signature(degrees: np.ndarray) -> str:
    """Degree-skew summary for the cache key: the [0.5, 0.9, 0.99, max]
    quantiles normalized by the mean, rounded to one decimal.  Two problems
    with the same shape bucket and the same (coarse) skew profile bin the
    same way, so they share a tuned config."""
    d = np.asarray(degrees, dtype=np.float64)
    if d.size == 0 or d.max() <= 0:
        return "flat"
    mean = max(d.mean(), 1e-12)
    qs = np.quantile(d, [0.5, 0.9, 0.99, 1.0]) / mean
    return ",".join(f"{v:.1f}" for v in qs)


def tune_key(solver: str, m: int, n: int, nnz: int,
             degrees: np.ndarray, *, p: int = 1, q: int = 1,
             k_multiple: int = 8, backend: Optional[str] = None) -> str:
    """Cache key: (solver, log2 shape buckets, skew quantiles, topology,
    backend).  Shapes are bucketed to the nearest power of two so minor
    size drift hits, while a real scale change (2x) misses and re-tunes."""
    bucket = lambda v: int(round(np.log2(max(int(v), 1))))
    return "|".join([
        solver,
        f"m=2^{bucket(m)}", f"n=2^{bucket(n)}", f"nnz=2^{bucket(nnz)}",
        f"skew={skew_signature(degrees)}",
        f"p={int(p)}", f"q={int(q)}", f"km={int(k_multiple)}",
        backend if backend is not None else _backend_tag(),
    ])


class TuneCache:
    """JSON-backed winner cache (``repro.core/tunecache-v1``).

    ``path=None`` keeps the cache in-process only (tests, throwaway runs);
    with a path every ``put`` rewrites the file atomically, so the cache
    survives across processes like ``BENCH_HISTORY.jsonl`` does.  Entries
    carry the winning config, its score, the full candidate ladder, and a
    provenance stamp; ``invalidate()`` drops one key (or everything) —
    the refresh workflow documented in TUNING.md.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._data = {"schema": TUNECACHE_SCHEMA, "entries": {}}
        if path is not None and os.path.exists(path):
            with open(path) as fh:
                data = json.load(fh)
            # a schema we don't speak is a miss, not an error
            if data.get("schema") == TUNECACHE_SCHEMA:
                self._data = data

    def __len__(self) -> int:
        return len(self._data["entries"])

    def get(self, key: str) -> Optional[dict]:
        return self._data["entries"].get(key)

    def put(self, key: str, entry: dict) -> dict:
        entry = dict(entry)
        entry.setdefault("provenance", provenance())
        self._data["entries"][key] = entry
        self._flush()
        return entry

    def invalidate(self, key: Optional[str] = None) -> None:
        if key is None:
            self._data["entries"] = {}
        else:
            self._data["entries"].pop(key, None)
        self._flush()

    def _flush(self) -> None:
        if self.path is None:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self._data, fh, indent=1, sort_keys=True)
        os.replace(tmp, self.path)


def _as_cache(cache) -> Optional[TuneCache]:
    if cache is None or isinstance(cache, TuneCache):
        return cache
    return TuneCache(str(cache))


# ---------------------------------------------------------------------------
# Analytic pricing: the exact integers a candidate store would stream.
# ---------------------------------------------------------------------------

def _binned_rows_bytes(degrees: np.ndarray, n_bins: int, k_multiple: int,
                       k_parent: int) -> Tuple[int, int]:
    """(bytes, slots) of one orientation's rows binned at (n_bins,
    k_multiple) — mirrors ``bin_padded`` exactly: ~log-spaced caps from the
    max degree, each bin re-padded at ``min(round_k(max member degree),
    k_parent)``, rows streamed as idx+val slots (8 B) plus cnt (4 B)."""
    deg = np.asarray(degrees, dtype=np.int64)
    kmax = int(deg.max()) if deg.size else 0
    caps = bin_caps(kmax, n_bins, k_multiple)
    assign = np.searchsorted(np.asarray(caps, dtype=np.int64),
                             np.maximum(deg, 1), side="left")
    total_bytes = 0
    total_slots = 0
    for b in range(len(caps)):
        sel = assign == b
        rows_b = int(sel.sum())
        if rows_b == 0:
            continue
        kb = min(round_k(int(deg[sel].max()), k_multiple), k_parent)
        total_bytes += rows_b * (kb * 8 + 4)
        total_slots += rows_b * kb
    return total_bytes, total_slots


def _stacked_bytes(deg: np.ndarray, n_bins: int, k_multiple: int,
                   k_parent: int, p: int) -> Tuple[int, int, list]:
    """(bytes, slots, pairs) of a ``[q, n]`` per-batch degree matrix binned
    batch-uniform — mirrors ``sparse.padded.stack_binned_parts``: global
    caps, per-bin rows = max per-batch member count rounded up to p, K =
    global rounded max member degree.  ``pairs`` are the per-bin
    (padded_slots, nnz) the planner prices."""
    q, _n = deg.shape
    kmax = int(deg.max()) if deg.size else 0
    caps = bin_caps(kmax, n_bins, k_multiple)
    assign = np.searchsorted(np.asarray(caps, dtype=np.int64),
                             np.maximum(deg, 1), side="left")
    total_bytes = 0
    total_slots = 0
    pairs = []
    for b in range(len(caps)):
        sel = assign == b                                  # [q, n]
        max_members = int(sel.sum(axis=1).max())
        if max_members == 0:
            continue
        kb = min(round_k(int(deg[sel].max()), k_multiple), k_parent)
        rows_b = -(-max_members // p) * p
        total_bytes += q * rows_b * (kb * 8 + 4)
        total_slots += q * rows_b * kb
        pairs.append((q * rows_b * kb, int(deg[sel].sum())))
    return total_bytes, total_slots, pairs


def _batch_item_degrees(r, q: int) -> np.ndarray:
    """[q, n] per-batch item degrees of a PaddedELL's q balanced row
    batches — the theta-half layout input, one vectorized pass."""
    m_pad = -(-r.m // q) * q
    rows_per = m_pad // q
    k = np.arange(r.K, dtype=np.int32)[None, :]
    live = k < r.cnt[:, None]
    users = np.broadcast_to(
        np.arange(r.m, dtype=np.int64)[:, None], r.idx.shape)[live]
    items = r.idx[live].astype(np.int64)
    deg = np.zeros((q, r.n_cols), dtype=np.int64)
    np.add.at(deg, (users // rows_per, items), 1)
    return deg


def _model_shard_k(r, p: int, k_multiple: int) -> int:
    """K_loc of ``partition_padded(r, p)`` without materializing shards."""
    if p == 1:
        return r.K
    npp = r.n_cols // p
    k = np.arange(r.K, dtype=np.int32)[None, :]
    live = k < r.cnt[:, None]
    shard_of = np.where(live, r.idx // npp, -1)
    kmax = 0
    for i in range(p):
        kmax = max(kmax, int((shard_of == i).sum(axis=1).max()))
    return round_k(kmax, k_multiple)


def predicted_als_bytes(r, q: int, cfg: LayoutConfig, *, p: int = 1,
                        f: int = 16,
                        deg_t: Optional[np.ndarray] = None) -> dict:
    """Exact per-iteration streamed bytes of one (r, q, p) problem under
    ``cfg`` — the same totals ``predicted_stream_stats`` would sum over a
    real ``RatingStore(n_bins=cfg.n_bins, k_multiple=cfg.k_multiple)``'s
    schedule.  ``deg_t`` (the ``[q, n]`` per-batch item degrees) can be
    passed in so a sweep computes it once."""
    km = cfg.k_multiple
    m_pad = -(-r.m // q) * q
    pad_deg = np.zeros(m_pad, dtype=np.int64)
    pad_deg[:r.m] = r.cnt
    if deg_t is None:
        deg_t = _batch_item_degrees(r, q)
    k_loc_t = round_k(int(deg_t.max()) if deg_t.size else 0, km)
    bin_fills = None
    # solve-X half
    if p > 1:
        k_model = _model_shard_k(r, p, km)
        x_bytes = m_pad * p * (k_model * 8 + 4)
        x_slots = m_pad * p * k_model
    elif cfg.n_bins > 1:
        x_bytes, x_slots = _binned_rows_bytes(pad_deg, cfg.n_bins, km, r.K)
    else:
        x_bytes, x_slots = m_pad * (r.K * 8 + 4), m_pad * r.K
    # accumulate-Theta half (+ the config-independent fresh X slices)
    if cfg.n_bins > 1 and p > 1:
        t_bytes, t_slots, bin_fills = _stacked_bytes(
            deg_t, cfg.n_bins, km, k_loc_t, p)
    elif cfg.n_bins > 1:
        t_bytes = t_slots = 0
        bin_fills = []
        for j in range(q):
            bj, sj = _binned_rows_bytes(deg_t[j], cfg.n_bins, km, k_loc_t)
            t_bytes += bj
            t_slots += sj
            bin_fills.append((sj, int(deg_t[j].sum())))
    else:
        t_bytes = q * r.n_cols * (k_loc_t * 8 + 4)
        t_slots = q * r.n_cols * k_loc_t
    t_bytes += m_pad * f * 4
    nnz = int(r.cnt.sum())
    return {"bytes": x_bytes + t_bytes, "x_bytes": x_bytes,
            "t_bytes": t_bytes, "slots": x_slots + t_slots,
            "fill": (x_slots + t_slots) / max(2 * nnz, 1),
            "bin_fills": bin_fills}


# ---------------------------------------------------------------------------
# The sweeps.
# ---------------------------------------------------------------------------

def als_ladder(k_multiple: int = 8,
               n_bins_ladder: Sequence[int] = ALS_N_BINS_LADDER
               ) -> list[LayoutConfig]:
    """Default ALS candidate ladder: the unbinned baseline, then every
    (n_bins, lane multiple) rung — the lane multiple only matters once
    binning re-rounds each bin's K, so n_bins = 1 carries just the base."""
    out = [LayoutConfig(n_bins=1, k_multiple=k_multiple)]
    for nb in n_bins_ladder:
        if nb <= 1:
            continue
        for km in (k_multiple, 2 * k_multiple):
            out.append(LayoutConfig(n_bins=nb, k_multiple=km))
    return out


def tune_als_layout(r, q: int, *, p: int = 1, f: int = 16,
                    k_multiple: int = 8,
                    ladder: Optional[Sequence[LayoutConfig]] = None,
                    cache=None, mode: str = "analytic",
                    tracer=None, registry=None) -> TuneResult:
    """Alg.-2 sweep over the ALS layout ladder for one (r, q, p) problem.

    Analytic mode prices every rung by :func:`predicted_als_bytes` (plus a
    ``plan_for(bin_fills=...)`` device-bytes check carried per candidate)
    and keeps the argmin of predicted streamed bytes per iteration, ties
    broken toward fewer bins (fewer compiled kernel shapes).  Measured mode
    re-scores the analytic top rungs by timing one real solve-X wave per
    candidate inside an ``obs.phase(cat="autotune")`` span.  The winner is
    cached under :func:`tune_key`; a hit skips the sweep entirely.
    """
    assert mode in ("analytic", "measured"), mode
    cache = _as_cache(cache)
    nnz = int(r.cnt.sum())
    key = tune_key("als", r.m, r.n_cols, nnz, r.cnt, p=p, q=q,
                   k_multiple=k_multiple)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(
                config=LayoutConfig.from_obj(hit["config"]),
                score=hit["score"], unit=hit.get("unit", "bytes"), key=key,
                cache_hit=True, mode=hit.get("mode", "analytic"),
                candidates=hit.get("candidates", []))
    from repro.obs.trace import phase
    ladder = list(ladder) if ladder is not None else als_ladder(k_multiple)
    deg_t = _batch_item_degrees(r, q)
    candidates = []
    for cfg in ladder:
        with phase("autotune.candidate", cat="autotune", tracer=tracer,
                   registry=registry, solver="als", n_bins=cfg.n_bins,
                   k_multiple=cfg.k_multiple):
            priced = predicted_als_bytes(r, q, cfg, p=p, f=f, deg_t=deg_t)
            plan = plan_for(r.m, r.n_cols, nnz, f, p, q,
                            fill=priced["fill"],
                            bin_fills=priced["bin_fills"])
            cand = {"config": cfg.to_obj(), "score": priced["bytes"],
                    "unit": "bytes", "fill": priced["fill"],
                    "bytes_per_device": plan.bytes_per_device}
            if mode == "measured":
                cand["seconds"] = _measure_als_candidate(
                    r, q, cfg, f=f, tracer=tracer, registry=registry)
            candidates.append(cand)
    score_of = ((lambda c: (c["seconds"], c["config"]["n_bins"]))
                if mode == "measured"
                else (lambda c: (c["score"], c["config"]["n_bins"])))
    best = min(candidates, key=score_of)
    result = TuneResult(
        config=LayoutConfig.from_obj(best["config"]),
        score=best["seconds"] if mode == "measured" else best["score"],
        unit="seconds" if mode == "measured" else "bytes",
        key=key, cache_hit=False, mode=mode, candidates=candidates)
    if cache is not None:
        cache.put(key, {"config": result.config.to_obj(),
                        "score": result.score, "unit": result.unit,
                        "mode": mode, "candidates": candidates})
    return result


def _measure_als_candidate(r, q: int, cfg: LayoutConfig, *, f: int,
                           tracer=None, registry=None) -> float:
    """Measured rung: build the candidate store and time ONE real solve-X
    wave (wave 0's rows through the binned/uniform row update).  All timing
    flows through the ``obs`` phase clock — the sweep reads the span's own
    category delta, so no bare timers leak in (obs-routing rule)."""
    import jax.numpy as jnp

    from repro.core import als as als_mod
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import phase
    from repro.outofcore.store import RatingStore

    reg = registry if registry is not None else MetricsRegistry()
    store = RatingStore(r, q=q, k_multiple=cfg.k_multiple,
                        n_bins=cfg.n_bins)
    acfg = als_mod.AlsConfig(f=f, lam=0.05, iters=1, mode="ref")
    theta = jnp.zeros((store.n, f), jnp.float32)
    rows_per = store.m_pad // q
    before = reg.phase_seconds().get("autotune", 0.0)
    with phase("autotune.measure_wave", cat="autotune", tracer=tracer,
               registry=reg, n_bins=cfg.n_bins,
               k_multiple=cfg.k_multiple):
        if cfg.n_bins > 1:
            bsl = store.x_slice_binned(0, rows_per)
            np.asarray(als_mod.update_rows_binned(theta, bsl, acfg))
        else:
            idx, val, cnt = store.x_slice_triplet(0, rows_per)
            np.asarray(als_mod.update_rows(
                theta, jnp.asarray(idx), jnp.asarray(val),
                jnp.asarray(cnt), acfg))
    return reg.phase_seconds().get("autotune", 0.0) - before


def tune_sgd_layout(ell, g: int, *, k_multiple: int = 8,
                    ladder: Optional[Sequence[Tuple[bool, bool]]] = None,
                    cache=None, tracer=None, registry=None) -> TuneResult:
    """Alg.-2 sweep over the SGD blocking ladder for one (ell, g) problem.

    Builds each rung's ``BlockGrid`` and scores the slots its kernels
    actually dispatch (``grid.padded_slots`` — per-tile K respected), the
    exact quantity the streaming SGD ledger measures.  The winning grid
    rides back on ``TuneResult.grid`` so ``block_coo(per_tile_k="auto")``
    doesn't build it twice; cache hits return config-only (the caller
    rebuilds)."""
    from repro.obs.trace import phase
    from repro.sgd.blocking import block_ell

    cache = _as_cache(cache)
    nnz = int(ell.cnt.sum())
    key = tune_key("sgd", ell.m, ell.n_cols, nnz, ell.cnt, q=g,
                   k_multiple=k_multiple)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(
                config=LayoutConfig.from_obj(hit["config"]),
                score=hit["score"], unit=hit.get("unit", "slots"), key=key,
                cache_hit=True, mode=hit.get("mode", "analytic"),
                candidates=hit.get("candidates", []))
    ladder = list(ladder) if ladder is not None else list(SGD_LADDER)
    candidates = []
    grids = {}
    for ptk, dsort in ladder:
        cfg = LayoutConfig(k_multiple=k_multiple, per_tile_k=ptk,
                           degree_sort=dsort)
        with phase("autotune.candidate", cat="autotune", tracer=tracer,
                   registry=registry, solver="sgd", per_tile_k=ptk,
                   degree_sort=dsort):
            grid = block_ell(ell, g, k_multiple=k_multiple,
                             per_tile_k=ptk, degree_sort=dsort)
        grids[(ptk, dsort)] = grid
        candidates.append({"config": cfg.to_obj(),
                           "score": int(grid.padded_slots),
                           "unit": "slots", "fill": grid.fill})
    best = min(candidates,
               key=lambda c: (c["score"], c["config"]["per_tile_k"],
                              c["config"]["degree_sort"]))
    cfg = LayoutConfig.from_obj(best["config"])
    result = TuneResult(config=cfg, score=best["score"], unit="slots",
                        key=key, cache_hit=False, mode="analytic",
                        candidates=candidates)
    result.grid = grids[(cfg.per_tile_k, cfg.degree_sort)]
    if cache is not None:
        cache.put(key, {"config": cfg.to_obj(), "score": best["score"],
                        "unit": "slots", "mode": "analytic",
                        "candidates": candidates})
    return result


def tune_plan_fills(m: int, n: int, nnz: int, f: int, p: int, q: int, *,
                    degrees, k_multiple: int = 8, cache=None) -> TuneResult:
    """Degree-summary sweep backing ``plan_for(auto=True)``: with only a
    row-degree vector (no index data), bin the rows over the ladder, keep
    the argmin of padded slots, and hand back the winner's per-bin
    ``(slots, nnz)`` pairs as ``TuneResult.candidates[...]["bin_fills"]``
    for the planner's R_shard pricing.  Cached like the full sweeps."""
    cache = _as_cache(cache)
    deg = np.asarray(degrees, dtype=np.int64)
    key = tune_key("plan", m, n, nnz, deg, p=p, q=q, k_multiple=k_multiple)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return TuneResult(
                config=LayoutConfig.from_obj(hit["config"]),
                score=hit["score"], unit="slots", key=key, cache_hit=True,
                mode="analytic", candidates=hit.get("candidates", []))
    k_parent = round_k(int(deg.max()) if deg.size else 0, k_multiple)
    candidates = []
    for cfg in als_ladder(k_multiple):
        caps = bin_caps(k_parent, cfg.n_bins, cfg.k_multiple)
        assign = np.searchsorted(np.asarray(caps, dtype=np.int64),
                                 np.maximum(deg, 1), side="left")
        pairs = []
        for b in range(len(caps)):
            sel = assign == b
            rows_b = int(sel.sum())
            if rows_b == 0:
                continue
            kb = min(round_k(int(deg[sel].max()), cfg.k_multiple), k_parent)
            pairs.append((rows_b * kb, int(deg[sel].sum())))
        candidates.append({"config": cfg.to_obj(),
                           "score": sum(s for s, _ in pairs),
                           "unit": "slots", "bin_fills": pairs})
    best = min(candidates,
               key=lambda c: (c["score"], c["config"]["n_bins"]))
    result = TuneResult(config=LayoutConfig.from_obj(best["config"]),
                        score=best["score"], unit="slots", key=key,
                        cache_hit=False, mode="analytic",
                        candidates=candidates)
    if cache is not None:
        cache.put(key, {"config": result.config.to_obj(),
                        "score": result.score, "unit": "slots",
                        "mode": "analytic", "candidates": candidates})
    return result
