"""Objective and evaluation metrics for ALS MF (paper eq. (1))."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


@functools.partial(jax.jit, static_argnames=())
def _sq_err_padded(x, theta, idx, val, cnt):
    """Sum of squared errors over the nonzeros of a PaddedELL batch.

    x     [m, f]  row factors for these rows
    theta [n, f]  column factors
    idx   [m, K], val [m, K], cnt [m]
    """
    g = jnp.take(theta, idx, axis=0)                    # [m, K, f]
    pred = jnp.einsum("uf,ukf->uk", x, g)
    mask = kref.mask_from_cnt(cnt, idx.shape[1], x.dtype)
    err = (val - pred) * mask
    return jnp.sum(err * err), jnp.sum(cnt)


def rmse_padded(x, theta, idx, val, cnt) -> jax.Array:
    """Root mean squared error over the nonzeros of (idx, val, cnt)."""
    sse, n = _sq_err_padded(x, theta, idx, val, cnt)
    return jnp.sqrt(sse / jnp.maximum(n, 1))


def objective_j(x, theta, idx, val, cnt_rows, cnt_cols, lam) -> jax.Array:
    """Paper eq. (1): squared error + weighted-lambda regularizer.

    cnt_rows [m] = n_{x_u}; cnt_cols [n] = n_{theta_v}.
    """
    sse, _ = _sq_err_padded(x, theta, idx, val, cnt_rows)
    reg = lam * (
        jnp.sum(cnt_rows.astype(x.dtype) * jnp.sum(x * x, axis=1))
        + jnp.sum(cnt_cols.astype(x.dtype) * jnp.sum(theta * theta, axis=1))
    )
    return sse + reg
