"""cuMF core: ALS matrix factorization (the paper's contribution) in JAX.

- als.py       : single-device MO-ALS iteration + full training driver.
- objective.py : cost J (weighted-lambda), train/test RMSE.
- partition.py : the eq. (8) partition planner (choose p, q from HBM budget).
"""

from repro.core.als import AlsConfig, AlsState, als_init, als_iteration, als_train
from repro.core.objective import rmse_padded, objective_j
from repro.core.partition import PartitionPlan, plan_partitions

__all__ = [
    "AlsConfig", "AlsState", "als_init", "als_iteration", "als_train",
    "rmse_padded", "objective_j", "PartitionPlan", "plan_partitions",
]
