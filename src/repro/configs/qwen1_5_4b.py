"""qwen1.5-4b [dense]: 40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.

QKV bias (the Qwen1.5 signature), RoPE, SwiGLU.  [hf:Qwen/Qwen1.5-*; hf]
20 heads don't divide the 16-wide model axis: padded to 32 for TP
(decode uses flash-decode with replicated projections instead).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="qwen1.5-4b",
    n_layers=40, d_model=2560, n_heads=20, n_kv=20, d_head=128,
    d_ff=6912, vocab=151936,
    qkv_bias=True, rope_theta=1e6, mlp="swiglu",
    tie_embeddings=False, head_pad_to=16,
)

ARCH = ArchSpec(
    model=MODEL,
    source="hf:Qwen/Qwen1.5-4B (scaled family config per assignment)",
    fsdp=True, serve_seq_shard=True, microbatch=4,
)

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=128, vocab=128, qkv_bias=True, mlp="swiglu",
    tie_embeddings=False,
)
