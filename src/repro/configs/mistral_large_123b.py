"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]

The framework's flagship memory case: trains only under 2D FSDP+TP with
Adafactor-compatible layouts (AdamW fits at 256 chips: ~12 GiB/chip of
optimizer+param state, see EXPERIMENTS.md §Dry-run); 32k decode requires
the sequence-sharded KV cache + flash-decode, and the FFN is 2D-sharded
when serving.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="mistral-large-123b",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, d_head=128,
    d_ff=28672, vocab=32768,
    rope_theta=1e6, mlp="swiglu", tie_embeddings=False,
)

ARCH = ArchSpec(
    model=MODEL,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    fsdp=True, serve_seq_shard=True, serve_mlp_2d=True, microbatch=16,
    opt="adafactor",
    notes="123B dense; microbatch=16 + Adafactor keep remat activations "
          "and optimizer state under 16 GiB/chip (see EXPERIMENTS.md)",
)

SMOKE = ModelConfig(
    name="mistral-large-smoke",
    n_layers=3, d_model=64, n_heads=8, n_kv=2, d_head=16,
    d_ff=192, vocab=128, mlp="swiglu", tie_embeddings=False,
)
