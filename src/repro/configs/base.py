"""Config dataclasses: model architecture, input shapes, parallelism knobs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free archs)
    n_kv: int                    # KV heads (GQA); == n_heads for MHA
    d_head: int
    d_ff: int
    vocab: int
    block_pattern: Tuple[str, ...] = ("attn",)   # cycled: attn | rglru | rwkv
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"        # rope | sinusoidal | none
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # local attention window
    mlp: str = "swiglu"          # swiglu | gelu | geglu (rwkv blocks carry their own)
    d_rnn: Optional[int] = None  # RG-LRU width
    frontend: Optional[str] = None   # audio_stub | vision_stub (embeds input)
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # --- implementation knobs (not architecture) ---
    head_pad_to: Optional[int] = None  # zero-pad q heads for TP divisibility
    subquadratic: bool = False   # True for SSM/hybrid: eligible for long_500k

    @property
    def attn_free(self) -> bool:
        return all(b != "attn" for b in self.block_pattern)

    @property
    def padded_heads(self) -> int:
        if self.head_pad_to and self.n_heads % self.head_pad_to:
            return (self.n_heads // self.head_pad_to + 1) * self.head_pad_to
        return self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding/logits tables shard evenly:
        to 256 (the full chip count, for the tp1 256-way layout) for real
        vocabularies, to 16 for tiny smoke vocabs."""
        mult = 256 if self.vocab >= 1024 else 16
        return -(-self.vocab // mult) * mult

    @property
    def padded_kv(self) -> int:
        """MHA archs (kv == heads) must pad KV alongside Q so the GQA
        group structure (g = H/KV) survives TP head padding."""
        if self.n_kv == self.n_heads:
            return self.padded_heads
        return self.n_kv

    def params_count(self) -> int:
        """Analytic parameter count (true heads, no TP padding)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        pattern = (self.block_pattern * self.n_layers)[: self.n_layers]
        for kind in pattern:
            if kind == "attn":
                total += D * (self.n_heads + 2 * self.n_kv + self.n_heads) * self.d_head
                if self.moe is not None:
                    total += D * self.moe.n_experts + 3 * self.moe.n_experts * D * F
                elif self.mlp in ("swiglu", "geglu"):
                    total += 3 * D * F
                else:
                    total += 2 * D * F + F + D
                total += 2 * D
            elif kind == "rglru":
                R = self.d_rnn or D
                total += 2 * D * R + 4 * R + 2 * R * R + R * D + 3 * D * F + 2 * D
            elif kind == "rwkv":
                total += 4 * D * D + D * D + 2 * D * 64 + 12 * D \
                    + D * F + F * D + D * D + 2 * D
        total += D  # final norm
        return total

    def active_params_count(self) -> int:
        """MoE: only top-k experts active per token (for 6*N_active*D flops)."""
        if self.moe is None:
            return self.params_count()
        D, F = self.d_model, self.d_ff
        per_layer_all = 3 * self.moe.n_experts * D * F
        per_layer_act = 3 * self.moe.top_k * D * F
        return self.params_count() - self.n_layers * (per_layer_all - per_layer_act)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq: int
    batch: int              # global batch
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """A model config + the parallelism/implementation plan for the mesh."""
    model: ModelConfig
    source: str = ""             # provenance note
    fsdp: bool = True            # 2D param sharding for training
    serve_seq_shard: bool = False  # flash-decode over seq-sharded cache
    serve_mlp_2d: bool = False   # spread FFN over (data, model) when serving
    microbatch: int = 1          # gradient-accumulation steps for train_4k
    remat: bool = True
    opt: str = "adamw"           # adamw | adafactor (memory option for 100B+)
    notes: str = ""

    def skip_reason(self, shape: ShapeConfig) -> Optional[str]:
        if shape.name == "long_500k" and not self.model.subquadratic:
            return "SKIP(full-attention): 500k decode needs sub-quadratic arch"
        return None
