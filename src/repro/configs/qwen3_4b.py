"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk-norm (per-head RMS on q and k) + GQA — the Qwen3 signature.
[hf:Qwen/Qwen3-4B family; hf]
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="qwen3-4b",
    n_layers=36, d_model=2560, n_heads=32, n_kv=8, d_head=128,
    d_ff=9728, vocab=151936,
    qk_norm=True, rope_theta=1e6, mlp="swiglu", tie_embeddings=True,
)

ARCH = ArchSpec(
    model=MODEL,
    source="hf:Qwen/Qwen3-4B",
    fsdp=True, serve_seq_shard=True, microbatch=4,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=16,
    d_ff=128, vocab=128, qk_norm=True, mlp="swiglu", tie_embeddings=True,
)
