"""cuMF ALS — the paper's own workload as an 11th selectable config.

Shapes are the paper's Table 5 data sets.  A dry-run cell lowers one
SU-ALS update-X wave (fused hermitian -> parallel reduction -> batch
solve) at the per-device shapes implied by the partition plan (eq. 8).
"""
import dataclasses

from repro.sparse.synth import DATASETS, SynthSpec


@dataclasses.dataclass(frozen=True)
class AlsShape:
    name: str
    spec: SynthSpec
    rows_per_wave: int     # q-batch rows solved per wave (global)
    k_pad: int             # padded nnz/row within a column shard


# K_pad: mean nnz/row x skew headroom, rounded to 128 (see sparse/synth.py).
ALS_SHAPES = {
    "netflix":    AlsShape("netflix", DATASETS["netflix"], 1 << 19, 512),
    "hugewiki":   AlsShape("hugewiki", DATASETS["hugewiki"], 1 << 21, 128),
    "facebook_f100": AlsShape("facebook_f100", DATASETS["cumf_max"], 1 << 22, 256),
}
