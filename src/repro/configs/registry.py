"""Architecture registry: ``--arch <id>`` resolution + input_specs()."""
from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ModelConfig, ShapeConfig, SHAPES

_ARCH_MODULES = {
    "musicgen-medium": "repro.configs.musicgen_medium",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "internvl2-26b": "repro.configs.internvl2_26b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.ARCH


def smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.SMOKE


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, mesh=None,
                dp_spec=None, model_axis="model", seq_shard_cache=False):
    """ShapeDtypeStruct stand-ins for every model input of a (arch x shape)
    cell — weak-type-correct, shardable, no allocation.

    train:   {tokens|embeds, labels, mask}
    prefill: {tokens|embeds}
    decode:  (tokens|embeds [B], lengths [B]) + cache built separately.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    def sds(shp, dtype, spec=None):
        if mesh is not None and spec is not None:
            return jax.ShapeDtypeStruct(shp, dtype,
                                        sharding=NamedSharding(mesh, spec))
        return jax.ShapeDtypeStruct(shp, dtype)

    B, S = shape.batch, shape.seq
    dp = dp_spec
    stub = cfg.frontend in ("audio_stub", "vision_stub")

    if shape.kind in ("train", "prefill"):
        if stub:
            batch = {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16,
                                   P(dp, None, None))}
        else:
            batch = {"tokens": sds((B, S), jnp.int32, P(dp, None))}
        if shape.kind == "train":
            batch["labels"] = sds((B, S), jnp.int32, P(dp, None))
            batch["mask"] = sds((B, S), jnp.float32, P(dp, None))
        return batch

    # decode: one new token + fill state
    tok = (sds((B, cfg.d_model), jnp.bfloat16, P(dp, None)) if stub
           else sds((B,), jnp.int32, P(dp)))
    lengths = sds((B,), jnp.int32, P(dp))
    return {"tokens_or_embeds": tok, "lengths": lengths}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, *, mesh=None,
                dp_spec=None, seq_shard_cache=False, dtype=jnp.bfloat16,
                stacked: bool = False):
    """ShapeDtypeStruct tree for the decode cache of a cell.

    Default layout is per-layer (unstacked) — required at scale so the
    donated cache buffers alias in place (see transformer.init_cache)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import transformer as T

    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.batch, shape.seq, dtype,
                             stacked=stacked))

    if mesh is None:
        return cache

    def shard(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        lead = [None] if stacked else []     # layer-stack dim when stacked
        if name in ("k", "v"):
            seq = "model" if seq_shard_cache else None
            kv = None
            if not seq_shard_cache and cfg.padded_kv % mesh.shape["model"] == 0 \
               and not cfg.sliding_window:
                kv = "model"
            spec = P(*(lead + [dp_spec, seq, kv, None]))
        elif name == "pos":
            spec = P(*(lead + [dp_spec, None]))
        elif name == "s":
            spec = P(*(lead + [dp_spec, "model", None, None]))
        else:
            spec = P(*(lead + [dp_spec] + [None] * (leaf.ndim - len(lead) - 1)))
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(shard, cache)
