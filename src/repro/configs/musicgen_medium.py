"""musicgen-medium [audio]: decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048  [arXiv:2306.05284; hf]
Backbone only — the EnCodec frontend is a stub; input_specs() provides
precomputed frame embeddings.  GELU MLP + sinusoidal positions (the
original musicgen transformer), biasless.  24 heads don't divide the
16-wide model axis: q-heads are zero-padded to 32 (exact function,
+33% attn-projection flops — see DESIGN.md §head-padding).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_head=64,
    d_ff=6144, vocab=2048,
    mlp="gelu", pos_emb="sinusoidal", rope_theta=0.0,
    frontend="audio_stub", tie_embeddings=False,
    head_pad_to=16,
)

ARCH = ArchSpec(
    model=MODEL,
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
    fsdp=True, serve_seq_shard=True, microbatch=2,
    notes="audio backbone; frame embeddings stubbed per assignment",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=128, vocab=64, mlp="gelu", pos_emb="sinusoidal",
    frontend="audio_stub", tie_embeddings=False, head_pad_to=None,
)
