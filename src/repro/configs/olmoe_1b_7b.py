"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) d_ff=1024/expert
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060; hf]

64 experts shard 4-per-device over the model axis (EP); sort-based
dispatch (see models/moe.py) because GShard one-hot dispatch would cost
more flops than these d_ff=1024 experts themselves.
"""
from repro.configs.base import ArchSpec, ModelConfig
from repro.models.moe import MoEConfig

MODEL = ModelConfig(
    name="olmoe-1b-7b",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8),
    rope_theta=10_000.0, mlp="swiglu", tie_embeddings=False,
)

ARCH = ArchSpec(
    model=MODEL,
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
    fsdp=True, serve_seq_shard=False, microbatch=2,
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=64, vocab=128, moe=MoEConfig(n_experts=8, top_k=2),
    mlp="swiglu", tie_embeddings=False,
)
