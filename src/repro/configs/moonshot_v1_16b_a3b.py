"""moonshot-v1-16b-a3b [moe] (kimi/moonlight): 48L d_model=2048 16H (kv=16)
d_ff=1408/expert vocab=163840, MoE 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchSpec, ModelConfig
from repro.models.moe import MoEConfig

MODEL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1408, vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6),
    rope_theta=50_000.0, mlp="swiglu", tie_embeddings=False,
)

ARCH = ArchSpec(
    model=MODEL,
    source="hf:moonshotai/Moonlight-16B-A3B",
    fsdp=True, serve_seq_shard=False, microbatch=4,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=96, vocab=128, moe=MoEConfig(n_experts=8, top_k=2),
    mlp="swiglu", tie_embeddings=False,
)
