"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 backbone.  [arXiv:2404.16821; hf]

Backbone only per assignment: the InternViT frontend is a stub and
input_specs() provides precomputed patch embeddings [B, S, d_model].
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="internvl2-26b",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_head=128,
    d_ff=16384, vocab=92553,
    rope_theta=1e6, mlp="swiglu", tie_embeddings=False,
    frontend="vision_stub",
)

ARCH = ArchSpec(
    model=MODEL,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B",
    fsdp=True, serve_seq_shard=True, serve_mlp_2d=True, microbatch=8,
    notes="vision patch embeddings stubbed per assignment",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_head=16,
    d_ff=128, vocab=128, mlp="swiglu", tie_embeddings=False,
    frontend="vision_stub",
)
