"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.

RoPE + SwiGLU + (here trivial, kv == heads) GQA.  [arXiv:2404.14219]
32 heads divide the model axis exactly; KV heads shard 2-per-device, so
decode uses the tp_kv path (no flash-decode needed at 32k).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="phi3-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, d_head=96,
    d_ff=8192, vocab=32064,
    rope_theta=10_000.0, mlp="swiglu", tie_embeddings=False,
)

ARCH = ArchSpec(
    model=MODEL,
    source="arXiv:2404.14219 (unverified per assignment)",
    fsdp=True, serve_seq_shard=False, microbatch=4,
)

SMOKE = ModelConfig(
    name="phi3-mini-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=128, vocab=128, mlp="swiglu", tie_embeddings=False,
)
