"""Architecture configs (the 10 assigned archs + the paper's own ALS runs)."""

from repro.configs.base import ModelConfig, ShapeConfig, ArchSpec, SHAPES
from repro.configs.registry import get_arch, list_archs, smoke_config

__all__ = ["ModelConfig", "ShapeConfig", "ArchSpec", "SHAPES",
           "get_arch", "list_archs", "smoke_config"]
