"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (R, R, A).
[arXiv:2402.19427; hf]

Sub-quadratic: eligible for the long_500k cell (RG-LRU state + 2048-token
window cache => O(1) decode state).  26 layers = 8 x (rglru, rglru, attn)
+ 2 rglru tail (two scan groups).  10 heads pad to 16 for TP.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_head=256,
    d_ff=7680, vocab=256000,
    block_pattern=("rglru", "rglru", "attn"),
    d_rnn=2560, sliding_window=2048,
    rope_theta=10_000.0, mlp="geglu", tie_embeddings=True,
    head_pad_to=16, subquadratic=True,
)

ARCH = ArchSpec(
    model=MODEL,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
    fsdp=True, serve_seq_shard=False, microbatch=4,
    notes="window cache is tiny (2048); decode shards it on batch only",
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    n_layers=5, d_model=64, n_heads=4, n_kv=1, d_head=16,
    d_ff=128, vocab=128, block_pattern=("rglru", "rglru", "attn"),
    d_rnn=64, sliding_window=8, mlp="geglu", tie_embeddings=True,
    subquadratic=True,
)
