"""rwkv6-7b [ssm] "Finch": 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — data-dependent decay.  [arXiv:2404.05892; hf]

Sub-quadratic: long_500k decode carries only the [H, 64, 64] WKV state per
layer.  Head bookkeeping (64 heads x 64 dims) is internal to the rwkv
block; n_heads here is metadata only.
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="rwkv6-7b",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_head=64,
    d_ff=14336, vocab=65536,
    block_pattern=("rwkv",),
    pos_emb="none", mlp="swiglu",  # mlp field unused by rwkv blocks
    tie_embeddings=False, subquadratic=True,
)

ARCH = ArchSpec(
    model=MODEL,
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b",
    fsdp=True, serve_seq_shard=False, microbatch=4,
    notes="paper technique (attention sharding) N/A — attention-free; "
          "see DESIGN.md §Arch-applicability",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    n_layers=2, d_model=128, n_heads=2, n_kv=2, d_head=64,
    d_ff=256, vocab=128, block_pattern=("rwkv",), pos_emb="none",
    tie_embeddings=False, subquadratic=True,
)
