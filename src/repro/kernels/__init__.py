"""Pallas TPU kernels for the cuMF hot spots (+ jnp oracles).

- hermitian.py   : fused get_hermitian_x + B_u (MO-ALS, paper §3.3) — the
                   VMEM-scratch accumulator is the register-file analogue.
- batch_solve.py : batched f x f Cholesky solve (cuBLAS batch_solve analogue).
- ops.py         : jitted wrappers (gather + padding + kernel/oracle dispatch).
- ref.py         : pure-jnp oracles; the source of truth for every kernel test.
"""

from repro.kernels.ops import fused_herm, batch_solve, als_update_factor, default_mode

__all__ = ["fused_herm", "batch_solve", "als_update_factor", "default_mode"]
