"""Pallas TPU kernel: batch-Hogwild SGD update over one rating tile.

CuMF_SGD keeps a (user-block, item-block) tile's factor slices resident
while a thread block sweeps its samples.  The TPU analogue:

- ``x [mb, f]`` and ``theta [nb, f]`` live in VMEM scratch across the
  entire ELL-slot grid dimension and are written back to HBM exactly
  once per tile (the same register-file re-homing as the hermitian
  kernel's accumulator);
- the grid walks the K padded ELL slots ("arbitrary" semantics — the
  factor carry serializes them); one grid step updates all mb user rows
  concurrently, which is the batch of batch-Hogwild;
- the in-slot theta gather *and* scatter are both expressed as one-hot
  MXU matmuls (``P @ theta`` / ``P^T @ contrib`` with ``P [mb, nb]`` the
  slot's item-selection one-hot) — a systolic array wants matmuls, not
  per-row scatter ops — and item collisions inside a slot are resolved
  as the *mean* of the colliding gradients, exactly matching the oracle
  (``ref.sgd_block_ref``; summing instead diverges on power-law items).

The public wrapper pads mb/nb/f/K to tile multiples and dispatches
ref | kernel | kernel_interpret like the other ops; compilation is
routed through ``compat.pallas_call`` so CPU hosts degrade to the
interpreter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.kernels import ref as kref
from repro.kernels.ops import _pad_axis, _round_up


def _sgd_tile_kernel(lr_ref, idx_ref, val_ref, mask_ref, x0_ref, t0_ref,
                     x_out, t_out, acc_x, acc_t, *, lam: float,
                     n_slots: int):
    """One ELL-slot grid step over a full tile."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_x[...] = x0_ref[...]
        acc_t[...] = t0_ref[...]

    x = acc_x[...]                            # [mb, f]
    th = acc_t[...]                           # [nb, f]
    lr = lr_ref[0, 0]
    iv = idx_ref[...]                         # [mb, 1]
    msk = mask_ref[...][:, 0]                 # [mb]
    nb = th.shape[0]
    # one-hot item selector for this slot: [mb, nb]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (iv.shape[0], nb), 1)
    onehot = (lanes == iv).astype(jnp.float32) * msk[:, None]
    tv = jax.lax.dot_general(                 # gather: [mb, f]
        onehot, th, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    e = (val_ref[...][:, 0] - jnp.sum(x * tv, axis=-1)) * msk
    dx = msk[:, None] * (e[:, None] * tv - lam * x)
    acc_x[...] = x + lr * dx
    # theta side: mean of the colliding per-sample grads (see ref oracle),
    # both the grad sum and the collision count via one-hot MXU matmuls
    num = jax.lax.dot_general(                # scatter-sum: [nb, f]
        onehot, e[:, None] * x,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    hits = jnp.sum(onehot, axis=0)            # [nb]
    dt = num / jnp.maximum(hits, 1.0)[:, None] \
        - lam * th * (hits > 0).astype(jnp.float32)[:, None]
    acc_t[...] = th + lr * dt

    @pl.when(k == n_slots - 1)
    def _epilogue():
        x_out[...] = acc_x[...]
        t_out[...] = acc_t[...]


def sgd_tile_pallas(
    x: jax.Array,      # [mb, f]
    theta: jax.Array,  # [nb, f]
    idx: jax.Array,    # [mb, K] int32
    val: jax.Array,    # [mb, K]
    mask: jax.Array,   # [mb, K]
    lr: jax.Array,     # [1, 1]
    *,
    lam: float,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batch-Hogwild tile sweep; see module doc.  Shapes must be pre-padded."""
    mb, K = idx.shape
    nb, f = theta.shape
    kernel = functools.partial(_sgd_tile_kernel, lam=lam, n_slots=K)
    return compat.pallas_call(
        kernel,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda k: (0, 0)),       # lr
            pl.BlockSpec((mb, 1), lambda k: (0, k)),      # idx slot
            pl.BlockSpec((mb, 1), lambda k: (0, k)),      # val slot
            pl.BlockSpec((mb, 1), lambda k: (0, k)),      # mask slot
            pl.BlockSpec((mb, f), lambda k: (0, 0)),      # x0
            pl.BlockSpec((nb, f), lambda k: (0, 0)),      # theta0
        ],
        out_specs=(
            pl.BlockSpec((mb, f), lambda k: (0, 0)),
            pl.BlockSpec((nb, f), lambda k: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((mb, f), jnp.float32),
            jax.ShapeDtypeStruct((nb, f), jnp.float32),
        ),
        scratch_shapes=[
            compat.vmem((mb, f), jnp.float32),   # resident x — the tile carry
            compat.vmem((nb, f), jnp.float32),   # resident theta
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(lr, idx, val, mask, x, theta)


@functools.partial(
    jax.jit,
    static_argnames=("lam", "mode", "row_mult", "col_mult", "f_mult"))
def sgd_block_update(
    x: jax.Array,      # [mb, f]  user-block factor slice
    theta: jax.Array,  # [nb, f]  item-block factor slice
    idx: jax.Array,    # [mb, K]  block-local item indices
    val: jax.Array,    # [mb, K]
    cnt: jax.Array,    # [mb]
    lr: jax.Array,     # scalar learning rate (traced: no retrace per epoch)
    lam: float,
    *,
    mode: str = "ref",
    row_mult: int = 8,
    col_mult: int = 128,
    f_mult: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """One batch-Hogwild sweep over a tile; returns (x', theta').

    Padding is semantics-free by construction: padded ELL slots and
    padded user rows are masked out, padded theta rows are never
    selected (real ``idx < nb``), and padded feature columns start at 0
    and stay 0 under the multiplicative update.
    """
    mb, K = idx.shape
    nb, f = theta.shape
    lr = jnp.asarray(lr, jnp.float32)
    if mode == "ref":
        return kref.sgd_block_ref(x, theta, idx, val, cnt, lr, lam)
    mask = kref.mask_from_cnt(cnt, K, x.dtype)
    mbp = _round_up(mb, row_mult)
    nbp = _round_up(nb, col_mult)
    fp = _round_up(f, f_mult)
    x_p = _pad_axis(_pad_axis(x, 1, fp), 0, mbp)
    t_p = _pad_axis(_pad_axis(theta, 1, fp), 0, nbp)
    idx_p = _pad_axis(idx.astype(jnp.int32), 0, mbp)
    val_p = _pad_axis(val, 0, mbp)
    mask_p = _pad_axis(mask, 0, mbp)
    x_new, t_new = sgd_tile_pallas(
        x_p, t_p, idx_p, val_p, mask_p, lr.reshape(1, 1), lam=lam,
        interpret=(mode == "kernel_interpret"))
    return x_new[:mb, :f], t_new[:nb, :f]
