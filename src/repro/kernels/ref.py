"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *definitions of correctness*: each kernel test sweeps shapes
and dtypes and asserts allclose against these functions.  They are also the
fallback execution path on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_from_cnt(cnt: jax.Array, K: int, dtype=jnp.float32) -> jax.Array:
    """[m] counts -> [m, K] 0/1 validity mask."""
    k = jnp.arange(K, dtype=jnp.int32)
    return (k[None, :] < cnt[:, None]).astype(dtype)


def herm_ref(
    g: jax.Array,      # [m, K, F] gathered theta rows (garbage in padding slots)
    val: jax.Array,    # [m, K]    rating values (0 in padding)
    mask: jax.Array,   # [m, K]    1.0 where slot is a real nonzero
    diag: jax.Array,   # [m]       weighted-lambda diagonal (lambda * n_u, or 1 for empty rows)
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused get_hermitian + B_u kernel.

    A_u = sum_k mask[u,k] * g[u,k,:] g[u,k,:]^T + diag[u] * I
    B_u = sum_k val[u,k]  * g[u,k,:]
    """
    F = g.shape[-1]
    gm = g * mask[..., None]
    A = jnp.einsum("ukf,ukg->ufg", gm, g, preferred_element_type=jnp.float32)
    A = A + diag[:, None, None] * jnp.eye(F, dtype=A.dtype)[None, :, :]
    B = jnp.einsum("uk,ukf->uf", val * mask, g, preferred_element_type=jnp.float32)
    return A, B


def batch_solve_ref(A: jax.Array, B: jax.Array) -> jax.Array:
    """Oracle for batched SPD solve: x_u = A_u^{-1} B_u via Cholesky."""
    L = jax.lax.linalg.cholesky(A)
    y = jax.lax.linalg.triangular_solve(
        L, B[..., None], left_side=True, lower=True)
    x = jax.lax.linalg.triangular_solve(
        L, y, left_side=True, lower=True, transpose_a=True)
    return x[..., 0]


def fused_herm_gathered_ref(theta, idx, val, cnt, lam):
    """End-to-end oracle: gather + herm in one call (what ops.fused_herm computes)."""
    g = jnp.take(theta, idx, axis=0)
    mask = mask_from_cnt(cnt, idx.shape[1], theta.dtype)
    diag = jnp.where(cnt > 0, lam * cnt.astype(jnp.float32), 1.0)
    return herm_ref(g, val, mask, diag)


def sgd_block_ref(
    x: jax.Array,      # [mb, f]  user factors of this user block
    theta: jax.Array,  # [nb, f]  item factors of this item block
    idx: jax.Array,    # [mb, K]  block-local item index per slot (0 in padding)
    val: jax.Array,    # [mb, K]  rating (0 in padding)
    cnt: jax.Array,    # [mb]     true nnz per user row
    lr: jax.Array,     # scalar   learning rate
    lam: float,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the batch-Hogwild block update (CuMF_SGD, one tile).

    The K ELL slots are processed sequentially; within one slot all mb
    user rows update concurrently (the "batch" of batch-Hogwild).  Users
    are disjoint by construction; item collisions inside a slot are
    determinized as the *mean* of the colliding per-sample gradients
    (mini-batch semantics), all computed against the pre-slot factors:

        e      = r_uv - <x_u, theta_v>
        x_u   += lr * (e * theta_v - lam * x_u)
        th_v  += lr * (mean_{u in slot hits v} e * x_u - lam * theta_v)

    Averaging (not summing) the collisions is load-bearing: a power-law
    popular item can be hit by thousands of rows in one slot, and the
    summed update diverges at any useful lr.
    """
    K = idx.shape[1]
    nb = theta.shape[0]
    mask = mask_from_cnt(cnt, K, x.dtype)

    def slot(k, carry):
        x, th = carry
        iv = idx[:, k]                       # [mb]
        msk = mask[:, k]                     # [mb]
        tv = jnp.take(th, iv, axis=0)        # [mb, f]
        e = (val[:, k] - jnp.sum(x * tv, axis=-1)) * msk
        dx = msk[:, None] * (e[:, None] * tv - lam * x)
        num = jnp.zeros_like(th).at[iv].add(
            msk[:, None] * (e[:, None] * x))        # [nb, f] grad sums
        hits = jnp.zeros((nb,), x.dtype).at[iv].add(msk)
        dt = num / jnp.maximum(hits, 1.0)[:, None] \
            - lam * th * (hits > 0)[:, None]
        return x + lr * dx, th + lr * dt

    return jax.lax.fori_loop(0, K, slot, (x, theta))
