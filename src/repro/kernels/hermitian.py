"""Pallas TPU kernel: fused get_hermitian_x + B_u (the cuMF hot spot).

cuMF's MO-ALS (paper Alg. 2) holds the f x f accumulator A_u in the GPU
register file across all rated items of a row and spills it to global
memory exactly once.  The TPU analogue implemented here:

- the accumulators ``accA [TM, F, F]`` and ``accB [TM, F]`` live in a VMEM
  scratch buffer across the entire inner (k-tile) grid dimension and are
  written to HBM once per row tile — the register-file trick, re-homed to
  the memory TPUs actually expose;
- the per-thread outer products ``theta_v theta_v^T`` are re-associated into
  ``TM`` batched ``[F, TK] x [TK, F]`` MXU matmuls (``dot_general`` with a
  batch dim) — a systolic array wants matmuls, not scalar FMAs;
- the rated feature rows arrive pre-gathered (``g = theta[idx]``, an XLA
  DMA-gather playing the role of the texture cache) and are streamed
  HBM -> VMEM tile by tile via BlockSpec (the shared-memory ``bin`` of the
  paper is the TK tile);
- B_u is fused into the same pass (beyond-paper: cuMF used a separate
  cuSPARSE call, costing a second sweep over R and Theta).

Grid: (m/TM, K/TK), row tiles major / k tiles minor, so the accumulator
carry is over the minor dimension ("arbitrary" semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _fused_herm_kernel(diag_ref, g_ref, val_ref, mask_ref,
                       a_ref, b_ref, acc_a, acc_b, *, n_ktiles: int):
    """One (row-tile, k-tile) grid step."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_a[...] = jnp.zeros_like(acc_a)
        acc_b[...] = jnp.zeros_like(acc_b)

    g = g_ref[...]                       # [TM, TK, F]
    v = val_ref[...]                     # [TM, TK]
    msk = mask_ref[...]                  # [TM, TK]
    gm = g * msk[..., None]

    # A[u] += (g_m[u]^T @ g[u]) : TM batched [F,TK]x[TK,F] MXU matmuls.
    acc_a[...] += jax.lax.dot_general(
        gm, g,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    # B[u] += val[u] @ g[u] : TM batched [1,TK]x[TK,F] matmuls.
    acc_b[...] += jax.lax.dot_general(
        v * msk, g,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_ktiles - 1)
    def _epilogue():
        F = acc_a.shape[-1]
        eye = jnp.eye(F, dtype=jnp.float32)
        d = diag_ref[...]                # [TM, 1]
        a_ref[...] = acc_a[...] + d[:, :, None] * eye[None, :, :]
        b_ref[...] = acc_b[...]


def fused_herm_pallas(
    g: jax.Array,        # [m, K, F]  gathered theta rows
    val: jax.Array,      # [m, K]
    mask: jax.Array,     # [m, K]
    diag: jax.Array,     # [m]
    *,
    tm: int = 8,
    tk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """A_u = sum_k mask*g g^T + diag*I ; B_u = sum_k val*g.  See module doc."""
    m, K, F = g.shape
    assert m % tm == 0, (m, tm)
    assert K % tk == 0, (K, tk)
    n_ktiles = K // tk
    grid = (m // tm, n_ktiles)

    kernel = functools.partial(_fused_herm_kernel, n_ktiles=n_ktiles)
    out_shapes = (
        jax.ShapeDtypeStruct((m, F, F), jnp.float32),
        jax.ShapeDtypeStruct((m, F), jnp.float32),
    )
    a, b = compat.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, 1), lambda i, k: (i, 0)),          # diag [m,1]
            pl.BlockSpec((tm, tk, F), lambda i, k: (i, k, 0)),   # g
            pl.BlockSpec((tm, tk), lambda i, k: (i, k)),         # val
            pl.BlockSpec((tm, tk), lambda i, k: (i, k)),         # mask
        ],
        out_specs=(
            pl.BlockSpec((tm, F, F), lambda i, k: (i, 0, 0)),
            pl.BlockSpec((tm, F), lambda i, k: (i, 0)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[
            compat.vmem((tm, F, F), jnp.float32),  # accA — the «register file»
            compat.vmem((tm, F), jnp.float32),     # accB
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(diag[:, None], g, val, mask)
    return a, b


# ---------------------------------------------------------------------------
# Ablation variant: the «no registers» baseline of paper Fig. 7.
# The accumulator round-trips through HBM after every k tile (bin), exactly
# like Alg. 2 without the register optimization: f^2 global-memory traffic
# per bin instead of once per row.  Implemented as one pallas_call per k
# chunk with an XLA add in between, so the HBM traffic is real, not modeled.
# ---------------------------------------------------------------------------

def _herm_onebin_kernel(g_ref, val_ref, mask_ref, a_ref, b_ref):
    g = g_ref[...]
    msk = mask_ref[...]
    gm = g * msk[..., None]
    a_ref[...] = jax.lax.dot_general(
        gm, g, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    b_ref[...] = jax.lax.dot_general(
        val_ref[...] * msk, g, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def herm_hbm_accum(
    g: jax.Array, val: jax.Array, mask: jax.Array, diag: jax.Array,
    *, tm: int = 8, tk: int = 128, interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fig. 7 ablation: accumulate A_u in HBM per bin (2.5x slower in paper)."""
    m, K, F = g.shape
    assert K % tk == 0
    acc_a = jnp.zeros((m, F, F), jnp.float32)
    acc_b = jnp.zeros((m, F), jnp.float32)
    onebin = compat.pallas_call(
        _herm_onebin_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, tk, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((tm, tk), lambda i: (i, 0)),
            pl.BlockSpec((tm, tk), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tm, F, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((tm, F), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, F, F), jnp.float32),
            jax.ShapeDtypeStruct((m, F), jnp.float32),
        ),
        interpret=interpret,
    )
    for k0 in range(0, K, tk):
        da, db = onebin(g[:, k0:k0 + tk], val[:, k0:k0 + tk], mask[:, k0:k0 + tk])
        acc_a = acc_a + da          # HBM round trip per bin (the ablated cost)
        acc_b = acc_b + db
    eye = jnp.eye(F, dtype=jnp.float32)[None, :, :]
    return acc_a + diag[:, None, None] * eye, acc_b
