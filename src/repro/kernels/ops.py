"""Jitted public wrappers around the Pallas kernels (+ shape plumbing).

``fused_herm`` / ``batch_solve`` are the two ops the rest of the framework
calls.  They handle:

- the theta gather (XLA DMA-gather == the paper's texture-cached read),
- padding m / K / F up to tile multiples (F to the MXU lane width),
- kernel-vs-oracle dispatch (``use_kernel=False`` or non-TPU backends fall
  back to the jnp oracle; on CPU the kernel runs in interpret mode inside
  tests only — production entry points use the oracle on CPU so jit costs
  stay sane).  ``mode="kernel"`` off-TPU no longer crashes: the pallas
  calls go through ``repro.compat.pallas_call``, which degrades to the
  interpreter when no Mosaic compiler is present.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.batch_solve import batch_solve_pallas
from repro.kernels.hermitian import fused_herm_pallas

Mode = Literal["kernel", "kernel_interpret", "ref"]


def default_mode() -> Mode:
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("lam", "mode", "tm", "tk", "f_mult", "diag_fallback"))
def fused_herm(
    theta: jax.Array,   # [n, f] feature matrix (the fixed side)
    idx: jax.Array,     # [m, K] padded column indices
    val: jax.Array,     # [m, K] padded rating values
    cnt: jax.Array,     # [m]    true nnz per row
    lam: float,
    *,
    mode: Mode = "ref",
    tm: int = 8,
    tk: int = 128,
    f_mult: int = 128,
    diag_fallback: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Return (A [m, f, f], B [m, f]) of paper eq. (2) with weighted-lambda reg.

    A_u = sum_{v: r_uv != 0} theta_v theta_v^T + lambda n_u I
    B_u = Theta^T R_{u*}^T

    ``diag_fallback`` puts I on the diagonal of empty rows so the solve stays
    nonsingular (x_u = 0).  SU-ALS shards set it to False: their partial A
    matrices are psum-reduced first and the guard is applied post-reduction
    (a locally-empty row may be nonempty globally).
    """
    m, K = idx.shape
    f = theta.shape[1]
    mask = kref.mask_from_cnt(cnt, K, theta.dtype)
    diag = lam * cnt.astype(jnp.float32)
    if diag_fallback:
        diag = jnp.where(cnt > 0, diag, 1.0)
    g = jnp.take(theta, idx, axis=0)          # [m, K, f] texture-gather analogue

    if mode == "ref":
        A, B = kref.herm_ref(g, val, mask, diag)
        return A, B

    F = _round_up(f, f_mult)
    Kp = _round_up(K, tk)
    mp = _round_up(m, tm)
    g = _pad_axis(_pad_axis(_pad_axis(g, 2, F), 1, Kp), 0, mp)
    val_p = _pad_axis(_pad_axis(val, 1, Kp), 0, mp)
    mask_p = _pad_axis(_pad_axis(mask, 1, Kp), 0, mp)
    diag_p = _pad_axis(diag, 0, mp)
    A, B = fused_herm_pallas(
        g, val_p, mask_p, diag_p, tm=tm, tk=tk,
        interpret=(mode == "kernel_interpret"))
    return A[:m, :f, :f], B[:m, :f]


@functools.partial(jax.jit, static_argnames=("mode", "tb"))
def batch_solve(
    A: jax.Array,  # [m, f, f]
    B: jax.Array,  # [m, f]
    *,
    mode: Mode = "ref",
    tb: int = 8,
) -> jax.Array:
    """x_u = A_u^{-1} B_u (batched Cholesky solve)."""
    if mode == "ref":
        return kref.batch_solve_ref(A, B)
    m, f, _ = A.shape
    mp = _round_up(m, tb)
    eye_pad = jnp.eye(f, dtype=A.dtype)[None]
    A_p = _pad_axis(A, 0, mp)
    # padded batch entries get I so the factorization stays nonsingular
    if mp != m:
        padmask = (jnp.arange(mp) < m).astype(A.dtype)[:, None, None]
        A_p = A_p * padmask + (1.0 - padmask) * eye_pad
    B_p = _pad_axis(B, 0, mp)
    x = batch_solve_pallas(A_p, B_p, tb=tb,
                           interpret=(mode == "kernel_interpret"))
    return x[:m]


def als_update_factor(
    theta: jax.Array,
    idx: jax.Array,
    val: jax.Array,
    cnt: jax.Array,
    lam: float,
    *,
    mode: Mode = "ref",
    tm: int = 8,
    tk: int = 128,
    tb: int = 8,
    f_mult: int = 128,
) -> jax.Array:
    """One half-iteration: given fixed theta, solve all rows of X (paper Alg. 1/2)."""
    A, B = fused_herm(theta, idx, val, cnt, lam,
                      mode=mode, tm=tm, tk=tk, f_mult=f_mult)
    return batch_solve(A, B, mode=mode, tb=tb)
