"""Declared per-kernel VMEM budgets — the static memory contract.

The paper's memory model (eq. 5-8 and the §3.3 register/shared-memory
discussion) is what makes cuMF fast: every kernel's working set is sized
against a *declared* fast-memory capacity, not discovered by OOM.  On the
TPU port that capacity is VMEM (``launch.mesh.VMEM_BYTES`` = 16 MiB per
chip; duplicated here as a plain number so this module stays importable
without JAX — test_analysis cross-checks the two constants).

``repro.analysis``'s pallas-budget rule statically walks every
``pl.pallas_call`` site, resolves the BlockSpec / scratch / out-spec block
shapes against the ``dim_bounds`` declared here, and estimates the VMEM
footprint as::

    2 * (sum of in-spec blocks + sum of out-spec blocks) + scratch

The factor 2 models the Pallas pipeline's double buffering of streamed
blocks (the next grid step's tiles are DMA'd while the current one
computes); scratch is allocated once and carried across the grid.  Block
dtypes are taken from the ``compat.vmem(..., dtype)`` declaration for
scratch and assumed float32 (4 B) for streamed blocks — every kernel in
this repo streams f32.

Budgets are per *wrapper function* (the enclosing ``def`` of the
``pallas_call``).  ``dim_bounds`` are the worst-case tile sizes the
wrapper is allowed to be called with; the public wrappers enforce them by
construction (tm/tk/tb defaults, ``f_mult=128`` padding) except for the
SGD tile sizes mb/nb, which ``sgd.blocking`` keeps at or below the bound
for every grid the repo builds (g >= 2 over the bench shapes).

Degree-binned dispatch (``BinnedELL`` bins, per-tile-K SGD groups) needs
no budget entries of its own: each per-bin call is the same wrapper at a
*smaller* K (bins satisfy ``K_b <= K <= dim_bounds`` by construction, and
the ALS kernels' VMEM footprint is K-independent anyway — they stream
fixed [tm, tk] rating tiles and grid over K), so the uniform worst-case
bounds declared here dominate every binned call site.

Worst-case footprints under the declared bounds (the numbers the limits
are set against, with headroom for interpreter/layout slack):

- ``fused_herm_pallas``  (tm=8, tk=128, F=128): streamed in 520 KiB +
  out 516 KiB, doubled, + 516 KiB scratch ~= 2.53 MiB  -> limit 4 MiB.
- ``herm_hbm_accum``     (tm=8, tk=128, F=128): ~2.03 MiB (no scratch —
  that is the point of the Fig. 7 ablation)          -> limit 4 MiB.
- ``batch_solve_pallas`` (tb=8, F=128): ~1.02 MiB     -> limit 2 MiB.
- ``sgd_tile_pallas``    (mb=nb=1024, f=128): streamed in ~1.01 MiB +
  out 1 MiB, doubled, + 1 MiB scratch ~= 5.02 MiB     -> limit 8 MiB.

All well under the 16 MiB chip VMEM, with room for the compiler's own
temporaries.  A new kernel (or a tile-size bump) that blows its limit
fails the lint job before it ever reaches hardware.
"""
from __future__ import annotations

import dataclasses

#: mirror of launch.mesh.VMEM_BYTES (kept import-free; cross-checked in
#: tests/test_analysis.py so the two cannot drift)
VMEM_BYTES = 16 * (1 << 20)


@dataclasses.dataclass(frozen=True)
class KernelBudget:
    """Static VMEM contract of one pallas_call wrapper."""

    vmem_limit: int              # bytes the estimated footprint must fit in
    dim_bounds: dict             # symbolic dim name -> worst-case value
    note: str = ""               # where the bound comes from


def footprint_bytes(name: str, **dims) -> int:
    """Estimated VMEM footprint of one launch of kernel ``name`` at the
    given tile sizes — the same ``2 * (in + out) + scratch`` double-buffer
    model the pallas-budget lint rule applies to the declared worst case
    (see the module doc), evaluated at a *run's* launch dims so the
    plan-vs-actual ledger can record budget-vs-launched.

    Dims are the symbolic names of ``BUDGETS[name].dim_bounds`` (``F`` is
    the f_mult-padded latent dim; the SGD kernel's ``K`` sizes the grid,
    not a block, and is not needed).  Block sums mirror the wrappers'
    BlockSpecs exactly: idx/val/mask/cnt stream as separate f32-width
    blocks, factor/accumulator tiles as [**, F] / [**, F, F] blocks.
    """
    if name == "fused_herm_pallas":
        tm, tk, F = dims["tm"], dims["tk"], dims["F"]
        inb = tm + tm * tk * F + 2 * tm * tk        # diag, g, val, mask
        out = tm * F * F + tm * F                   # A, B
        scratch = out                               # accA, accB carry
        return 4 * (2 * (inb + out) + scratch)
    if name == "herm_hbm_accum":
        tm, tk, F = dims["tm"], dims["tk"], dims["F"]
        inb = tm * tk * F + 2 * tm * tk             # g, val, mask
        out = tm * F * F + tm * F                   # A, B (HBM round-trip)
        return 4 * 2 * (inb + out)
    if name == "batch_solve_pallas":
        tb, F = dims["tb"], dims["F"]
        inb = tb * F * F + tb * F                   # A batch, B batch
        out = tb * F                                # solved rows
        return 4 * 2 * (inb + out)
    if name == "sgd_tile_pallas":
        mb, nb, f = dims["mb"], dims["nb"], dims["f"]
        fac = (mb + nb) * f                         # x + theta blocks
        inb = 1 + 3 * mb + fac                      # lr, idx/val/mask, x0/t0
        out = fac
        scratch = fac                               # resident factor carry
        return 4 * (2 * (inb + out) + scratch)
    raise KeyError(f"no footprint model for kernel {name!r}; "
                   f"known: {sorted(BUDGETS)}")


BUDGETS: dict[str, KernelBudget] = {
    "fused_herm_pallas": KernelBudget(
        vmem_limit=4 * (1 << 20),
        dim_bounds={"tm": 8, "tk": 128, "F": 128},
        note="MO-ALS fused Hermitian (paper Alg. 2); F = f padded to the "
             "MXU lane width by ops.fused_herm(f_mult=128)",
    ),
    "herm_hbm_accum": KernelBudget(
        vmem_limit=4 * (1 << 20),
        dim_bounds={"tm": 8, "tk": 128, "F": 128},
        note="Fig. 7 no-registers ablation: per-bin kernel, accumulator "
             "round-trips HBM so no scratch term",
    ),
    "batch_solve_pallas": KernelBudget(
        vmem_limit=2 * (1 << 20),
        dim_bounds={"tb": 8, "F": 128},
        note="batched Cholesky solve; one [tb, F, F] system batch resident",
    ),
    "sgd_tile_pallas": KernelBudget(
        vmem_limit=8 * (1 << 20),
        dim_bounds={"mb": 1024, "nb": 1024, "f": 128, "K": 1 << 16},
        note="CuMF_SGD tile sweep: both factor blocks resident in scratch; "
             "mb/nb bound the block sizes sgd.blocking may produce (K only "
             "sizes the grid, not a block)",
    ),
}
