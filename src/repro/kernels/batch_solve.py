"""Pallas TPU kernel: batched SPD Cholesky solve (cuMF's batch_solve phase).

cuMF delegates ``A_u x_u = B_u`` to cuBLAS batched routines.  On TPU we give
the phase its own in-VMEM kernel: each grid step loads a batch of TB
(F x F) Hermitian systems, runs an unblocked right-looking Cholesky, then a
forward and a backward triangular solve, all without leaving VMEM (an F=128
fp32 tile is 64 KB — 0.4% of VMEM).

Dynamic scalar indexing on the lane dimension is not TPU-friendly, so every
row/column extraction is expressed as a one-hot contraction and every
triangular constraint as a ``jnp.where`` mask — the standard trick for
in-kernel factorizations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _cholesky_inplace(A: jax.Array) -> jax.Array:
    """Right-looking Cholesky of a batch [TB, F, F]; returns lower L."""
    TB, F, _ = A.shape
    idx = jax.lax.iota(jnp.int32, F)

    def body(j, carry):
        M, L = carry
        ej = (idx == j).astype(M.dtype)                       # one-hot [F]
        dj = jnp.einsum("bfg,f,g->b", M, ej, ej)              # M[:, j, j]
        dj = jnp.maximum(dj, 1e-20)
        colj = jnp.einsum("bfg,g->bf", M, ej)                 # M[:, :, j]
        c = jnp.where(idx[None, :] >= j, colj * jax.lax.rsqrt(dj)[:, None], 0.0)
        L = L + c[:, :, None] * ej[None, None, :]             # L[:, :, j] = c
        ct = jnp.where(idx[None, :] > j, c, 0.0)              # strict trailing part
        M = M - ct[:, :, None] * ct[:, None, :]
        return (M, L)

    _, L = jax.lax.fori_loop(0, F, body, (A, jnp.zeros_like(A)))
    return L


def _trsv_lower(L: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L y = b (forward substitution), batch [TB, F, F] / [TB, F]."""
    TB, F = b.shape
    idx = jax.lax.iota(jnp.int32, F)

    def body(j, y):
        ej = (idx == j).astype(b.dtype)
        lrow = jnp.einsum("bfg,f->bg", L, ej)                 # L[j, :]
        s = jnp.einsum("bg,bg->b", jnp.where(idx[None, :] < j, lrow, 0.0), y)
        bj = jnp.einsum("bf,f->b", b, ej)
        ljj = jnp.einsum("bg,g->b", lrow, ej)
        yj = (bj - s) / jnp.maximum(ljj, 1e-20)
        return y + yj[:, None] * ej[None, :]

    return jax.lax.fori_loop(0, F, body, jnp.zeros_like(b))


def _trsv_upper_t(L: jax.Array, y: jax.Array) -> jax.Array:
    """Solve L^T x = y (back substitution on the transposed factor)."""
    TB, F = y.shape
    idx = jax.lax.iota(jnp.int32, F)

    def body(t, x):
        j = F - 1 - t
        ej = (idx == j).astype(y.dtype)
        lcol = jnp.einsum("bfg,g->bf", L, ej)                 # L[:, j] == L^T[j, :]
        s = jnp.einsum("bf,bf->b", jnp.where(idx[None, :] > j, lcol, 0.0), x)
        yj = jnp.einsum("bf,f->b", y, ej)
        ljj = jnp.einsum("bf,f->b", lcol, ej)
        xj = (yj - s) / jnp.maximum(ljj, 1e-20)
        return x + xj[:, None] * ej[None, :]

    return jax.lax.fori_loop(0, F, body, jnp.zeros_like(y))


def _batch_solve_kernel(a_ref, b_ref, x_ref):
    A = a_ref[...].astype(jnp.float32)        # [TB, F, F]
    b = b_ref[...].astype(jnp.float32)        # [TB, F]
    L = _cholesky_inplace(A)
    y = _trsv_lower(L, b)
    x_ref[...] = _trsv_upper_t(L, y)


def batch_solve_pallas(
    A: jax.Array,      # [m, F, F] SPD
    B: jax.Array,      # [m, F]
    *,
    tb: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """x_u = A_u^{-1} B_u for every u, one VMEM-resident batch per grid step."""
    m, F, _ = A.shape
    assert m % tb == 0, (m, tb)
    return compat.pallas_call(
        _batch_solve_kernel,
        grid=(m // tb,),
        in_specs=[
            pl.BlockSpec((tb, F, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, F), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, F), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, F), jnp.float32),
        interpret=interpret,
    )(A, B)
