"""Host-resident stores for out-of-core ALS (paper §4.4 "keep R and R^T").

The rating matrix lives in host memory in *both* orientations, pre-cut into
the shapes the wave driver streams:

- ``RatingStore.r`` — R row-major (rows = users), sliced per wave with
  ``sparse.padded.row_slice`` for the solve-X half.
- ``RatingStore.rt_parts`` — R^T column-partitioned into the plan's q
  user-batches (``partition_padded``), one ``[n, K_loc]`` shard per batch
  with batch-local user coordinates, for the accumulate-Theta half.

With ``n_bins > 1`` both orientations additionally carry degree-binned
shards (``r_binned``, one ``BinnedELL`` per R^T user-batch in
``rt_binned``): the wave driver streams each wave's rows cut bin-wise
(``x_slice_binned`` / ``theta_batch_binned``) so heavy rows pay a large K
and light rows a small one — cuMF's degree binning applied to the
streaming layout.  With ``p > 1`` (mesh streaming) the theta half instead
carries batch-uniform stacked bins (``rt_stacked``, one
``sparse.padded.BinShardStack`` per bin): bin caps are chosen globally
across all q batches so every batch's bin presents one ``[rows_b, K_b]``
shape the mesh herm stack can shard, while per-batch membership stays
free (the ``items`` scatter map carries it).  ``n_bins="auto"`` consults
the layout autotuner (``repro.core.autotune``) and records the chosen
config in ``RatingStore.tune`` for the ledger.

Factors live in ``FactorStore`` as plain numpy arrays; the driver reads
slices onto device and writes solved slices back, so device memory only ever
holds the resident factor plus the streaming wave buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.sparse.padded import (BinnedELL, BinShardStack, PaddedELL,
                                 bin_padded, csr_from_coo, pad_csr_fast,
                                 pad_rows, partition_padded, row_slice,
                                 stack_binned_parts)

Triplet = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _triplet(ell: PaddedELL) -> Triplet:
    # copy=False: the arrays are already int32/float32 out of pad_csr_fast /
    # partition_padded, and row_slice made the one deliberate copy — a
    # second astype copy per streamed wave would double host traffic
    return (ell.idx.astype(np.int32, copy=False),
            ell.val.astype(np.float32, copy=False),
            ell.cnt.astype(np.int32, copy=False))


def triplet_nbytes(t: Triplet) -> int:
    return sum(int(a.nbytes) for a in t)


def binned_nbytes(binned: BinnedELL) -> int:
    """Streamed bytes of a BinnedELL's per-bin triplets (idx + val + cnt)."""
    return sum(int(b.idx.nbytes + b.val.nbytes + b.cnt.nbytes)
               for b in binned.bins)


@dataclasses.dataclass
class FactorStore:
    """Host-resident X [m_pad, f] and Theta [n, f] with slice IO."""

    x: np.ndarray
    theta: np.ndarray

    @classmethod
    def from_arrays(cls, x, theta) -> "FactorStore":
        # np.array (not asarray): jnp inputs arrive as read-only views and
        # the driver writes solved slices back in place
        return cls(x=np.array(x, np.float32, order="C"),
                   theta=np.array(theta, np.float32, order="C"))

    def factor(self, side: str) -> np.ndarray:
        assert side in ("x", "theta"), side
        return self.x if side == "x" else self.theta

    def read_slice(self, side: str, start: int, stop: int) -> np.ndarray:
        return np.ascontiguousarray(self.factor(side)[start:stop])

    def write_slice(self, side: str, start: int, stop: int, rows) -> None:
        arr = self.factor(side)
        assert stop - start == len(rows), (start, stop, len(rows))
        arr[start:stop] = np.asarray(rows, arr.dtype)

    # -- model-shard IO (mesh streaming): shard k of p owns the contiguous
    # row range [k*rows/p, (k+1)*rows/p) of a factor.  The ownership rule of
    # the p-sharded theta: only the owning model shard ever writes its range
    # (see repro.outofcore's module doc), so shard reads/writes never race.
    def shard_bounds(self, side: str, k: int, p: int) -> tuple[int, int]:
        rows = self.factor(side).shape[0]
        assert rows % p == 0, f"{side} rows={rows} not divisible by p={p}"
        assert 0 <= k < p, (k, p)
        npp = rows // p
        return k * npp, (k + 1) * npp

    def read_shard(self, side: str, k: int, p: int) -> np.ndarray:
        return self.read_slice(side, *self.shard_bounds(side, k, p))

    def write_shard(self, side: str, k: int, p: int, rows) -> None:
        self.write_slice(side, *self.shard_bounds(side, k, p), rows)

    @property
    def nbytes(self) -> int:
        return int(self.x.nbytes + self.theta.nbytes)


class RatingStore:
    """R in both orientations, pre-cut for a q-batch wave schedule.

    ``q`` is the plan's number of X-row batches.  Rows are padded with empty
    rows to ``m_pad`` (the next multiple of q) so every batch — and therefore
    every wave buffer — has identical shape; padded rows carry cnt = 0 and
    solve to x_u = 0 without touching Theta.

    ``n_bins > 1`` additionally materializes degree-binned shards of both
    orientations (``bin_padded`` re-bins the uniform layouts in place, no
    COO round trip): the driver then streams each wave bin-wise through
    ``x_slice_binned`` / ``theta_batch_binned``, cutting padded slots from
    ``fill`` x nnz down to the per-bin sum.  With ``p > 1`` the theta half
    is binned batch-uniform instead (``rt_stacked``, globally-chosen caps)
    so the bins stream on a real (data, model) mesh; the solve-X half stays
    on the uniform mesh layout (``x_slice_mesh_triplet``).

    ``n_bins="auto"`` resolves the bin count (and bin ``k_multiple``)
    through ``repro.core.autotune.tune_als_layout`` — argmin of predicted
    streamed bytes over the config ladder, cached in ``tune_cache`` (a
    ``repro.core.autotune.TuneCache`` or path) — and records the decision
    in ``self.tune`` for the driver's ledger run context.
    """

    def __init__(self, r: PaddedELL, q: int, k_multiple: int = 8, p: int = 1,
                 n_bins=1, tune_cache=None):
        self.tune = None
        if n_bins == "auto":
            from repro.core import autotune as _autotune
            res = _autotune.tune_als_layout(
                r, q=q, p=p, k_multiple=k_multiple, cache=tune_cache)
            n_bins = res.config.n_bins
            k_multiple = res.config.k_multiple
            self.tune = res.to_obj()
        assert q >= 1 and p >= 1 and n_bins >= 1
        self.m = r.m                       # true (unpadded) user count
        self.n = r.n_cols                  # item count
        self.q = q
        self.p = p
        self.n_bins = n_bins
        self.m_pad = -(-r.m // q) * q
        self.r = pad_rows(r, self.m_pad)   # rows = users, global item idx
        # R^T with n_cols = m_pad, column-partitioned into the q user-batches:
        # shard j holds the nonzeros of users [j*m_pad/q, (j+1)*m_pad/q) with
        # user coordinates re-based to the batch (eq. 5-7 partitioning, the
        # q axis instead of p).
        items, users, vals = self.r.transpose_coo()
        ptr, cc, vv = csr_from_coo(items, users, vals, self.n)
        rt = pad_csr_fast(ptr, cc, vv, n_cols=self.m_pad,
                          k_multiple=k_multiple)
        self.rt_parts = partition_padded(rt, q, k_multiple=k_multiple)
        # p > 1 (mesh streaming): R also column-partitioned into the p theta
        # shards (shard-local item coordinates) so solve-X waves can be cut
        # straight into the shard_map layout — the real eq. 5-7 p axis.
        assert self.n % p == 0, f"n={self.n} not divisible by p={p}"
        self.r_model_parts = (partition_padded(self.r, p,
                                               k_multiple=k_multiple)
                              if p > 1 else None)
        # n_bins > 1: degree-binned shards.  p = 1: both orientations, each
        # R^T shard re-binned independently (its item degrees are
        # batch-local).  p > 1 (mesh): the theta half gets batch-uniform
        # stacked bins instead — caps chosen globally over all q batches,
        # per-batch membership carried by the stack's ``items`` map — while
        # the solve-X half keeps the uniform mesh layout (r_model_parts).
        self.r_binned = None
        self.rt_binned = None
        self.rt_stacked = None
        if n_bins > 1:
            if p == 1:
                self.r_binned = bin_padded(self.r, n_bins,
                                           k_multiple=k_multiple)
                self.rt_binned = tuple(
                    bin_padded(self._rt_shard(j), n_bins,
                               k_multiple=k_multiple)
                    for j in range(q))
            else:
                self.rt_stacked = stack_binned_parts(
                    self.rt_parts, n_bins, k_multiple=k_multiple, p=p)

    def _rt_shard(self, j: int) -> PaddedELL:
        """R^T shard of user-batch ``j`` as a standalone PaddedELL view."""
        return PaddedELL(idx=self.rt_parts.idx[j], val=self.rt_parts.val[j],
                         cnt=self.rt_parts.cnt[j],
                         n_cols=self.m_pad // self.q)

    @property
    def nnz(self) -> int:
        return self.r.nnz

    @property
    def fill_r(self) -> float:
        """Padding overhead of the row-major orientation (solve-X waves):
        per-bin padded slots over nnz when binned, uniform-K fill otherwise.
        """
        if self.r_binned is not None:
            return self.r_binned.fill
        return self.r.fill

    @property
    def fill_rt(self) -> float:
        """Padding overhead of the q-partitioned R^T shards.  Much worse than
        ``fill_r`` on power-law data: every item row pads to the max in-batch
        item degree — feed this to ``plan_for(fill=...)`` so the eq. (8)
        budget prices what the driver actually streams."""
        if self.rt_binned is not None:
            slots = sum(b.padded_slots for b in self.rt_binned)
            return float(slots) / max(self.nnz, 1)
        if self.rt_stacked is not None:
            slots = sum(st.padded_slots for st in self.rt_stacked)
            return float(slots) / max(self.nnz, 1)
        q, n, K_loc = self.rt_parts.idx.shape
        return float(q * n * K_loc) / max(self.nnz, 1)

    @property
    def fill_r_model(self) -> float:
        """Padding overhead of the p column-partitioned R (mesh solve-X
        waves): every user row pads to its max in-shard degree."""
        if self.r_model_parts is None:
            return self.fill_r
        p, m, K_loc = self.r_model_parts.idx.shape
        return float(p * m * K_loc) / max(self.nnz, 1)

    @property
    def worst_fill(self) -> float:
        return max(self.fill_r, self.fill_rt, self.fill_r_model)

    def fill_breakdown(self) -> dict:
        """Per-component padding fills, keyed like the ledger records them.

        ``worst_fill`` is the max over these — the bound fed to
        ``plan_for(fill=...)`` — but each streamed component pays only its
        own fill, so the ledger records every component separately instead
        of letting one bad orientation smear the others (the old
        ``fill``/``worst_fill`` asymmetry).
        """
        out = {"r": self.fill_r, "rt": self.fill_rt}
        if self.r_model_parts is not None:
            out["r_model"] = self.fill_r_model
        return out

    def bin_fill_pairs(self) -> list:
        """Per-bin ``(padded_slots, nnz)`` of the worst-fill orientation —
        the ``plan_for(bin_fills=...)`` pricing input.  Requires a binned
        store.  p = 1: their aggregate equals ``worst_fill``, so the planner
        prices exactly the binned bytes the driver streams.  p > 1
        (stacked): the pairs price the batch-uniform theta-half stacks —
        the binned component of the mesh run (the uniform solve-X side is
        priced by ``fill_r_model``)."""
        if self.rt_stacked is not None:
            return [(int(st.padded_slots), int(st.nnz))
                    for st in self.rt_stacked]
        assert self.r_binned is not None, \
            "RatingStore was built with n_bins=1; pass n_bins to price bins"
        if self.fill_r >= self.fill_rt:
            src = self.r_binned.bins
        else:
            src = [bb for b in self.rt_binned for bb in b.bins]
        return [(int(b.padded_slots), int(b.nnz)) for b in src]

    @property
    def host_nbytes(self) -> int:
        total = int(self.r.idx.nbytes + self.r.val.nbytes + self.r.cnt.nbytes
                    + self.rt_parts.idx.nbytes + self.rt_parts.val.nbytes
                    + self.rt_parts.cnt.nbytes)
        if self.r_model_parts is not None:
            total += int(self.r_model_parts.idx.nbytes
                         + self.r_model_parts.val.nbytes
                         + self.r_model_parts.cnt.nbytes)
        if self.r_binned is not None:
            total += binned_nbytes(self.r_binned)
            total += sum(binned_nbytes(b) for b in self.rt_binned)
        if self.rt_stacked is not None:
            total += sum(st.nbytes + st.items.nbytes
                         for st in self.rt_stacked)
        return total

    def x_slice_triplet(self, row_start: int, row_stop: int) -> Triplet:
        """R rows for one solve-X wave slice (global item indices)."""
        return _triplet(row_slice(self.r, row_start, row_stop))

    def x_slice_binned(self, row_start: int, row_stop: int) -> BinnedELL:
        """R rows for one solve-X wave slice, cut bin-wise: a BinnedELL
        whose per-bin rows are slice-local (congruent bin structure across
        waves — every wave carries all bins, possibly empty).  Requires the
        store to have been built with ``n_bins > 1``."""
        assert self.r_binned is not None, \
            "RatingStore was built with n_bins=1; pass n_bins to bin waves"
        return self.r_binned.row_slice(row_start, row_stop)

    def x_slice_mesh_triplet(self, row_start: int, row_stop: int) -> Triplet:
        """R rows for one solve-X wave slice in the ``shard_ratings`` mesh
        layout: idx/val ``[rows, p*K_loc]`` (shard-local item coordinates,
        the p column blocks laid out contiguously) and cnt ``[rows, p]``.
        Requires the store to have been built with ``p > 1``."""
        assert self.r_model_parts is not None, \
            "RatingStore was built with p=1; pass p to stream on a mesh"
        parts = self.r_model_parts
        p, _, K_loc = parts.idx.shape
        rows = row_stop - row_start
        idx = np.ascontiguousarray(
            np.transpose(parts.idx[:, row_start:row_stop], (1, 0, 2))
        ).reshape(rows, p * K_loc)
        val = np.ascontiguousarray(
            np.transpose(parts.val[:, row_start:row_stop], (1, 0, 2))
        ).reshape(rows, p * K_loc)
        cnt = np.ascontiguousarray(
            np.transpose(parts.cnt[:, row_start:row_stop], (1, 0)))
        return (idx.astype(np.int32, copy=False),
                val.astype(np.float32, copy=False),
                cnt.astype(np.int32, copy=False))

    def theta_batch_triplet(self, j: int) -> Triplet:
        """R^T shard of user-batch ``j`` (batch-local user indices).

        Returns host views into the precomputed shard stack (no per-wave
        copy — the driver only reads them to stage device transfers)."""
        assert 0 <= j < self.q, (j, self.q)
        return (self.rt_parts.idx[j].astype(np.int32, copy=False),
                self.rt_parts.val[j].astype(np.float32, copy=False),
                self.rt_parts.cnt[j].astype(np.int32, copy=False))

    def theta_batch_binned(self, j: int) -> BinnedELL:
        """Degree-binned R^T shard of user-batch ``j`` (batch-local user
        indices, item rows grouped by in-batch degree).  Host views — the
        binned shards are precomputed at store build."""
        assert self.rt_binned is not None, \
            "RatingStore was built with n_bins=1; pass n_bins to bin shards"
        assert 0 <= j < self.q, (j, self.q)
        return self.rt_binned[j]

    def theta_wave_stacked(self, batch_indices) -> list:
        """Per-bin stacked theta-half payloads of one mesh wave: for each
        bin, (idx ``[nbatch, rows_b, K_b]``, val, cnt ``[nbatch, rows_b]``,
        items ``[nbatch, rows_b]``) cut to the wave's batches — host views
        of the precomputed batch-uniform stacks (``items`` stays on host;
        it is the scatter map for the per-bin partials, not a transfer).
        Requires the store to have been built with ``p > 1`` and
        ``n_bins > 1``."""
        assert self.rt_stacked is not None, \
            "RatingStore was built without stacked bins; pass p > 1 and " \
            "n_bins > 1 to stream binned waves on a mesh"
        js = np.asarray(list(batch_indices), dtype=np.int64)
        assert js.size and js.min() >= 0 and js.max() < self.q, (js, self.q)
        return [(st.idx[js], st.val[js], st.cnt[js], st.items[js])
                for st in self.rt_stacked]


class TileStore:
    """Host-resident g x g ``BlockGrid`` tiles for the streaming SGD driver.

    The grid's stacked arrays already live in host memory in exactly the
    shapes the tile waves stream — one ``[mb, K]`` triplet per (user-block,
    item-block) tile — so the store is a thin per-tile view layer over the
    grid, the SGD counterpart of ``RatingStore``'s wave slicing.  Factor
    blocks live in a ``FactorStore`` whose X is ``[g*mb, f]`` and Theta is
    ``[g*nb, f]``; block ``i`` is the contiguous slice ``[i*mb, (i+1)*mb)``.
    """

    def __init__(self, grid):
        self.grid = grid

    @property
    def g(self) -> int:
        return self.grid.g

    @property
    def mb(self) -> int:
        return self.grid.mb

    @property
    def nb(self) -> int:
        return self.grid.nb

    @property
    def K(self) -> int:
        return self.grid.K

    @property
    def m(self) -> int:
        return self.grid.m

    @property
    def n(self) -> int:
        return self.grid.n

    @property
    def nnz(self) -> int:
        return self.grid.nnz

    @property
    def host_nbytes(self) -> int:
        return int(self.grid.idx.nbytes + self.grid.val.nbytes
                   + self.grid.cnt.nbytes)

    def tile_k(self, i: int, j: int) -> int:
        return self.grid.tile_k(i, j)

    def tile_triplet(self, i: int, j: int) -> Triplet:
        """Tile (i, j)'s (idx, val, cnt) as host views (no copy — the
        driver only reads them to stage device transfers).  On a per-tile-K
        grid the slot axis is sliced to the tile's own K: the trailing
        columns are all-padding, so the cut is exact and the wave streams
        only the slots its kernel shape dispatches."""
        assert 0 <= i < self.g and 0 <= j < self.g, (i, j, self.g)
        k = self.grid.tile_k(i, j)
        return (self.grid.idx[i, j, :, :k].astype(np.int32, copy=False),
                self.grid.val[i, j, :, :k].astype(np.float32, copy=False),
                self.grid.cnt[i, j].astype(np.int32, copy=False))
