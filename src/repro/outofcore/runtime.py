"""Solver-agnostic streaming runtime: the plumbing every wave driver shares.

The out-of-core subsystem runs more than one solver (ALS half-iterations,
SGD diagonal-set epochs); what they have in common is not the math but the
execution substrate: a metered simulated-device footprint, telemetry of what
actually streamed, per-wave checkpoint commits, and the simulated-kill hook
the resume tests drive.  That substrate lives here so a new solver's driver
only writes its wave loop.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional


class MemoryMeter:
    """Named live-allocation tracker (thread-safe: the prefetch worker
    registers wave buffers while the consumer frees earlier ones)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._live: dict[str, int] = {}
        self.live_bytes = 0
        self.peak_bytes = 0

    def alloc(self, name: str, nbytes: int) -> None:
        with self._lock:
            assert name not in self._live, name
            self._live[name] = int(nbytes)
            self.live_bytes += int(nbytes)
            self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def free(self, name: str) -> None:
        with self._lock:
            self.live_bytes -= self._live.pop(name)


@dataclasses.dataclass
class StreamTelemetry:
    """What the run actually did — peak footprint, traffic, resume point."""

    capacity_bytes: int = 0
    peak_bytes: int = 0
    waves_run: int = 0
    batches_loaded: int = 0
    bytes_streamed: int = 0      # host->device rating + factor-slice traffic
    resumed_from_step: int = 0
    wall_seconds: float = 0.0
    # mesh streaming only: per-link traffic of the topology-aware reduction
    # that combines the per-data-shard Hermitian partials (distributed.reduce)
    reduce_fast_bytes: int = 0   # intra-fast-domain ring traffic
    reduce_slow_bytes: int = 0   # inter-domain tree traffic
    topology: str = ""           # DeviceTopology.describe() of the reduce


class SimulatedFailure(RuntimeError):
    """Raised by ``fail_after_waves`` — stands in for a killed machine."""


class WaveCheckpointer:
    """Per-wave commit + simulated-kill counter, shared by the drivers.

    ``save`` takes the checkpoint tree as a thunk so the host-side snapshot
    copies are only made when a manager is actually attached; the kill fires
    *after* the wave's commit is durable (``mgr.wait()``), which is what lets
    the resume tests demand bit-exact continuation.
    """

    def __init__(self, mgr, fail_after_waves: Optional[int] = None):
        self.mgr = mgr
        self.fail_after_waves = fail_after_waves
        self.saves = 0

    def save(self, step: int, tree_fn: Callable[[], dict]) -> None:
        if self.mgr is not None:
            self.mgr.save(step, tree_fn())
        self.saves += 1
        if (self.fail_after_waves is not None
                and self.saves >= self.fail_after_waves):
            if self.mgr is not None:
                self.mgr.wait()             # make sure the wave committed
            raise SimulatedFailure(
                f"simulated kill after {self.saves} wave(s)")
