"""Solver-agnostic streaming runtime: the plumbing every wave driver shares.

The out-of-core subsystem runs more than one solver (ALS half-iterations,
SGD diagonal-set epochs); what they have in common is not the math but the
execution substrate: a metered simulated-device footprint, telemetry of what
actually streamed, per-wave checkpoint commits, and the simulated-kill hook
the resume tests drive.  That substrate lives here so a new solver's driver
only writes its wave loop.

Since the observability layer landed (``repro.obs``), the drivers do all
their counting and timing through an ``obs.MetricsRegistry`` —
:class:`StreamTelemetry` is no longer mutated field by field but *computed*
from the registry at the end of a run (:meth:`StreamTelemetry.from_registry`),
with the same public fields callers always read.  The registry counter /
gauge names that view reads are the contract::

    counters: waves_run, batches_loaded, bytes_streamed,
              padded_slots, nnz_streamed,
              reduce_fast_bytes, reduce_slow_bytes,
              phase_seconds/<category>   (fed by obs.trace.phase)
    gauges:   peak_bytes, resumed_from_step

``wall_seconds`` is the total of the ``driver`` phase category — the span
that wraps one whole streaming run.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Mapping, Optional

from repro.obs.ledger import merge_ledgers
from repro.obs.trace import phase


class MemoryMeter:
    """Named live-allocation tracker (thread-safe: the prefetch worker
    registers wave buffers while the consumer frees earlier ones)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._live: dict[str, int] = {}
        self.live_bytes = 0
        self.peak_bytes = 0

    def alloc(self, name: str, nbytes: int) -> None:
        with self._lock:
            assert name not in self._live, name
            self._live[name] = int(nbytes)
            self.live_bytes += int(nbytes)
            self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def free(self, name: str) -> None:
        with self._lock:
            self.live_bytes -= self._live.pop(name)


@dataclasses.dataclass
class StreamTelemetry:
    """What the run actually did — peak footprint, traffic, resume point.

    A read-only *view* built from the run's ``obs.MetricsRegistry`` (see
    the module doc for the name contract); the classic fields are unchanged
    so existing callers (benches, examples, tests) keep working, and two
    breakdown fields ride along:

    - ``phase_seconds``: total seconds per phase category (``prefetch``,
      ``solve``, ``reduce``, ``checkpoint``, ...) — where the wall-clock
      went.  For a merged hybrid telemetry the keys are prefixed with the
      phase name (``als/solve``, ``sgd/solve``).
    - ``phases``: for merged telemetries only, the per-phase
      ``StreamTelemetry`` objects keyed by phase name (``als``/``sgd``).

    The pad/fill accounting (ISSUE 8): ``padded_slots`` counts every ELL
    slot streamed in a rating payload (padding included), ``nnz_streamed``
    the true ratings under those slots, and ``fill_waste_ratio`` their
    quotient — the measured twin of ``RatingStore.worst_fill``'s planning
    bound.  ``ledger`` is the run's serialized plan-vs-actual ledger
    (``repro.obs.ledger``), empty when the driver predates it.
    """

    capacity_bytes: int = 0
    peak_bytes: int = 0
    waves_run: int = 0
    batches_loaded: int = 0
    bytes_streamed: int = 0      # host->device rating + factor-slice traffic
    padded_slots: int = 0        # ELL slots streamed (padding included)
    nnz_streamed: int = 0        # true ratings under those slots
    fill_waste_ratio: float = 0.0  # padded_slots / nnz_streamed
    resumed_from_step: int = 0
    wall_seconds: float = 0.0
    # mesh streaming only: per-link traffic of the topology-aware reduction
    # that combines the per-data-shard Hermitian partials (distributed.reduce)
    reduce_fast_bytes: int = 0   # intra-fast-domain ring traffic
    reduce_slow_bytes: int = 0   # inter-domain tree traffic
    topology: str = ""           # DeviceTopology.describe() of the reduce
    # observability additions (ISSUE 7)
    phase_seconds: dict = dataclasses.field(default_factory=dict)
    phases: dict = dataclasses.field(default_factory=dict)
    # plan-vs-actual ledger (ISSUE 8): serialized repro.obs.ledger object
    ledger: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_registry(cls, registry, *, capacity_bytes: int = 0,
                      topology: str = "",
                      ledger: Optional[dict] = None) -> "StreamTelemetry":
        """The post-run view over a driver's metrics registry."""
        def cnt(name):
            return registry.counter(name).value

        phases = registry.phase_seconds()
        slots = int(cnt("padded_slots"))
        nnz = int(cnt("nnz_streamed"))
        return cls(
            capacity_bytes=int(capacity_bytes),
            peak_bytes=int(registry.gauge("peak_bytes").value),
            waves_run=int(cnt("waves_run")),
            batches_loaded=int(cnt("batches_loaded")),
            bytes_streamed=int(cnt("bytes_streamed")),
            padded_slots=slots,
            nnz_streamed=nnz,
            fill_waste_ratio=slots / nnz if nnz else 0.0,
            resumed_from_step=int(registry.gauge("resumed_from_step").value),
            wall_seconds=phases.get("driver", 0.0),
            reduce_fast_bytes=int(cnt("reduce_fast_bytes")),
            reduce_slow_bytes=int(cnt("reduce_slow_bytes")),
            topology=topology,
            phase_seconds=phases,
            ledger=dict(ledger) if ledger else {},
        )


def merge_telemetry(
        parts: Mapping[str, Optional[StreamTelemetry]]) -> StreamTelemetry:
    """One telemetry over a multi-phase run (the hybrid drivers).

    ``parts`` maps phase name -> that phase's telemetry (None for a phase
    that did not run, e.g. the ALS warm start skipped on resume).  Traffic
    and time sum; capacity/peak take the max (each phase ran under its own
    budget, and per-phase ``peak <= capacity`` implies the same for the
    maxima); ``phase_seconds`` keys are prefixed with the phase name and
    the full per-phase telemetries stay reachable under ``.phases``.
    """
    live = {k: t for k, t in parts.items() if t is not None}
    assert live, "merge_telemetry needs at least one non-None phase"
    tels = list(live.values())
    slots = sum(t.padded_slots for t in tels)
    nnz = sum(t.nnz_streamed for t in tels)
    ledgers = {name: t.ledger for name, t in live.items() if t.ledger}
    return StreamTelemetry(
        capacity_bytes=max(t.capacity_bytes for t in tels),
        peak_bytes=max(t.peak_bytes for t in tels),
        waves_run=sum(t.waves_run for t in tels),
        batches_loaded=sum(t.batches_loaded for t in tels),
        bytes_streamed=sum(t.bytes_streamed for t in tels),
        padded_slots=slots,
        nnz_streamed=nnz,
        fill_waste_ratio=slots / nnz if nnz else 0.0,
        resumed_from_step=max(t.resumed_from_step for t in tels),
        wall_seconds=sum(t.wall_seconds for t in tels),
        reduce_fast_bytes=sum(t.reduce_fast_bytes for t in tels),
        reduce_slow_bytes=sum(t.reduce_slow_bytes for t in tels),
        topology=next((t.topology for t in tels if t.topology), ""),
        phase_seconds={f"{name}/{cat}": secs
                       for name, t in live.items()
                       for cat, secs in t.phase_seconds.items()},
        phases=dict(live),
        ledger=merge_ledgers(ledgers) if ledgers else {},
    )


class SimulatedFailure(RuntimeError):
    """Raised by ``fail_after_waves`` — stands in for a killed machine."""


class WaveCheckpointer:
    """Per-wave commit + simulated-kill counter, shared by the drivers.

    ``save`` takes the checkpoint tree as a thunk so the host-side snapshot
    copies are only made when a manager is actually attached; the kill fires
    *after* the wave's commit is durable (``mgr.wait()``), which is what lets
    the resume tests demand bit-exact continuation.  Each commit runs in a
    ``checkpoint`` phase span covering the snapshot + async enqueue — the
    host-blocking part of the §4.4 protocol (the background write itself is
    deliberately off the clock; it overlaps the next wave).
    """

    def __init__(self, mgr, fail_after_waves: Optional[int] = None,
                 tracer=None, registry=None):
        self.mgr = mgr
        self.fail_after_waves = fail_after_waves
        self.saves = 0
        self._tracer = tracer
        self._registry = registry

    def save(self, step: int, tree_fn: Callable[[], dict]) -> None:
        if self.mgr is not None:
            with phase("checkpoint.commit", cat="checkpoint",
                       tracer=self._tracer, registry=self._registry,
                       step=step):
                self.mgr.save(step, tree_fn())
            if self._registry is not None:
                self._registry.counter("checkpoints_committed").inc()
        self.saves += 1
        if (self.fail_after_waves is not None
                and self.saves >= self.fail_after_waves):
            if self.mgr is not None:
                self.mgr.wait()             # make sure the wave committed
            raise SimulatedFailure(
                f"simulated kill after {self.saves} wave(s)")
