"""Out-of-core wave scheduling: factorize R larger than aggregate device HBM.

Implements cuMF's §4.3/§4.4 out-of-core batching as a first-class subsystem.
Paper vocabulary -> implementation map:

- **p** (Theta column shards, data parallelism): the planner's
  ``PartitionPlan.p``.  With ``p = 1`` the streaming driver executes one
  model-shard's view on a single simulated device; with ``mesh=`` (a real
  ``(data, model)`` mesh) and a ``RatingStore(p=...)``, every wave runs
  shard-mapped — solve-X through ``distributed.su_als.make_wave_update_fn``,
  accumulate-Theta through ``make_wave_herm_fn`` with the per-data-shard
  partials combined by ``distributed.reduce.topology_reduce``.

  **p-sharded theta ownership rules** (mesh streaming):

  1. ``FactorStore.theta`` stays one host array, but model shard ``k``
     *owns* the contiguous row range ``[k*n/p, (k+1)*n/p)`` —
     ``read_shard``/``write_shard`` are the only sanctioned shard IO.
  2. A device only ever materializes its own ``[n/p, f]`` theta shard
     (plus the wave's R / R^T slice for its coordinates); nothing outside
     the final all-gather of solved X rows replicates theta.
  3. Only the owning shard writes its theta rows, and only after the
     topology reduce of the half's full partial sums — so shard writes
     never race and never see partially-reduced systems.
- **q** (X row batches, model parallelism): ``PartitionPlan.q``, made
  explicit as ``core.partition.QBatch`` row ranges.  ``store.RatingStore``
  keeps R row-major for the solve-X half and R^T column-partitioned into the
  same q user-batches for the accumulate-Theta half — the paper's "keep R
  and R^T in host memory".
- **waves** (q batches beyond the device axis, §4.4 elasticity):
  ``schedule.IterationSchedule.waves`` — each wave streams up to ``n_data``
  consecutive q-batches through the (simulated) devices; both iteration
  halves walk the same wave list.
- **preload** (§4.4 "hide load time behind compute"): the driver double-
  buffers the next wave's shards host->device through
  ``data.prefetch.Prefetcher`` while the current wave computes;
  ``core.partition.plan_for(buffers=depth + 2)`` prices the extra resident
  shard buffers in the eq. (8) budget (depth queued + one held by the
  loader thread + one being consumed).
- **checkpoint/restart** (§4.4 fault tolerance): every completed wave
  commits factors (+ Hermitian accumulators mid-half) through
  ``checkpoint.CheckpointManager``; a killed run resumes mid-iteration.
- **degree-binned layout** (§4.1 binning): ``RatingStore(n_bins > 1)``
  additionally keeps R and each R^T shard as
  ``sparse.padded.BinnedELL`` — rows grouped into ~log-spaced degree bins,
  each padded at its own tight K.  Layout ownership rules:

  1. The binned shards are *views of the same nonzeros* as the uniform
     arrays (which stay resident for eval/compat); masked padding slots
     are exact zeros, so binned and unbinned runs agree to float roundoff.
  2. Factors and checkpoints always live in ORIGINAL row order — the bin
     permutation (``perm``/``inv_perm``) never escapes the store; the
     binned ALS kernels scatter per-bin results back through
     ``BinnedELL.rows`` (checkpoints are layout-agnostic: a binned run
     resumes a uniform checkpoint and vice versa).
  3. The wave scheduler relies on stable grouping: each bin's original-row
     list ascends, so any wave range ``[start, stop)`` cuts every bin in
     one contiguous span (``bin_spans``) and per-wave byte/slot
     predictions stay exact.
  4. Planner pricing goes through ``RatingStore.bin_fill_pairs()`` ->
     ``plan_for(bin_fills=...)``; the ledger's ``fill_waste_ratio`` and
     per-component ``fill_bound/*`` records measure the binned layout.
  5. Binned + mesh (``p > 1``): the theta half streams batch-uniform
     stacked bins (``RatingStore.rt_stacked``, bin caps chosen globally
     over all q batches so every batch's bin presents one shape the mesh
     herm stack can shard; per-batch membership varies and rides in each
     stack's ``items`` scatter map).  The solve-X half keeps the uniform
     mesh layout.  Stack padding rows carry cnt = 0 and contribute
     exact-zero partials, so the f64 accumulators — and therefore
     checkpoints and the topology reduce — are bit-identical to a
     uniform run's.
  6. ``n_bins="auto"`` (and the SGD side's ``per_tile_k="auto"``) route
     through ``repro.core.autotune``: argmin of predicted streamed bytes
     over the config ladder, cached per (shape, skew, topology, backend);
     the chosen config and cache hit/miss are recorded in the ledger run
     context (``autotune``).

  The SGD side gets the same treatment at tile granularity:
  ``sgd.blocking.block_coo(per_tile_k=True, degree_sort=True)`` records a
  ladder-quantized ``tile_K`` per tile (plus an optional descending-degree
  user placement), and the streaming SGD driver dispatches each wave's
  tiles in same-K groups sliced to their own K.

The subsystem is **solver-generic**: schedules are built from abstract wave
work items (``schedule.WaveItem``) and the drivers share one streaming
runtime (``runtime`` — meter, telemetry, per-wave checkpointer).  Beyond
the ALS halves above, ``run_streaming_sgd`` streams a CuMF_SGD
``BlockGrid``'s diagonal-set tiles (``schedule.TileWave``) through the same
budget — with ``mesh=`` each wave's tiles go one-per-device over the joint
(data, model) axes — so the SGD and hybrid solvers factorize matrices
larger than device memory too.
"""
from repro.outofcore.driver import run_streaming_als
from repro.outofcore.runtime import (MemoryMeter, SimulatedFailure,
                                     StreamTelemetry, WaveCheckpointer)
from repro.outofcore.schedule import (IterationSchedule, SgdEpochSchedule,
                                      TileWave, Wave, WaveItem,
                                      build_schedule, build_sgd_schedule,
                                      required_capacity_bytes,
                                      sgd_required_capacity_bytes)
from repro.outofcore.sgd_driver import run_streaming_sgd
from repro.outofcore.store import (FactorStore, RatingStore, TileStore,
                                   binned_nbytes)

__all__ = [
    "FactorStore", "IterationSchedule", "MemoryMeter", "RatingStore",
    "SgdEpochSchedule", "SimulatedFailure", "StreamTelemetry", "TileStore",
    "TileWave", "Wave", "WaveCheckpointer", "WaveItem", "binned_nbytes",
    "build_schedule", "build_sgd_schedule", "required_capacity_bytes",
    "run_streaming_als", "run_streaming_sgd", "sgd_required_capacity_bytes",
]
