"""Out-of-core wave scheduling: factorize R larger than aggregate device HBM.

Implements cuMF's §4.3/§4.4 out-of-core batching as a first-class subsystem.
Paper vocabulary -> implementation map:

- **p** (Theta column shards, data parallelism): the planner's
  ``PartitionPlan.p``.  With ``p = 1`` the streaming driver executes one
  model-shard's view on a single simulated device; with ``mesh=`` (a real
  ``(data, model)`` mesh) and a ``RatingStore(p=...)``, every wave runs
  shard-mapped — solve-X through ``distributed.su_als.make_wave_update_fn``,
  accumulate-Theta through ``make_wave_herm_fn`` with the per-data-shard
  partials combined by ``distributed.reduce.topology_reduce``.

  **p-sharded theta ownership rules** (mesh streaming):

  1. ``FactorStore.theta`` stays one host array, but model shard ``k``
     *owns* the contiguous row range ``[k*n/p, (k+1)*n/p)`` —
     ``read_shard``/``write_shard`` are the only sanctioned shard IO.
  2. A device only ever materializes its own ``[n/p, f]`` theta shard
     (plus the wave's R / R^T slice for its coordinates); nothing outside
     the final all-gather of solved X rows replicates theta.
  3. Only the owning shard writes its theta rows, and only after the
     topology reduce of the half's full partial sums — so shard writes
     never race and never see partially-reduced systems.
- **q** (X row batches, model parallelism): ``PartitionPlan.q``, made
  explicit as ``core.partition.QBatch`` row ranges.  ``store.RatingStore``
  keeps R row-major for the solve-X half and R^T column-partitioned into the
  same q user-batches for the accumulate-Theta half — the paper's "keep R
  and R^T in host memory".
- **waves** (q batches beyond the device axis, §4.4 elasticity):
  ``schedule.IterationSchedule.waves`` — each wave streams up to ``n_data``
  consecutive q-batches through the (simulated) devices; both iteration
  halves walk the same wave list.
- **preload** (§4.4 "hide load time behind compute"): the driver double-
  buffers the next wave's shards host->device through
  ``data.prefetch.Prefetcher`` while the current wave computes;
  ``core.partition.plan_for(buffers=depth + 2)`` prices the extra resident
  shard buffers in the eq. (8) budget (depth queued + one held by the
  loader thread + one being consumed).
- **checkpoint/restart** (§4.4 fault tolerance): every completed wave
  commits factors (+ Hermitian accumulators mid-half) through
  ``checkpoint.CheckpointManager``; a killed run resumes mid-iteration.

The subsystem is **solver-generic**: schedules are built from abstract wave
work items (``schedule.WaveItem``) and the drivers share one streaming
runtime (``runtime`` — meter, telemetry, per-wave checkpointer).  Beyond
the ALS halves above, ``run_streaming_sgd`` streams a CuMF_SGD
``BlockGrid``'s diagonal-set tiles (``schedule.TileWave``) through the same
budget — with ``mesh=`` each wave's tiles go one-per-device over the joint
(data, model) axes — so the SGD and hybrid solvers factorize matrices
larger than device memory too.
"""
from repro.outofcore.driver import run_streaming_als
from repro.outofcore.runtime import (MemoryMeter, SimulatedFailure,
                                     StreamTelemetry, WaveCheckpointer)
from repro.outofcore.schedule import (IterationSchedule, SgdEpochSchedule,
                                      TileWave, Wave, WaveItem,
                                      build_schedule, build_sgd_schedule,
                                      required_capacity_bytes,
                                      sgd_required_capacity_bytes)
from repro.outofcore.sgd_driver import run_streaming_sgd
from repro.outofcore.store import FactorStore, RatingStore, TileStore

__all__ = [
    "FactorStore", "IterationSchedule", "MemoryMeter", "RatingStore",
    "SgdEpochSchedule", "SimulatedFailure", "StreamTelemetry", "TileStore",
    "TileWave", "Wave", "WaveCheckpointer", "WaveItem", "build_schedule",
    "build_sgd_schedule", "required_capacity_bytes",
    "run_streaming_als", "run_streaming_sgd", "sgd_required_capacity_bytes",
]
