"""Streaming SGD driver: execute a tile-wave schedule end to end.

CuMF_SGD's block grid carries the same out-of-core property as the ALS
waves (cuMF §3.3): a (user-block, item-block) tile only ever touches its
two factor blocks, so an epoch streams tiles through a fixed device budget
instead of holding the grid resident.  Per epoch the driver:

- permutes the diagonal-set order with ``sgd.train.epoch_set_order`` (the
  same PRNG the in-core epoch uses, keyed on ``(cfg.seed, epoch)`` — the
  streaming trajectory matches the in-core one and resume is bit-exact);
- walks the epoch's ``TileWave`` list, double-buffering each wave's tile
  triplets host->device through ``data.prefetch.Prefetcher``.  Factor
  blocks are deliberately NOT prefetched: consecutive waves of different
  sets share blocks, so a block read ahead of the previous wave's
  writeback would be stale — they are fetched synchronously at consume
  time (they are O(f) per row; the O(K) rating payload is what preload
  hides);
- stacks the wave's tiles into ONE ``sgd_block_update`` dispatch (tiles of
  a set are disjoint in both factors — the same stacking as the in-core
  scan epoch) and writes the updated blocks straight back to the host
  ``FactorStore``; on a per-tile-K grid (degree binning at tile
  granularity) the wave instead splits into same-K ladder groups, one
  stacked dispatch per group — still exact, since a wave's tiles are
  mutually disjoint in both factors;
- commits resumable state (factors + global wave step) through
  ``checkpoint.CheckpointManager`` after every wave, so a killed run
  restarts mid-epoch.

``MemoryMeter`` models one simulated worker of the wave (payloads divide by
the wave's tile count), mirroring the ALS driver's per-device accounting.

With ``mesh`` set the wave's stacked tiles are placed sharded over the
joint ``("data", "model")`` device axes — one tile (and therefore one user
block + one item block of the factors) per real device, CuMF_SGD's workers
made concrete.  Ragged waves pad the stack with empty tiles (cnt = 0, a
no-op update) up to the device count; the padded outputs are discarded
before writeback.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.objective import rmse_padded
from repro.data.prefetch import Prefetcher
from repro.kernels.budgets import BUDGETS, footprint_bytes
from repro.obs.ledger import Ledger
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import current_tracer, phase
from repro.outofcore.runtime import (MemoryMeter, StreamTelemetry,
                                     WaveCheckpointer)
from repro.outofcore.schedule import (SgdEpochSchedule,
                                      predicted_sgd_stream_stats,
                                      sgd_required_capacity_bytes)
from repro.outofcore.store import FactorStore, TileStore, triplet_nbytes
from repro.sgd.train import (SgdConfig, epoch_lr, epoch_set_order, sgd_init,
                             sgd_tiles_update)


def run_streaming_sgd(
    tiles: TileStore,
    sched: SgdEpochSchedule,
    cfg: SgdConfig,
    *,
    factors: Optional[FactorStore] = None,
    ckpt_dir: Optional[str] = None,
    keep: int = 3,
    prefetch_depth: int = 2,
    train_eval=None,                 # (idx, val, cnt) for per-epoch RMSE
    test_eval=None,
    fail_after_waves: Optional[int] = None,
    mesh=None,
    callback=None,
    tracer=None,
    registry=None,
) -> tuple[FactorStore, List[dict], StreamTelemetry]:
    """Run ``cfg.epochs`` streaming SGD epochs of ``sched`` over ``tiles``.

    Returns (factor store, per-epoch history, telemetry) — the same
    protocol as ``run_streaming_als``.  With ``ckpt_dir`` set the run
    resumes from the latest committed wave; ``factors`` seeds a warm start
    (the hybrid path) and defaults to ``sgd_init`` at the grid's shape.
    Observability mirrors the ALS driver: the run wraps in a ``driver``
    phase, each epoch in an ``epoch`` phase, each consumed wave in one
    ``solve`` span, commits in ``checkpoint`` spans, and every count goes
    through ``registry`` (created when not passed); ``tracer`` defaults to
    the process-wide one and is a no-op unless enabled.
    With ``mesh`` set (a ``(data, model)`` mesh) each wave's tile stack is
    sharded one-tile-per-device over the joint axes before the single
    ``sgd_tiles_update`` dispatch runs, so the factor blocks live
    distributed across the real devices.
    """
    assert (tiles.g, tiles.mb, tiles.nb, tiles.K) == \
        (sched.g, sched.mb, sched.nb, sched.K), \
        "TileStore and SgdEpochSchedule were built for different grids"
    g, mb, nb, f = sched.g, sched.mb, sched.nb, cfg.f
    assert f == sched.f, (f, sched.f)
    wpe = sched.waves_per_epoch
    fac_bytes = (mb + nb) * f * 4          # one worker's two factor blocks

    tile_sh = None
    n_dev = 0
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        joint = tuple(a for a in ("data", "model", "pod")
                      if a in mesh.axis_names)
        assert "data" in joint and len(joint) >= 2, mesh.axis_names
        n_dev = 1
        for a in joint:
            n_dev *= mesh.shape[a]
        assert sched.n_workers <= n_dev, \
            f"schedule wants {sched.n_workers} workers, mesh has {n_dev}"
        tile_sh = NamedSharding(mesh, P(joint))   # stack dim: 1 tile/device

    def _pad_tiles(stack: np.ndarray) -> np.ndarray:
        """Pad the leading tile axis up to the device count with empty
        tiles (zeros everywhere -> cnt = 0 -> the update is a no-op)."""
        pad = n_dev - stack.shape[0]
        if pad <= 0:
            return stack
        return np.pad(stack, ((0, pad),) + ((0, 0),) * (stack.ndim - 1))

    def _place(stack: np.ndarray):
        return (jax.device_put(_pad_tiles(stack), tile_sh)
                if mesh is not None else jnp.asarray(stack))

    meter = MemoryMeter()
    tracer = tracer if tracer is not None else current_tracer()
    reg = registry if registry is not None else MetricsRegistry()

    mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
    start_step = 0
    if mgr is not None:
        tree, start_step = mgr.restore_or_init(
            {"x": np.zeros((g * mb, f), np.float32),
             "theta": np.zeros((g * nb, f), np.float32)}, lambda: None)
        if start_step:
            factors = FactorStore.from_arrays(tree["x"], tree["theta"])
    reg.gauge("resumed_from_step").set(start_step)
    if factors is None:
        st = sgd_init(tiles.grid, cfg)
        factors = FactorStore.from_arrays(st.x, st.theta)
    assert factors.x.shape == (g * mb, f), (factors.x.shape, g, mb, f)
    assert factors.theta.shape == (g * nb, f), (factors.theta.shape, g, nb, f)

    ckpt = WaveCheckpointer(mgr, fail_after_waves,
                            tracer=tracer, registry=reg)

    def _save(step: int):
        # snapshot copies: the manager commits async while later waves keep
        # mutating the live factor arrays
        ckpt.save(step, lambda: {"x": factors.x.copy(),
                                 "theta": factors.theta.copy()})

    # Plan side of the ledger: per-tile [g, g] bytes/slots/nnz matrices
    # (constant entries on a uniform grid, per-tile K when binned), summed
    # over exactly the waves each epoch will execute.
    pst = predicted_sgd_stream_stats(tiles, sched)
    pred = {"bytes": 0, "slots": 0, "nnz": 0}

    def _epoch(ep: int, first_wave: int):
        lr_t = jnp.float32(epoch_lr(cfg, ep))
        order = np.asarray(epoch_set_order(cfg.seed, ep, g))
        waves = sched.epoch_waves(order)
        for wave in waves[first_wave:]:
            pred["bytes"] += sum(int(pst["tile_bytes"][i][j])
                                 for i, j in wave.tiles)
            pred["slots"] += sum(int(pst["tile_slots"][i][j])
                                 for i, j in wave.tiles)
            pred["nnz"] += sum(int(pst["tile_nnz"][i][j])
                               for i, j in wave.tiles)

        def gen():
            for wave in waves[first_wave:]:
                trips = [tiles.tile_triplet(i, j) for i, j in wave.tiles]
                yield wave, trips

        def put(item):
            wave, trips = item
            payload = sum(triplet_nbytes(t) for t in trips)
            # one (simulated or real) worker holds ONE tile of the wave
            meter.alloc(f"tilewave{wave.index}", payload // len(trips))
            reg.counter("padded_slots").inc(sum(t[0].size for t in trips))
            reg.counter("nnz_streamed").inc(
                sum(int(t[2].sum()) for t in trips))
            # same-K tiles stack into one dispatch; a per-tile-K grid's
            # wave splits into a few ladder groups (one group — the whole
            # wave, today's single dispatch — when the grid is uniform).
            # Groups of one wave touch disjoint blocks, so running them
            # back to back is exact.
            groups = []
            for k_t in sorted({t[0].shape[-1] for t in trips}):
                sel = [c for c, t in enumerate(trips)
                       if t[0].shape[-1] == k_t]
                groups.append((
                    sel,
                    _place(np.stack([trips[c][0] for c in sel])),
                    _place(np.stack([trips[c][1] for c in sel])),
                    _place(np.stack([trips[c][2] for c in sel]))))
            return wave, groups, payload

        with Prefetcher(gen(), depth=prefetch_depth, put=put,
                        tracer=tracer, registry=reg) as pf:
            for wave, groups, payload in pf:
                t = len(wave.tiles)
                with phase("sgd.wave", cat="solve", tracer=tracer,
                           registry=reg, wave=wave.index, epoch=ep + 1,
                           tiles=t, bytes=payload):
                    # factor blocks: synchronous fetch AFTER the previous
                    # wave's writeback (see module doc — prefetching these
                    # across a set boundary would read stale blocks)
                    meter.alloc(f"fac_in{wave.index}", fac_bytes)
                    x_host = np.stack([
                        factors.read_slice("x", i * mb, (i + 1) * mb)
                        for i, _ in wave.tiles])
                    th_host = np.stack([
                        factors.read_slice("theta", j * nb, (j + 1) * nb)
                        for _, j in wave.tiles])
                    meter.alloc(f"fac_out{wave.index}", fac_bytes)
                    # each same-K group's disjoint tiles stack into one
                    # dispatch — the same sgd_tiles_update the in-core
                    # epoch uses, which is what keeps streaming == in-core
                    # parity exact; a uniform grid has exactly one group
                    # (the whole wave, today's single dispatch); on a mesh
                    # the stack is sharded one tile per device, so the
                    # padded no-op tiles ride along and are discarded below
                    for sel, idx_d, val_d, cnt_d in groups:
                        x_new, t_new = sgd_tiles_update(
                            _place(x_host[sel]), _place(th_host[sel]),
                            idx_d, val_d, cnt_d, lr_t, cfg.lam,
                            mode=cfg.mode, row_mult=cfg.row_mult,
                            col_mult=cfg.col_mult, f_mult=cfg.f_mult)
                        x_np, t_np = np.asarray(x_new), np.asarray(t_new)
                        for k, c in enumerate(sel):
                            i, j = wave.tiles[c]
                            factors.write_slice("x", i * mb, (i + 1) * mb,
                                                x_np[k])
                            factors.write_slice("theta", j * nb,
                                                (j + 1) * nb, t_np[k])
                    meter.free(f"fac_out{wave.index}")
                    meter.free(f"fac_in{wave.index}")
                    meter.free(f"tilewave{wave.index}")
                reg.counter("waves_run").inc()
                reg.counter("batches_loaded").inc(t)
                reg.counter("bytes_streamed").inc(
                    payload + x_host.nbytes + th_host.nbytes)
                _save(ep * wpe + wave.index + 1)

    history: List[dict] = []
    m, n = tiles.m, tiles.n
    ep0 = start_step // wpe
    with phase("sgd.stream", cat="driver", tracer=tracer, registry=reg,
               epochs=cfg.epochs, waves_per_epoch=wpe):
        for ep in range(ep0, cfg.epochs):
            ph0 = reg.phase_seconds()
            with phase("sgd.epoch", cat="epoch", tracer=tracer,
                       registry=reg, epoch=ep + 1):
                _epoch(ep, first_wave=start_step % wpe if ep == ep0 else 0)
            ph1 = reg.phase_seconds()
            rec = {"epoch": ep + 1, "lr": epoch_lr(cfg, ep),
                   "waves_run": int(reg.counter("waves_run").value),
                   "peak_bytes": meter.peak_bytes,
                   "phase_seconds": {
                       cat: s - ph0.get(cat, 0.0)
                       for cat, s in ph1.items()
                       if s - ph0.get(cat, 0.0) > 0.0}}
            if train_eval is not None or test_eval is not None:
                # degree-sorted grids store X rows permuted; evaluation is
                # in original user coordinates
                if tiles.grid.user_perm is not None:
                    x_dev = jnp.asarray(factors.x[tiles.grid.user_inv])
                else:
                    x_dev = jnp.asarray(factors.x[:m])
                t_dev = jnp.asarray(factors.theta[:n])
                if test_eval is not None:
                    rec["test_rmse"] = float(
                        rmse_padded(x_dev, t_dev, *test_eval))
                if train_eval is not None:
                    rec["train_rmse"] = float(
                        rmse_padded(x_dev, t_dev, *train_eval))
            history.append(rec)
            if callback is not None:
                callback(factors, rec)
        if mgr is not None:
            mgr.wait()
    reg.gauge("peak_bytes").set(meter.peak_bytes)

    # Close the loop: the schedule's predictions vs the meters.
    meas_slots = int(reg.counter("padded_slots").value)
    meas_nnz = int(reg.counter("nnz_streamed").value)
    meas_ratio = meas_slots / meas_nnz if meas_nnz else 0.0
    led = Ledger(solver="sgd", mesh=mesh is not None, g=g, mb=mb, nb=nb,
                 f=f, n_workers=sched.n_workers,
                 epochs=cfg.epochs - ep0, mode=cfg.mode,
                 per_tile_k=tiles.grid.tile_K is not None,
                 degree_sorted=tiles.grid.user_perm is not None,
                 autotune=getattr(tiles.grid, "tune", None),
                 resumed_from_step=start_step,
                 phase_seconds=reg.phase_seconds())
    led.record("peak_device_bytes", sched.capacity_bytes, meter.peak_bytes,
               unit="bytes", check="le")
    led.record("modeled_peak_bytes",
               sgd_required_capacity_bytes(mb, nb, sched.K, f,
                                           prefetch_depth=prefetch_depth),
               meter.peak_bytes, unit="bytes", check="le")
    led.record("bytes_streamed", pred["bytes"],
               int(reg.counter("bytes_streamed").value), unit="bytes")
    led.record("padded_slots", pred["slots"], meas_slots, unit="slots")
    led.record("nnz_streamed", pred["nnz"], meas_nnz, unit="ratings")
    led.record("fill_waste_ratio",
               pred["slots"] / pred["nnz"] if pred["nnz"] else 0.0,
               meas_ratio, unit="ratio", check="rel", rel_tol=1e-9)
    led.record("worst_fill_bound", tiles.grid.fill, meas_ratio,
               unit="ratio", check="le")
    F = -(-f // cfg.f_mult) * cfg.f_mult
    led.record("vmem/sgd_tile_pallas",
               BUDGETS["sgd_tile_pallas"].vmem_limit,
               footprint_bytes("sgd_tile_pallas", mb=mb, nb=nb, f=F),
               unit="bytes", check="le", mode=cfg.mode)

    return factors, history, StreamTelemetry.from_registry(
        reg, capacity_bytes=sched.capacity_bytes, ledger=led.to_obj())
