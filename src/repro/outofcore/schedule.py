"""Wave schedules: streaming plans made executable (paper §4.3/§4.4).

A schedule is a sequence of abstract **wave work items** — each names the
host-resident shards one synchronous streaming step moves through the
(simulated) devices — plus the per-device capacity the driver meters
against.  Two concrete item kinds exist today:

- ``Wave`` (ALS): up to ``n_data`` contiguous q-batches — R row slices on
  the solve-X half, R^T column shards + fresh X slices on the
  accumulate-Theta half.
- ``TileWave`` (SGD): up to ``n_workers`` tiles of one conflict-free
  diagonal block-set of a ``BlockGrid`` — each simulated worker holds one
  (user-block, item-block) tile plus its two factor blocks, the CuMF_SGD
  batch-Hogwild unit.

``build_schedule`` turns the planner's (p, q, waves) into explicit per-
iteration ALS work: which q-batches (X row ranges) each wave streams, which
R shards it touches, and which factor slices must be device-resident.  One
iteration runs two halves over the *same* wave list:

- **solve-X half** — Theta is fully resident (the plan's ``Theta_shard``
  term); wave ``w`` streams the R rows of its batches, solves those X rows
  directly, and writes the slice back to host.
- **accumulate-Theta half** — the A/B Hermitian accumulators for all n items
  are resident; wave ``w`` streams, per batch ``j``, the R^T column shard of
  user-batch ``j`` plus the freshly solved X slice of batch ``j`` (the
  "factor slices resident" of §4.4), and adds the batch's partial Hermitians.
  After the last wave the accumulated systems are solved in row blocks.

This is SU-ALS's partial-sum scheme (eq. 5-7) serialized over waves: with
``n_data`` simulated devices, each wave models one synchronous step in which
every device holds one q-batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.partition import GiB, PartitionPlan, QBatch, export_schedule


@dataclasses.dataclass(frozen=True)
class WaveItem:
    """Abstract wave work item: one synchronous streaming step.

    ``index`` is the item's checkpoint position within its schedule unit
    (iteration half for ALS, epoch for SGD) — the drivers commit resumable
    state after every item, so ``index`` is also the resume coordinate.
    """

    index: int


@dataclasses.dataclass(frozen=True)
class Wave(WaveItem):
    """ALS wave: up to n_data contiguous q-batches."""

    batches: Tuple[QBatch, ...]

    @property
    def row_start(self) -> int:
        return self.batches[0].row_start

    @property
    def row_stop(self) -> int:
        return self.batches[-1].row_stop

    @property
    def rows(self) -> int:
        return self.row_stop - self.row_start


@dataclasses.dataclass(frozen=True)
class IterationSchedule:
    plan: PartitionPlan
    m_pad: int                  # padded X rows (multiple of q)
    n: int                      # Theta rows
    n_data: int                 # simulated devices on the data axis
    waves: Tuple[Wave, ...]     # shared by both halves of an iteration
    capacity_bytes: int         # per-device budget the driver meters against
    p: int = 1                  # theta model shards (mesh "model" axis size)

    @property
    def waves_per_iteration(self) -> int:
        """Checkpoint steps per iteration: each half walks every wave once."""
        return 2 * len(self.waves)

    def describe(self) -> str:
        w = self.waves[0]
        return (f"waves={len(self.waves)} x {len(w.batches)} batches "
                f"({w.rows} rows/wave, m_pad={self.m_pad}, n={self.n}, "
                f"p={self.p}, capacity={self.capacity_bytes / GiB:.3f}GiB)")


def build_schedule(
    plan: PartitionPlan,
    m: int,
    n: int,
    *,
    n_data: Optional[int] = None,
    capacity_bytes: Optional[int] = None,
) -> IterationSchedule:
    """Explicit per-iteration schedule for ``plan`` on an (m x n) problem.

    ``m`` may be the true row count; it is padded up to a multiple of q here
    so every wave has identical shape (build the RatingStore with the same q
    and the stores line up).  ``capacity_bytes`` defaults to the plan's own
    per-device estimate — the budget the driver's memory meter reports
    against.
    """
    if n_data is None:
        n_data = -(-plan.q // plan.waves)
    m_pad = -(-m // plan.q) * plan.q
    groups = export_schedule(plan, m_pad, n_data)
    waves = tuple(Wave(index=w, batches=g) for w, g in enumerate(groups))
    assert len(waves) * n_data >= plan.q
    assert waves[0].row_start == 0 and waves[-1].row_stop == m_pad
    assert plan.p == 1 or n % plan.p == 0, (n, plan.p)
    return IterationSchedule(
        plan=plan, m_pad=m_pad, n=n, n_data=n_data, waves=waves,
        capacity_bytes=(plan.bytes_per_device if capacity_bytes is None
                        else capacity_bytes),
        p=plan.p)


# ---------------------------------------------------------------------------
# SGD: diagonal block-sets streamed as tile waves.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileWave(WaveItem):
    """SGD wave: up to n_workers tiles of ONE diagonal block-set.

    Tiles within a set touch disjoint user and item blocks, so the wave's
    tiles update concurrently (batch-Hogwild) and consecutive waves of the
    same set commute; a wave must never mix sets — tiles of different sets
    share factor blocks.
    """

    set_index: int
    tiles: Tuple[Tuple[int, int], ...]   # (user-block i, item-block j)


@dataclasses.dataclass(frozen=True)
class SgdEpochSchedule:
    """One SGD epoch as tile waves, grouped by canonical set index.

    ``set_waves[s]`` holds the waves of diagonal set ``s`` in canonical
    order; an epoch executes the sets in a per-epoch permuted order (the
    CuMF_SGD schedule randomization), so ``epoch_waves(set_order)``
    flattens and renumbers the waves for one concrete epoch.
    """

    g: int
    mb: int                     # user rows per block
    nb: int                     # item rows per block
    K: int                      # uniform ELL slots per tile
    f: int                      # latent dimension
    n_workers: int              # simulated devices == tiles per wave
    set_waves: Tuple[Tuple[TileWave, ...], ...]
    capacity_bytes: int         # per-worker budget the driver meters against

    @property
    def waves_per_epoch(self) -> int:
        """Checkpoint steps per epoch (every set, every wave)."""
        return sum(len(ws) for ws in self.set_waves)

    def epoch_waves(self, set_order) -> Tuple[TileWave, ...]:
        """The epoch's flat wave list: sets in ``set_order``, waves
        renumbered 0..waves_per_epoch-1 (the per-epoch resume coordinate)."""
        assert sorted(int(s) for s in set_order) == list(range(self.g)), \
            set_order
        out = []
        for s in set_order:
            for w in self.set_waves[int(s)]:
                out.append(dataclasses.replace(w, index=len(out)))
        return tuple(out)

    def describe(self) -> str:
        return (f"sgd waves={self.waves_per_epoch}/epoch "
                f"({self.g} sets x {len(self.set_waves[0])} waves, "
                f"{self.n_workers} tiles/wave, mb={self.mb}, nb={self.nb}, "
                f"K={self.K}, capacity={self.capacity_bytes / GiB:.3f}GiB)")


def sgd_tile_bytes(mb: int, K: int) -> int:
    """Streamed bytes of one tile's (idx, val, cnt) triplet."""
    return mb * K * 8 + mb * 4


def sgd_required_capacity_bytes(mb: int, nb: int, K: int, f: int,
                                prefetch_depth: int = 2) -> int:
    """Per-worker bytes the streaming SGD driver keeps resident.

    Mirrors ``run_streaming_sgd``'s MemoryMeter model: up to ``depth + 2``
    tile triplets live in the prefetch pipeline (queued + loader-held +
    consumed), while the factor blocks are fetched synchronously at consume
    time (they must see the previous wave's writeback — see the driver) and
    are staged twice (input + updated output) around the tile sweep.
    """
    bufs = prefetch_depth + 2
    factor_bytes = (mb + nb) * f * 4
    return bufs * sgd_tile_bytes(mb, K) + 2 * factor_bytes


def build_sgd_schedule(
    grid,
    f: int,
    *,
    n_workers: Optional[int] = None,
    capacity_bytes: Optional[int] = None,
    prefetch_depth: int = 2,
) -> SgdEpochSchedule:
    """Tile-wave schedule for one SGD epoch over a ``BlockGrid``.

    ``n_workers`` is the simulated device count: each wave streams that many
    tiles of one diagonal set (default: the whole set at once, the in-core
    shape).  ``n_workers < g`` forces multiple waves per set — the
    out-of-core regime where the epoch's tiles stream through a fixed
    budget.  ``capacity_bytes`` defaults to the driver's own resident-bytes
    model (``sgd_required_capacity_bytes``).
    """
    g, mb, nb, K = grid.g, grid.mb, grid.nb, grid.K
    if n_workers is None:
        n_workers = g
    n_workers = max(1, min(int(n_workers), g))
    set_waves = []
    for s in range(g):
        tiles = tuple((i, (i + s) % g) for i in range(g))
        # index is the within-set position only; epoch_waves renumbers to
        # the epoch-flat resume coordinate before any driver sees it
        set_waves.append(tuple(
            TileWave(index=c // n_workers, set_index=s,
                     tiles=tiles[c:c + n_workers])
            for c in range(0, g, n_workers)))
    if capacity_bytes is None:
        capacity_bytes = sgd_required_capacity_bytes(
            mb, nb, K, f, prefetch_depth)
    sched = SgdEpochSchedule(
        g=g, mb=mb, nb=nb, K=K, f=f, n_workers=n_workers,
        set_waves=tuple(set_waves), capacity_bytes=int(capacity_bytes))
    assert sched.waves_per_epoch == g * -(-g // n_workers)
    return sched


def required_capacity_bytes(store, sched: IterationSchedule, f: int,
                            prefetch_depth: int = 2) -> int:
    """Per-device bytes the streaming driver will actually keep resident.

    Mirrors the driver's MemoryMeter model exactly: up to ``depth + 2`` wave
    buffers can be live at once — ``depth`` queued in the Prefetcher, one
    already materialized by the worker while it blocks on the full queue,
    and one held by the consuming wave — plus the fixed factor and solve
    scratch (solve-X half) or the accumulators (accumulate-Theta half).
    The honest counterpart of the planner's eq. (8) estimate, computed from
    the store's *real* padding fills.  ``plan_for(fill=store.worst_fill,
    buffers=prefetch_depth + 2, acc_bytes=streaming_acc_bytes(n, f))``
    should dominate this.

    On a ``p > 1`` schedule (mesh streaming) every theta-sized resident —
    the fixed Theta, the Hermitian accumulators, the solved shard — divides
    by p, and the solve-X wave payload is the device's single column block
    of the p-partitioned slice; only the fresh X slice of the accumulate
    half stays replicated across the model axis (every shard's partial
    Hermitian reads the whole batch).

    A degree-binned store streams bin-wise cuts: at p = 1 per-wave
    payloads vary with where each bin's rows fall, so the model bounds
    every wave by the maximum per-batch payload — still ``le`` vs the
    meter, and never above the uniform-K model.  At p > 1 the theta half
    streams the batch-uniform stacks (``rt_stacked``): every batch
    presents the same per-bin shapes, so its payload is one exact
    constant per batch.
    """
    n_data, p = sched.n_data, sched.p
    wave_rows = sched.waves[0].rows
    bufs = prefetch_depth + 2
    binned = getattr(store, "r_binned", None) is not None
    stacked = getattr(store, "rt_stacked", None)
    # solve-X half: resident Theta shard + wave triplets + solve scratch
    theta_bytes = store.n * f * 4 // p
    if binned:
        x_payload = max(
            _binned_span_bytes(store.r_binned, w.row_start, w.row_stop)
            // len(w.batches)
            for w in sched.waves)
    else:
        K = store.r.K if p == 1 else store.r_model_parts.idx.shape[-1]
        x_payload = (wave_rows * (K * 8 + 4)) // n_data
    x_scratch = (wave_rows * (f * f + 2 * f) * 4) // n_data
    x_half = theta_bytes + bufs * x_payload + x_scratch
    # accumulate-Theta half: resident A/B/c shard + per-batch R^T rows of
    # the owned theta shard + the batch's (replicated) X slice
    q, n, K_loc = store.rt_parts.idx.shape
    acc_bytes = n * (f * f + f + 1) * 4 // p
    if binned:
        from repro.outofcore.store import binned_nbytes
        t_payload = max(binned_nbytes(b) for b in store.rt_binned) \
            + (sched.m_pad // q) * f * 4
    elif stacked is not None:
        # one batch's per-bin triplets, 1/p on each device (rows_b rows are
        # sharded over the model axis), plus the replicated fresh X slice
        batch_trip = sum(st.rows * (st.K * 8 + 4) for st in stacked)
        t_payload = batch_trip // p + (sched.m_pad // q) * f * 4
    else:
        t_payload = n * (K_loc * 8 + 4) // p + (sched.m_pad // q) * f * 4
    t_half = acc_bytes + bufs * t_payload + n * f * 4 // p
    return max(x_half, t_half)


def _binned_span_bytes(binned, start: int, stop: int) -> int:
    """Triplet bytes of original rows ``[start, stop)`` cut bin-wise: each
    bin contributes its span's rows at that bin's own K (idx + val slots
    at 8 bytes, cnt at 4) — exactly what ``x_slice_binned`` materializes."""
    return sum((hi - lo) * (b.K * 8 + 4)
               for b, (lo, hi) in zip(binned.bins,
                                      binned.bin_spans(start, stop)))


def _binned_span_slots(binned, start: int, stop: int) -> int:
    """Padded ELL slots of the same bin-wise cut."""
    return sum((hi - lo) * b.K
               for b, (lo, hi) in zip(binned.bins,
                                      binned.bin_spans(start, stop)))


# ---------------------------------------------------------------------------
# Plan-side streaming predictions (the ledger's "predicted" column).
# ---------------------------------------------------------------------------

def predicted_stream_stats(store, sched: IterationSchedule, f: int) -> dict:
    """Per-wave plan-side streaming stats of ONE ALS iteration, computed
    from the store's array shapes alone — no wave is ever materialized.

    Returns six lists aligned with ``sched.waves``: ``x_bytes`` /
    ``x_slots`` / ``x_nnz`` for the solve-X half and ``t_bytes`` /
    ``t_slots`` / ``t_nnz`` for the accumulate-Theta half.  ``*_bytes``
    predict exactly what the driver's ``bytes_streamed`` counter will
    measure for that wave (rating triplets, and on the theta half the
    replicated fresh X slices too); ``*_slots`` count the padded ELL slots
    streamed (rating payloads only — dense factor slices carry no padding)
    and ``*_nnz`` the true ratings under them, from the host-resident cnt
    arrays.  Per-wave granularity is what keeps the prediction exact under
    ragged last waves and mid-iteration resume: the driver sums exactly
    the waves it executes.  On a ``p > 1`` schedule the solve-X side uses
    the mesh triplet layout (``x_slice_mesh_triplet``'s pre-padding
    shapes).  On a degree-binned store the per-wave numbers sum each bin's
    contiguous span at that bin's own K (``x_slice_binned`` /
    ``theta_batch_binned``'s exact shapes) — still exact integers, so the
    ledger's ``fill_waste_ratio`` stays an equality under binning.  A
    stacked store (``p > 1`` with ``n_bins > 1``) prices the theta half
    from the batch-uniform ``rt_stacked`` shapes (``theta_wave_stacked``'s
    exact per-batch payloads) while the solve-X side stays on the uniform
    mesh layout.
    """
    p = sched.p
    binned = getattr(store, "r_binned", None) is not None
    cnt_rows = store.r.cnt                    # [m_pad], padded rows cnt = 0
    x_bytes, x_slots, x_nnz = [], [], []
    if binned:
        for w in sched.waves:
            x_bytes.append(_binned_span_bytes(
                store.r_binned, w.row_start, w.row_stop))
            x_slots.append(_binned_span_slots(
                store.r_binned, w.row_start, w.row_stop))
            x_nnz.append(int(cnt_rows[w.row_start:w.row_stop].sum()))
    else:
        if p == 1:
            K = store.r.K
            per_row_bytes = K * 8 + 4         # idx + val slots, cnt
            per_row_slots = K
        else:
            K_loc = store.r_model_parts.idx.shape[-1]
            per_row_bytes = p * (K_loc * 8 + 4)  # [rows, p*K_loc] x2 + [rows, p]
            per_row_slots = p * K_loc
        for w in sched.waves:
            x_bytes.append(w.rows * per_row_bytes)
            x_slots.append(w.rows * per_row_slots)
            x_nnz.append(int(cnt_rows[w.row_start:w.row_stop].sum()))
    q, n, K_t = store.rt_parts.idx.shape
    stacked = getattr(store, "rt_stacked", None)
    t_bytes, t_slots, t_nnz = [], [], []
    if binned:
        from repro.outofcore.store import binned_nbytes
        shard_bytes = [binned_nbytes(b) for b in store.rt_binned]
        shard_slots = [int(b.padded_slots) for b in store.rt_binned]
        for w in sched.waves:
            t_bytes.append(sum(
                shard_bytes[b.index] + (b.row_stop - b.row_start) * f * 4
                for b in w.batches))
            t_slots.append(sum(shard_slots[b.index] for b in w.batches))
            t_nnz.append(sum(int(store.rt_parts.cnt[b.index].sum())
                             for b in w.batches))
    elif stacked is not None:
        # batch-uniform stacks: every batch streams the same per-bin shapes
        # (rows_b x K_b triplets), so one constant prices all batches
        batch_trip = sum(st.rows * (st.K * 8 + 4) for st in stacked)
        batch_slots = sum(st.rows * st.K for st in stacked)
        for w in sched.waves:
            t_bytes.append(sum(
                batch_trip + (b.row_stop - b.row_start) * f * 4
                for b in w.batches))
            t_slots.append(len(w.batches) * batch_slots)
            t_nnz.append(sum(int(store.rt_parts.cnt[b.index].sum())
                             for b in w.batches))
    else:
        batch_trip = n * (K_t * 8 + 4)        # one R^T shard's triplet
        for w in sched.waves:
            t_bytes.append(sum(
                batch_trip + (b.row_stop - b.row_start) * f * 4
                for b in w.batches))
            t_slots.append(len(w.batches) * n * K_t)
            t_nnz.append(sum(int(store.rt_parts.cnt[b.index].sum())
                             for b in w.batches))
    return {"x_bytes": x_bytes, "x_slots": x_slots, "x_nnz": x_nnz,
            "t_bytes": t_bytes, "t_slots": t_slots, "t_nnz": t_nnz}


def predicted_sgd_stream_stats(tiles, sched: SgdEpochSchedule) -> dict:
    """Plan-side per-tile streaming stats for the SGD ledger.

    All three come back as ``[g, g]`` per-tile matrices: ``tile_bytes``
    is the tile's ELL triplet (``sgd_tile_bytes`` at the tile's own K on
    a per-tile-binned grid, the grid-wide K otherwise) plus the two
    factor blocks the driver fetches synchronously and the measured
    counter includes; ``tile_slots`` the padded slots the tile's kernel
    shape dispatches; ``tile_nnz`` the true ratings from the grid's
    host-resident cnt.  The driver sums these over exactly the (possibly
    resumed-into, per-epoch-permuted) waves it executes — on a uniform
    grid every entry is the same constant, so the sums are unchanged.
    """
    mb, nb, K, f = sched.mb, sched.nb, sched.K, sched.f
    g = sched.g
    grid = tiles.grid
    tk = (np.full((g, g), K, dtype=np.int64) if grid.tile_K is None
          else grid.tile_K.astype(np.int64))
    return {
        "tile_bytes": mb * tk * 8 + mb * 4 + (mb + nb) * f * 4,  # [g, g]
        "tile_slots": mb * tk,                                   # [g, g]
        "tile_nnz": tiles.grid.cnt.sum(axis=-1),                 # [g, g]
    }
