"""Wave schedule: a PartitionPlan made executable (paper §4.3/§4.4).

``build_schedule`` turns the planner's (p, q, waves) into explicit per-
iteration work: which q-batches (X row ranges) each wave streams, which R
shards it touches, and which factor slices must be device-resident.  One
iteration runs two halves over the *same* wave list:

- **solve-X half** — Theta is fully resident (the plan's ``Theta_shard``
  term); wave ``w`` streams the R rows of its batches, solves those X rows
  directly, and writes the slice back to host.
- **accumulate-Theta half** — the A/B Hermitian accumulators for all n items
  are resident; wave ``w`` streams, per batch ``j``, the R^T column shard of
  user-batch ``j`` plus the freshly solved X slice of batch ``j`` (the
  "factor slices resident" of §4.4), and adds the batch's partial Hermitians.
  After the last wave the accumulated systems are solved in row blocks.

This is SU-ALS's partial-sum scheme (eq. 5-7) serialized over waves: with
``n_data`` simulated devices, each wave models one synchronous step in which
every device holds one q-batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.partition import GiB, PartitionPlan, QBatch, export_schedule


@dataclasses.dataclass(frozen=True)
class Wave:
    """One synchronous streaming step: up to n_data contiguous q-batches."""

    index: int
    batches: Tuple[QBatch, ...]

    @property
    def row_start(self) -> int:
        return self.batches[0].row_start

    @property
    def row_stop(self) -> int:
        return self.batches[-1].row_stop

    @property
    def rows(self) -> int:
        return self.row_stop - self.row_start


@dataclasses.dataclass(frozen=True)
class IterationSchedule:
    plan: PartitionPlan
    m_pad: int                  # padded X rows (multiple of q)
    n: int                      # Theta rows
    n_data: int                 # simulated devices on the data axis
    waves: Tuple[Wave, ...]     # shared by both halves of an iteration
    capacity_bytes: int         # per-device budget the driver meters against

    @property
    def waves_per_iteration(self) -> int:
        """Checkpoint steps per iteration: each half walks every wave once."""
        return 2 * len(self.waves)

    def describe(self) -> str:
        w = self.waves[0]
        return (f"waves={len(self.waves)} x {len(w.batches)} batches "
                f"({w.rows} rows/wave, m_pad={self.m_pad}, n={self.n}, "
                f"capacity={self.capacity_bytes / GiB:.3f}GiB)")


def build_schedule(
    plan: PartitionPlan,
    m: int,
    n: int,
    *,
    n_data: Optional[int] = None,
    capacity_bytes: Optional[int] = None,
) -> IterationSchedule:
    """Explicit per-iteration schedule for ``plan`` on an (m x n) problem.

    ``m`` may be the true row count; it is padded up to a multiple of q here
    so every wave has identical shape (build the RatingStore with the same q
    and the stores line up).  ``capacity_bytes`` defaults to the plan's own
    per-device estimate — the budget the driver's memory meter reports
    against.
    """
    if n_data is None:
        n_data = -(-plan.q // plan.waves)
    m_pad = -(-m // plan.q) * plan.q
    groups = export_schedule(plan, m_pad, n_data)
    waves = tuple(Wave(index=w, batches=g) for w, g in enumerate(groups))
    assert len(waves) * n_data >= plan.q
    assert waves[0].row_start == 0 and waves[-1].row_stop == m_pad
    return IterationSchedule(
        plan=plan, m_pad=m_pad, n=n, n_data=n_data, waves=waves,
        capacity_bytes=(plan.bytes_per_device if capacity_bytes is None
                        else capacity_bytes))


def required_capacity_bytes(store, sched: IterationSchedule, f: int,
                            prefetch_depth: int = 2) -> int:
    """Per-device bytes the streaming driver will actually keep resident.

    Mirrors the driver's MemoryMeter model exactly: up to ``depth + 2`` wave
    buffers can be live at once — ``depth`` queued in the Prefetcher, one
    already materialized by the worker while it blocks on the full queue,
    and one held by the consuming wave — plus the fixed factor and solve
    scratch (solve-X half) or the accumulators (accumulate-Theta half).
    The honest counterpart of the planner's eq. (8) estimate, computed from
    the store's *real* padding fills.  ``plan_for(fill=store.worst_fill,
    buffers=prefetch_depth + 2, eps=<accumulator bytes>)`` should dominate
    this.
    """
    n_data = sched.n_data
    wave_rows = sched.waves[0].rows
    bufs = prefetch_depth + 2
    # solve-X half: resident Theta + wave triplets + Hermitian/solve scratch
    theta_bytes = store.n * f * 4
    K = store.r.K
    x_payload = (wave_rows * (K * 8 + 4)) // n_data
    x_scratch = (wave_rows * (f * f + 2 * f) * 4) // n_data
    x_half = theta_bytes + bufs * x_payload + x_scratch
    # accumulate-Theta half: resident A/B/c + per-batch shard + X slice
    q, n, K_loc = store.rt_parts.idx.shape
    acc_bytes = n * (f * f + f + 1) * 4
    t_payload = n * (K_loc * 8 + 4) + (sched.m_pad // q) * f * 4
    t_half = acc_bytes + bufs * t_payload + n * f * 4
    return max(x_half, t_half)
