"""Streaming ALS driver: execute a wave schedule end to end (§4.4).

Per iteration the driver runs the two halves of the schedule:

- **solve-X**: Theta resident on device; each wave's R row-slice is double-
  buffered host->device through ``data.prefetch.Prefetcher`` while the
  current wave solves its X rows (``core.als.update_rows``); solved slices
  are written straight back to the host ``FactorStore``.
- **accumulate-Theta**: A/B Hermitian accumulators resident; each wave
  streams its batches' R^T column shards together with the freshly solved X
  slices (``core.als.partial_herm``), and after the last wave the
  accumulated systems are solved (``core.als.solve_accumulated``).

Every wave completion checkpoints the full resumable state (factors +
accumulators + global step) through ``checkpoint.CheckpointManager``, so a
killed run restarts mid-iteration — the paper's §4.4 fault tolerance at wave
rather than iteration granularity.

A ``MemoryMeter`` tracks the *simulated device* footprint: the meter models
one device of the ``n_data`` axis (wave payloads are divided by ``n_data``;
replicated residents — the fixed factor, the accumulators — are counted in
full), which is what the planner's eq. (8) budget prices.

**Mesh streaming** (``mesh=`` set): the same schedule executes on a real
``(data, model)`` device mesh — the paper's full data x model parallelism
instead of one model-shard's simulated view:

- the solve-X half dispatches each wave through
  ``distributed.su_als.make_wave_update_fn`` (shard-mapped SU-ALS: local
  partial Hermitians from each device's theta shard, psum-scatter over the
  model axis, p-way parallel solve, gather);
- theta lives as ``p`` model shards — each device holds only its
  ``[n/p, f]`` shard plus its column block of the wave's R slice, and the
  meter prices exactly that;
- the accumulate-Theta half computes per-(data, model) partial Hermitians
  on the mesh (``make_wave_herm_fn``) with **no in-program reduction**:
  each data shard accumulates its own partials across waves (float64 on
  host, standing in for device-resident partial state), and the half ends
  with ``distributed.reduce.topology_reduce`` — the paper's Fig. 5b
  intra-socket-ring-then-inter-socket-tree schedule, validated bit-for-bit
  against the flat all-reduce oracle — before each model shard solves and
  writes back its own theta rows.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import als as als_mod
from repro.core.objective import rmse_padded
from repro.data.prefetch import Prefetcher
from repro.kernels.budgets import BUDGETS, footprint_bytes
from repro.obs.ledger import Ledger
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import current_tracer, phase
from repro.outofcore.runtime import (MemoryMeter, SimulatedFailure,
                                     StreamTelemetry, WaveCheckpointer)
from repro.outofcore.schedule import (IterationSchedule,
                                      predicted_stream_stats,
                                      required_capacity_bytes)
from repro.outofcore.store import (FactorStore, RatingStore, binned_nbytes,
                                   triplet_nbytes)

__all__ = ["MemoryMeter", "SimulatedFailure", "StreamTelemetry",
           "run_streaming_als"]


def _binned_cnt_rows(binned) -> np.ndarray:
    """Full-length [m] float32 per-row counts of a BinnedELL (bins hold
    disjoint row subsets, so plain assignment reassembles the vector)."""
    out = np.zeros(binned.m, np.float32)
    for b, r in zip(binned.bins, binned.rows):
        if b.m:
            out[r] = b.cnt
    return out


def _zeros_ckpt_tree(m_pad: int, n: int, f: int, n_dev: int = 0) -> dict:
    """Checkpoint structure.  The acc leaves are committed EMPTY (zero rows)
    by solve-X-half saves — restore never reads them there, and shipping
    full-sized zeros would dominate the per-wave checkpoint I/O — and are
    replaced with the live accumulators by mid-accumulate-half saves: the
    serial f32 partial sums, or, on the mesh path (``n_dev`` > 0), the
    PER-DATA-SHARD float64 partials so a resume replays the topology-aware
    reduction bit-exactly from the same summands.
    """
    acc_dt = np.float64 if n_dev else np.float32
    lead = (n_dev,) if n_dev else ()
    return {
        "x": np.zeros((m_pad, f), np.float32),
        "theta": np.zeros((n, f), np.float32),
        "a_acc": np.zeros(lead + (0, f, f), acc_dt),
        "b_acc": np.zeros(lead + (0, f), acc_dt),
        "c_acc": np.zeros(lead + (0,), acc_dt),
    }


def _mesh_axes(mesh) -> tuple[int, int, object]:
    """(n_data, p, col_dim spec entry) of a streaming mesh."""
    from repro.distributed.su_als import _col_axes
    assert "data" in mesh.axis_names, mesh.axis_names
    col_axes, col_dim = _col_axes(mesh)
    assert col_axes, f"mesh needs a model axis, got {mesh.axis_names}"
    p = 1
    for a in col_axes:
        p *= mesh.shape[a]
    return mesh.shape["data"], p, col_dim


def run_streaming_als(
    ratings: RatingStore,
    sched: IterationSchedule,
    cfg: als_mod.AlsConfig,
    *,
    factors: Optional[FactorStore] = None,
    ckpt_dir: Optional[str] = None,
    keep: int = 3,
    prefetch_depth: int = 2,
    train_eval=None,                 # (idx, val, cnt) for per-iteration RMSE
    test_eval=None,
    fail_after_waves: Optional[int] = None,
    update_rows_fn: Optional[Callable] = None,
    partial_herm_fn: Optional[Callable] = None,
    solve_acc_fn: Optional[Callable] = None,
    mesh=None,
    topology=None,
    callback=None,
    tracer=None,
    registry=None,
) -> tuple[FactorStore, List[dict], StreamTelemetry]:
    """Run ``cfg.iters`` streaming ALS iterations of ``sched`` over ``ratings``.

    Returns (factor store, per-iteration history, telemetry).  With
    ``ckpt_dir`` set the run resumes from the latest committed wave; the
    ``*_fn`` hooks default to the in-process ``core.als`` entry points.

    Observability: every hot phase runs inside an ``obs`` span — the whole
    run (``driver``), each iteration/half, one ``solve`` span per wave,
    ``reduce`` for the mesh epilogue, ``checkpoint`` per commit — and all
    counting/timing goes through ``registry`` (an ``obs.MetricsRegistry``;
    one is created when not passed).  ``tracer`` defaults to the
    process-wide tracer (``obs.set_tracer`` / ``--trace``); with the
    default ``NULL_TRACER`` the spans are no-ops and only the cheap
    per-wave metrics remain.  The returned telemetry is
    ``StreamTelemetry.from_registry`` — same fields as ever, plus the
    ``phase_seconds`` breakdown, which each history record also carries as
    its per-iteration delta.

    With a degree-binned ``RatingStore`` (``n_bins > 1``) both halves
    stream bin-wise cuts and dispatch the kernels once per bin at that
    bin's own K — identical factor trajectory (padding slots are exact
    zeros), strictly fewer streamed slots/bytes; the ``update_rows_fn`` /
    ``partial_herm_fn`` hooks are bypassed on this path.  Binned + mesh
    (``p > 1``): the accumulate-Theta half streams the store's
    batch-uniform stacked bins (``rt_stacked``) — one ``wave_herm``
    dispatch per bin, partials host-scattered through the ``items`` maps —
    while the solve-X half keeps the uniform mesh layout.

    With ``mesh`` set (axes ``("data", "model")``, sizes matching
    ``sched.n_data`` and ``sched.p``) every wave executes shard-mapped on
    the real mesh and theta is handled as p model shards; ``topology`` is
    the ``distributed.reduce.DeviceTopology`` of the data axis for the
    accumulate-half reduction (default: fast domains of 2, the paper's
    2-GPUs-per-PCIe-switch machine).  ``partial_herm_fn`` is unused on the
    mesh path (the shard-mapped ``make_wave_herm_fn`` replaces it).
    """
    assert ratings.m_pad == sched.m_pad and ratings.n == sched.n, \
        "RatingStore and IterationSchedule were built for different shapes"
    f = cfg.f
    m_pad, n, n_data = sched.m_pad, sched.n, sched.n_data
    W = len(sched.waves)
    wpi = sched.waves_per_iteration            # 2 * W checkpoint steps/iter
    user_update_fn = update_rows_fn            # explicit hook (mesh override)
    update_rows_fn = update_rows_fn or (
        lambda fixed, i, v, c: als_mod.update_rows(fixed, i, v, c, cfg))
    partial_herm_fn = partial_herm_fn or (
        lambda xb, i, v, c: als_mod.partial_herm(xb, i, v, c, cfg))
    solve_acc_fn = solve_acc_fn or (
        lambda A, B, c: als_mod.solve_accumulated(A, B, c, cfg))

    # degree-binned store: waves stream bin-wise cuts and dispatch the
    # kernels once per bin at that bin's K.  p = 1 binned runs cut both
    # halves bin-wise; on a mesh the theta half streams the batch-uniform
    # stacked bins (rt_stacked) while solve-X keeps the uniform mesh layout.
    n_bins = getattr(ratings, "n_bins", 1)
    binned = n_bins > 1
    stacked = getattr(ratings, "rt_stacked", None)
    assert not (binned and mesh is None and stacked is not None), \
        "stacked (p > 1) binned stores require mesh= to stream"
    assert not (binned and mesh is not None and stacked is None), \
        "mesh streaming of a binned store needs batch-uniform bins; " \
        "build the RatingStore with p > 1 so rt_stacked exists"

    p = 1
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import reduce as dreduce
        from repro.distributed.su_als import (make_wave_herm_fn,
                                              make_wave_update_fn)
        mesh_n_data, p, col_dim = _mesh_axes(mesh)
        assert mesh_n_data == n_data, \
            f"mesh data axis {mesh_n_data} != schedule n_data {n_data}"
        assert p == sched.p == ratings.p, (p, sched.p, ratings.p)
        assert n % p == 0, (n, p)
        topo = topology or dreduce.linear_topology(n_data, group_size=2)
        assert topo.n_devices == n_data, (topo.describe(), n_data)
        # per-reduce link traffic is a pure function of the payload size and
        # the topology — priced once here, measured against in the ledger
        topo_traffic = dreduce.reduce_traffic(n * (f * f + f + 1) * 4, topo)
        wave_update = make_wave_update_fn(
            mesh, cfg.lam, mode=cfg.mode,
            tm=cfg.tm, tk=cfg.tk, tb=cfg.tb, f_mult=cfg.f_mult)
        wave_herm = make_wave_herm_fn(
            mesh, cfg.lam, mode=cfg.mode,
            tm=cfg.tm, tk=cfg.tk, f_mult=cfg.f_mult)
        rows_sh = NamedSharding(mesh, P("data", col_dim))
        fixed_sh = NamedSharding(mesh, P(col_dim, None))

    meter = MemoryMeter()
    tracer = tracer if tracer is not None else current_tracer()
    reg = registry if registry is not None else MetricsRegistry()
    topo_desc = topo.describe() if mesh is not None else ""

    mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
    acc_restored = None
    start_step = 0
    if mgr is not None:
        tree, start_step = mgr.restore_or_init(
            _zeros_ckpt_tree(m_pad, n, f, n_data if mesh is not None else 0),
            lambda: None)
        if start_step:
            factors = FactorStore.from_arrays(tree["x"], tree["theta"])
            if start_step % wpi > W:       # killed mid-accumulate-Theta
                acc_restored = (tree["a_acc"], tree["b_acc"], tree["c_acc"])
    reg.gauge("resumed_from_step").set(start_step)
    if factors is None:
        st = als_mod.als_init(ratings.m, n, cfg)
        x0 = np.zeros((m_pad, f), np.float32)
        x0[:ratings.m] = np.asarray(st.x)
        factors = FactorStore.from_arrays(x0, np.asarray(st.theta))

    ckpt = WaveCheckpointer(mgr, fail_after_waves,
                            tracer=tracer, registry=reg)

    def _save(step: int, acc=None):
        def tree_fn():
            tree = _zeros_ckpt_tree(m_pad, n, f,
                                    n_data if mesh is not None else 0)
            # snapshot copies: the manager commits async while later waves
            # keep mutating the live factor arrays
            tree["x"], tree["theta"] = factors.x.copy(), factors.theta.copy()
            if acc is not None:
                # np.array, NOT np.asarray: on the mesh path the acc leaves
                # are the live f64 per-shard accumulators and asarray would
                # alias them (same dtype), racing the async commit against
                # the next wave's in-place `A_dev += A_w`
                tree["a_acc"] = np.array(acc[0], tree["a_acc"].dtype)
                tree["b_acc"] = np.array(acc[1], tree["b_acc"].dtype)
                tree["c_acc"] = np.array(acc[2], tree["c_acc"].dtype)
            return tree
        ckpt.save(step, tree_fn)

    # ------------------------------------------------------------------
    # solve-X half: stream R row slices, solve rows, write back.
    # ------------------------------------------------------------------
    def _x_half(it: int, first_wave: int):
        theta_dev = jnp.asarray(factors.theta)
        meter.alloc("fixed_theta", factors.theta.nbytes)
        scratch = (sched.waves[0].rows * (f * f + 2 * f) * 4) // n_data

        def gen():
            for wave in sched.waves[first_wave:]:
                yield wave, ratings.x_slice_triplet(
                    wave.row_start, wave.row_stop)

        def put(item):
            wave, trip = item
            nb = triplet_nbytes(trip)
            # per-device share: each device on the axis takes ONE batch of
            # the wave (a ragged last wave has fewer batches than n_data)
            meter.alloc(f"xwave{wave.index}", nb // len(wave.batches))
            reg.counter("padded_slots").inc(trip[0].size)
            reg.counter("nnz_streamed").inc(int(trip[2].sum()))
            reg.counter("x_padded_slots").inc(trip[0].size)
            reg.counter("x_nnz_streamed").inc(int(trip[2].sum()))
            dev = tuple(jnp.asarray(a) for a in trip)
            return wave, dev, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, (idx, val, cnt), nb in pf:
                    with phase("als.wave_x", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb):
                        meter.alloc("x_scratch", scratch)
                        rows = np.asarray(
                            update_rows_fn(theta_dev, idx, val, cnt))
                        meter.free("x_scratch")
                        factors.write_slice("x", wave.row_start,
                                            wave.row_stop, rows)
                    meter.free(f"xwave{wave.index}")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(wave.batches))
                    reg.counter("bytes_streamed").inc(nb)
                    _save(it * wpi + wave.index + 1)
        finally:
            meter.free("fixed_theta")

    # ------------------------------------------------------------------
    # accumulate-Theta half: stream R^T shards + X slices, accumulate,
    # solve after the last wave.
    # ------------------------------------------------------------------
    def _theta_half(it: int, first_wave: int, acc0=None):
        acc_bytes = n * (f * f + f + 1) * 4
        meter.alloc("acc", acc_bytes)
        if acc0 is not None:
            A = jnp.asarray(acc0[0], jnp.float32)
            B = jnp.asarray(acc0[1], jnp.float32)
            c = jnp.asarray(acc0[2], jnp.float32)
        else:
            A = jnp.zeros((n, f, f), jnp.float32)
            B = jnp.zeros((n, f), jnp.float32)
            c = jnp.zeros((n,), jnp.float32)

        def gen():
            for wave in sched.waves[first_wave:]:
                payload = [
                    (b, ratings.theta_batch_triplet(b.index),
                     factors.read_slice("x", b.row_start, b.row_stop))
                    for b in wave.batches]
                yield wave, payload

        def put(item):
            wave, payload = item
            nb = sum(triplet_nbytes(t) + x.nbytes for _, t, x in payload)
            # each simulated device holds ONE batch's shard + X slice
            meter.alloc(f"twave{wave.index}", nb // len(payload))
            slots = sum(t[0].size for _, t, _x in payload)
            nz = sum(int(t[2].sum()) for _, t, _x in payload)
            reg.counter("padded_slots").inc(slots)
            reg.counter("nnz_streamed").inc(nz)
            reg.counter("t_padded_slots").inc(slots)
            reg.counter("t_nnz_streamed").inc(nz)
            dev = [(b, tuple(jnp.asarray(a) for a in t), jnp.asarray(x))
                   for b, t, x in payload]
            return wave, dev, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, payload, nb in pf:
                    last = wave.index == W - 1
                    with phase("als.wave_theta", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb):
                        for _, (idx, val, cnt), x_dev in payload:
                            Aj, Bj = partial_herm_fn(x_dev, idx, val, cnt)
                            A = A + Aj
                            B = B + Bj
                            c = c + cnt.astype(jnp.float32)
                        meter.free(f"twave{wave.index}")
                        if last:
                            meter.alloc("theta_out", n * f * 4)
                            factors.write_slice(
                                "theta", 0, n,
                                np.asarray(solve_acc_fn(A, B, c)))
                            meter.free("theta_out")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(payload))
                    reg.counter("bytes_streamed").inc(nb)
                    _save(it * wpi + W + wave.index + 1,
                          acc=None if last else (A, B, c))
        finally:
            meter.free("acc")

    # ------------------------------------------------------------------
    # Binned halves: the same waves cut bin-wise — each wave's rows arrive
    # as a BinnedELL and the kernels dispatch once per bin at that bin's K.
    # Padding slots are exact zeros, so the factor trajectory is identical
    # to the uniform halves'; only the streamed slots/bytes shrink.
    # ------------------------------------------------------------------
    def _x_half_binned(it: int, first_wave: int):
        theta_dev = jnp.asarray(factors.theta)
        meter.alloc("fixed_theta", factors.theta.nbytes)
        scratch = (sched.waves[0].rows * (f * f + 2 * f) * 4) // n_data

        def gen():
            for wave in sched.waves[first_wave:]:
                yield wave, ratings.x_slice_binned(
                    wave.row_start, wave.row_stop)

        def put(item):
            wave, bsl = item
            nb = binned_nbytes(bsl)
            meter.alloc(f"xwave{wave.index}", nb // len(wave.batches))
            reg.counter("padded_slots").inc(int(bsl.padded_slots))
            reg.counter("nnz_streamed").inc(int(bsl.nnz))
            reg.counter("x_padded_slots").inc(int(bsl.padded_slots))
            reg.counter("x_nnz_streamed").inc(int(bsl.nnz))
            return wave, bsl, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, bsl, nb in pf:
                    with phase("als.wave_x", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb, bins=bsl.n_bins):
                        meter.alloc("x_scratch", scratch)
                        rows = np.asarray(
                            als_mod.update_rows_binned(theta_dev, bsl, cfg))
                        meter.free("x_scratch")
                        factors.write_slice("x", wave.row_start,
                                            wave.row_stop, rows)
                    meter.free(f"xwave{wave.index}")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(wave.batches))
                    reg.counter("bytes_streamed").inc(nb)
                    _save(it * wpi + wave.index + 1)
        finally:
            meter.free("fixed_theta")

    def _theta_half_binned(it: int, first_wave: int, acc0=None):
        acc_bytes = n * (f * f + f + 1) * 4
        meter.alloc("acc", acc_bytes)
        if acc0 is not None:
            A = jnp.asarray(acc0[0], jnp.float32)
            B = jnp.asarray(acc0[1], jnp.float32)
            c = jnp.asarray(acc0[2], jnp.float32)
        else:
            A = jnp.zeros((n, f, f), jnp.float32)
            B = jnp.zeros((n, f), jnp.float32)
            c = jnp.zeros((n,), jnp.float32)

        def gen():
            for wave in sched.waves[first_wave:]:
                payload = [
                    (b, ratings.theta_batch_binned(b.index),
                     factors.read_slice("x", b.row_start, b.row_stop))
                    for b in wave.batches]
                yield wave, payload

        def put(item):
            wave, payload = item
            nb = sum(binned_nbytes(bell) + x.nbytes
                     for _, bell, x in payload)
            meter.alloc(f"twave{wave.index}", nb // len(payload))
            slots = sum(int(bell.padded_slots) for _, bell, _x in payload)
            nz = sum(int(bell.nnz) for _, bell, _x in payload)
            reg.counter("padded_slots").inc(slots)
            reg.counter("nnz_streamed").inc(nz)
            reg.counter("t_padded_slots").inc(slots)
            reg.counter("t_nnz_streamed").inc(nz)
            dev = [(b, bell, jnp.asarray(x)) for b, bell, x in payload]
            return wave, dev, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, payload, nb in pf:
                    last = wave.index == W - 1
                    with phase("als.wave_theta", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb, bins=n_bins):
                        for _, bell, x_dev in payload:
                            Aj, Bj = als_mod.partial_herm_binned(
                                x_dev, bell, cfg)
                            A = A + Aj
                            B = B + Bj
                            c = c + jnp.asarray(_binned_cnt_rows(bell))
                        meter.free(f"twave{wave.index}")
                        if last:
                            meter.alloc("theta_out", n * f * 4)
                            factors.write_slice(
                                "theta", 0, n,
                                np.asarray(solve_acc_fn(A, B, c)))
                            meter.free("theta_out")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(payload))
                    reg.counter("bytes_streamed").inc(nb)
                    _save(it * wpi + W + wave.index + 1,
                          acc=None if last else (A, B, c))
        finally:
            meter.free("acc")

    # ------------------------------------------------------------------
    # Mesh halves: the same waves, shard-mapped on the real (data, model)
    # mesh with theta as p shards and a host-scheduled partial reduction.
    # ------------------------------------------------------------------
    def _x_half_mesh(it: int, first_wave: int):
        theta_dev = jax.device_put(factors.theta, fixed_sh)
        meter.alloc("fixed_theta", factors.theta.nbytes // p)  # one shard
        full_rows = sched.waves[0].rows          # n_data * rows-per-batch
        scratch = (full_rows * (f * f + 2 * f) * 4) // n_data
        custom_update = user_update_fn or wave_update

        def gen():
            for wave in sched.waves[first_wave:]:
                yield wave, ratings.x_slice_mesh_triplet(
                    wave.row_start, wave.row_stop)

        def put(item):
            wave, (idx, val, cnt) = item
            nb = int(idx.nbytes + val.nbytes + cnt.nbytes)
            # per-device share: one batch's rows x one model column block
            meter.alloc(f"xwave{wave.index}", nb // (len(wave.batches) * p))
            reg.counter("padded_slots").inc(idx.size)
            reg.counter("nnz_streamed").inc(int(cnt.sum()))
            reg.counter("x_padded_slots").inc(idx.size)
            reg.counter("x_nnz_streamed").inc(int(cnt.sum()))
            pad = full_rows - idx.shape[0]
            if pad:      # ragged last wave: empty rows solve to x_u = 0
                idx = np.pad(idx, ((0, pad), (0, 0)))
                val = np.pad(val, ((0, pad), (0, 0)))
                cnt = np.pad(cnt, ((0, pad), (0, 0)))
            dev = (jax.device_put(idx, rows_sh),
                   jax.device_put(val, rows_sh),
                   jax.device_put(cnt, rows_sh))
            return wave, dev, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, (idx, val, cnt), nb in pf:
                    with phase("als.wave_x", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb, mesh=True):
                        meter.alloc("x_scratch", scratch)
                        rows = np.asarray(
                            custom_update(theta_dev, idx, val, cnt))
                        meter.free("x_scratch")
                        factors.write_slice("x", wave.row_start,
                                            wave.row_stop, rows[:wave.rows])
                    meter.free(f"xwave{wave.index}")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(wave.batches))
                    reg.counter("bytes_streamed").inc(nb)
                    _save(it * wpi + wave.index + 1)
        finally:
            meter.free("fixed_theta")

    def _theta_half_mesh(it: int, first_wave: int, acc0=None):
        # per-device resident: only the owned model shard's systems
        acc_shard = n * (f * f + f + 1) * 4 // p
        meter.alloc("acc", acc_shard)
        if acc0 is not None:
            A_dev = np.asarray(acc0[0], np.float64).copy()
            B_dev = np.asarray(acc0[1], np.float64).copy()
            c_dev = np.asarray(acc0[2], np.float64).copy()
        else:
            A_dev = np.zeros((n_data, n, f, f), np.float64)
            B_dev = np.zeros((n_data, n, f), np.float64)
            c_dev = np.zeros((n_data, n), np.float64)

        def gen():
            for wave in sched.waves[first_wave:]:
                trips = [ratings.theta_batch_triplet(b.index)
                         for b in wave.batches]
                xs = [factors.read_slice("x", b.row_start, b.row_stop)
                      for b in wave.batches]
                yield wave, trips, xs

        def put(item):
            wave, trips, xs = item
            nbatch = len(trips)
            trip_nb = sum(triplet_nbytes(t) for t in trips)
            x_nb = sum(x.nbytes for x in xs)
            slots = sum(t[0].size for t in trips)
            nz = sum(int(t[2].sum()) for t in trips)
            reg.counter("padded_slots").inc(slots)
            reg.counter("nnz_streamed").inc(nz)
            reg.counter("t_padded_slots").inc(slots)
            reg.counter("t_nnz_streamed").inc(nz)
            # per device: 1/p of one batch's R^T shard (its theta rows) +
            # the batch's full X slice (replicated over the model axis)
            meter.alloc(f"twave{wave.index}",
                        trip_nb // (nbatch * p) + x_nb // nbatch)
            pad = n_data - nbatch
            idxT = np.stack([t[0] for t in trips])
            valT = np.stack([t[1] for t in trips])
            cntT = np.stack([t[2] for t in trips])
            x_stack = np.stack(xs)
            if pad:      # ragged last wave: empty batches contribute A = 0
                z = ((0, pad),) + ((0, 0),) * 2
                idxT, valT = np.pad(idxT, z), np.pad(valT, z)
                cntT = np.pad(cntT, ((0, pad), (0, 0)))
                x_stack = np.pad(x_stack, z)
            return wave, (x_stack, idxT, valT, cntT), trip_nb + x_nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, (x_stack, idxT, valT, cntT), nb in pf:
                    with phase("als.wave_theta", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb, mesh=True):
                        A_w, B_w = wave_herm(x_stack, idxT, valT, cntT)
                        # per-DATA-SHARD accumulation (float64: host
                        # stand-in for the device-resident partials; exact
                        # for f32 summands, so the final topology reduce is
                        # order-free)
                        A_dev += A_w
                        B_dev += B_w
                        c_dev += cntT
                    meter.free(f"twave{wave.index}")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(wave.batches))
                    reg.counter("bytes_streamed").inc(nb)
                    last = wave.index == W - 1
                    if last:
                        # NOT nested in the wave's solve span: the reduce +
                        # post-reduce shard solves are their own phase
                        _reduce_and_solve(A_dev, B_dev, c_dev)
                    _save(it * wpi + W + wave.index + 1,
                          acc=None if last else (A_dev, B_dev, c_dev))
        finally:
            meter.free("acc")

    def _theta_half_mesh_binned(it: int, first_wave: int, acc0=None):
        """Mesh theta half over the batch-uniform stacked bins: one
        ``wave_herm`` dispatch per bin per wave (one compiled shape per
        bin — ``make_wave_herm_fn`` is shape-polymorphic), partials
        host-scattered into the per-data-shard f64 accumulators through
        each stack's ``items`` map.  Padding rows/batches carry cnt = 0
        and produce exact-zero partials, so scattering them (``np.add.at``,
        duplicate-safe) changes nothing; the checkpoint tree is identical
        to the uniform mesh half's, so kill/resume stays bit-exact and
        layout-agnostic."""
        acc_shard = n * (f * f + f + 1) * 4 // p
        meter.alloc("acc", acc_shard)
        if acc0 is not None:
            A_dev = np.asarray(acc0[0], np.float64).copy()
            B_dev = np.asarray(acc0[1], np.float64).copy()
            c_dev = np.asarray(acc0[2], np.float64).copy()
        else:
            A_dev = np.zeros((n_data, n, f, f), np.float64)
            B_dev = np.zeros((n_data, n, f), np.float64)
            c_dev = np.zeros((n_data, n), np.float64)

        def gen():
            for wave in sched.waves[first_wave:]:
                bins = ratings.theta_wave_stacked(
                    [b.index for b in wave.batches])
                xs = [factors.read_slice("x", b.row_start, b.row_stop)
                      for b in wave.batches]
                yield wave, bins, xs

        def put(item):
            wave, bins, xs = item
            nbatch = len(xs)
            trip_nb = sum(int(i.nbytes + v.nbytes + c.nbytes)
                          for i, v, c, _ in bins)
            x_nb = sum(x.nbytes for x in xs)
            slots = sum(i.size for i, _v, _c, _it in bins)
            nz = sum(int(c.sum()) for _i, _v, c, _it in bins)
            reg.counter("padded_slots").inc(slots)
            reg.counter("nnz_streamed").inc(nz)
            reg.counter("t_padded_slots").inc(slots)
            reg.counter("t_nnz_streamed").inc(nz)
            meter.alloc(f"twave{wave.index}",
                        trip_nb // (nbatch * p) + x_nb // nbatch)
            pad = n_data - nbatch
            x_stack = np.stack(xs)
            if pad:      # ragged last wave: empty batches contribute A = 0
                z3 = ((0, pad), (0, 0), (0, 0))
                bins = [(np.pad(i, z3), np.pad(v, z3),
                         np.pad(c, ((0, pad), (0, 0))), items)
                        for i, v, c, items in bins]
                x_stack = np.pad(x_stack, z3)
            return wave, (x_stack, bins, nbatch), trip_nb + x_nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put,
                            tracer=tracer, registry=reg) as pf:
                for wave, (x_stack, bins, nbatch), nb in pf:
                    with phase("als.wave_theta", cat="solve", tracer=tracer,
                               registry=reg, wave=wave.index,
                               iteration=it + 1, bytes=nb, mesh=True,
                               bins=len(bins)):
                        for idx_b, val_b, cnt_b, items_b in bins:
                            A_w, B_w = wave_herm(x_stack, idx_b, val_b,
                                                 cnt_b)
                            A_w = np.asarray(A_w, np.float64)
                            B_w = np.asarray(B_w, np.float64)
                            for d in range(nbatch):
                                np.add.at(A_dev[d], items_b[d], A_w[d])
                                np.add.at(B_dev[d], items_b[d], B_w[d])
                                np.add.at(c_dev[d], items_b[d],
                                          cnt_b[d].astype(np.float64))
                    meter.free(f"twave{wave.index}")
                    reg.counter("waves_run").inc()
                    reg.counter("batches_loaded").inc(len(wave.batches))
                    reg.counter("bytes_streamed").inc(nb)
                    last = wave.index == W - 1
                    if last:
                        _reduce_and_solve(A_dev, B_dev, c_dev)
                    _save(it * wpi + W + wave.index + 1,
                          acc=None if last else (A_dev, B_dev, c_dev))
        finally:
            meter.free("acc")

    def _reduce_and_solve(A_dev, B_dev, c_dev):
        """Combine per-data-shard partials (paper Fig. 5b schedule), then
        each model shard solves and writes back its own theta rows."""
        traffic = topo_traffic
        with phase("als.reduce_partials", cat="reduce", tracer=tracer,
                   registry=reg, topology=topo_desc,
                   fast_bytes=traffic["fast_link_bytes"],
                   slow_bytes=traffic["slow_link_bytes"]):
            A = dreduce.topology_reduce(list(A_dev), topo, tracer=tracer)
            B = dreduce.topology_reduce(list(B_dev), topo, tracer=tracer)
            c = dreduce.topology_reduce(list(c_dev), topo, tracer=tracer)
        reg.counter("reduce_fast_bytes").inc(traffic["fast_link_bytes"])
        reg.counter("reduce_slow_bytes").inc(traffic["slow_link_bytes"])
        meter.alloc("theta_out", n * f * 4 // p)
        npp = n // p
        for k in range(p):
            lo, hi = k * npp, (k + 1) * npp
            th_k = solve_acc_fn(jnp.asarray(A[lo:hi], jnp.float32),
                                jnp.asarray(B[lo:hi], jnp.float32),
                                jnp.asarray(c[lo:hi], jnp.float32))
            factors.write_shard("theta", k, p, np.asarray(th_k))
        meter.free("theta_out")

    x_half = (_x_half_mesh if mesh is not None
              else _x_half_binned if binned else _x_half)
    theta_half = (_theta_half_mesh_binned if mesh is not None and binned
                  else _theta_half_mesh if mesh is not None
                  else _theta_half_binned if binned else _theta_half)

    # ------------------------------------------------------------------
    # Plan side of the ledger: per-wave predictions summed over exactly the
    # waves this run will execute (resume-aware), before any wave streams.
    pstats = predicted_stream_stats(ratings, sched, f)
    pred = {"bytes": 0, "slots": 0, "nnz": 0, "reduces": 0,
            "x_slots": 0, "x_nnz": 0, "t_slots": 0, "t_nnz": 0}

    def _predict_iteration(r: int):
        for wi in range(r if r < W else W, W):          # solve-X half
            pred["bytes"] += pstats["x_bytes"][wi]
            pred["slots"] += pstats["x_slots"][wi]
            pred["nnz"] += pstats["x_nnz"][wi]
            pred["x_slots"] += pstats["x_slots"][wi]
            pred["x_nnz"] += pstats["x_nnz"][wi]
        for wi in range(max(0, r - W), W):              # accumulate-Theta
            pred["bytes"] += pstats["t_bytes"][wi]
            pred["slots"] += pstats["t_slots"][wi]
            pred["nnz"] += pstats["t_nnz"][wi]
            pred["t_slots"] += pstats["t_slots"][wi]
            pred["t_nnz"] += pstats["t_nnz"][wi]
        if mesh is not None:
            pred["reduces"] += 1         # one Fig. 5b reduce per theta half

    # ------------------------------------------------------------------
    history: List[dict] = []
    it0 = start_step // wpi
    with phase("als.stream", cat="driver", tracer=tracer, registry=reg,
               iterations=cfg.iters, waves=W, topology=topo_desc):
        for it in range(it0, cfg.iters):
            resume_here = it == it0
            r = start_step % wpi if resume_here else 0
            _predict_iteration(r)
            ph0 = reg.phase_seconds()
            with phase("als.iteration", cat="iteration", tracer=tracer,
                       registry=reg, iteration=it + 1):
                if r < W:
                    with phase("als.solve_x_half", cat="half",
                               tracer=tracer, registry=reg,
                               iteration=it + 1):
                        x_half(it, first_wave=r)
                if r < wpi:
                    with phase("als.accumulate_theta_half", cat="half",
                               tracer=tracer, registry=reg,
                               iteration=it + 1):
                        theta_half(it, first_wave=max(0, r - W),
                                   acc0=acc_restored if resume_here
                                   else None)
            ph1 = reg.phase_seconds()
            rec = {"iteration": it + 1,
                   "waves_run": int(reg.counter("waves_run").value),
                   "peak_bytes": meter.peak_bytes,
                   "phase_seconds": {
                       cat: s - ph0.get(cat, 0.0)
                       for cat, s in ph1.items()
                       if s - ph0.get(cat, 0.0) > 0.0}}
            if train_eval is not None or test_eval is not None:
                x_dev = jnp.asarray(factors.x[:ratings.m])
                t_dev = jnp.asarray(factors.theta)
                if test_eval is not None:
                    rec["test_rmse"] = float(
                        rmse_padded(x_dev, t_dev, *test_eval))
                if train_eval is not None:
                    rec["train_rmse"] = float(
                        rmse_padded(x_dev, t_dev, *train_eval))
            history.append(rec)
            if callback is not None:
                callback(it, rec)
        if mgr is not None:
            mgr.wait()
    reg.gauge("peak_bytes").set(meter.peak_bytes)

    # ------------------------------------------------------------------
    # Close the loop: every prediction the planner/schedule/budget layer
    # made for this run, confronted with what the meters measured.
    led = Ledger(solver="als", mesh=mesh is not None, p=p,
                 n_data=n_data, waves=W, iterations=cfg.iters - it0,
                 f=f, m_pad=m_pad, n=n, mode=cfg.mode, n_bins=n_bins,
                 resumed_from_step=start_step, topology=topo_desc,
                 autotune=getattr(ratings, "tune", None),
                 phase_seconds=reg.phase_seconds())
    led.record("peak_device_bytes", sched.capacity_bytes, meter.peak_bytes,
               unit="bytes", check="le")
    led.record("modeled_peak_bytes",
               required_capacity_bytes(ratings, sched, f,
                                       prefetch_depth=prefetch_depth),
               meter.peak_bytes, unit="bytes", check="le")
    meas_slots = int(reg.counter("padded_slots").value)
    meas_nnz = int(reg.counter("nnz_streamed").value)
    led.record("bytes_streamed", pred["bytes"],
               int(reg.counter("bytes_streamed").value), unit="bytes")
    led.record("padded_slots", pred["slots"], meas_slots, unit="slots")
    led.record("nnz_streamed", pred["nnz"], meas_nnz, unit="ratings")
    led.record("fill_waste_ratio",
               pred["slots"] / pred["nnz"] if pred["nnz"] else 0.0,
               meas_slots / meas_nnz if meas_nnz else 0.0,
               unit="ratio", check="rel", rel_tol=1e-9)
    led.record("worst_fill_bound", ratings.worst_fill,
               meas_slots / meas_nnz if meas_nnz else 0.0,
               unit="ratio", check="le")
    # per-half fill attribution: each streamed orientation pays only its own
    # padding (ISSUE 9 satellite — the old worst_fill max smeared them)
    mxs = int(reg.counter("x_padded_slots").value)
    mxn = int(reg.counter("x_nnz_streamed").value)
    mts = int(reg.counter("t_padded_slots").value)
    mtn = int(reg.counter("t_nnz_streamed").value)
    led.record("fill/solve_x",
               pred["x_slots"] / pred["x_nnz"] if pred["x_nnz"] else 0.0,
               mxs / mxn if mxn else 0.0,
               unit="ratio", check="rel", rel_tol=1e-9)
    led.record("fill/accumulate_theta",
               pred["t_slots"] / pred["t_nnz"] if pred["t_nnz"] else 0.0,
               mts / mtn if mtn else 0.0,
               unit="ratio", check="rel", rel_tol=1e-9)
    for comp, fb in ratings.fill_breakdown().items():
        led.record(f"fill_bound/{comp}", ratings.worst_fill, fb,
                   unit="ratio", check="le")
    if mesh is not None:
        led.record("reduce_fast_bytes",
                   pred["reduces"] * topo_traffic["fast_link_bytes"],
                   int(reg.counter("reduce_fast_bytes").value), unit="bytes")
        led.record("reduce_slow_bytes",
                   pred["reduces"] * topo_traffic["slow_link_bytes"],
                   int(reg.counter("reduce_slow_bytes").value), unit="bytes")
    F = -(-f // cfg.f_mult) * cfg.f_mult
    led.record("vmem/fused_herm_pallas",
               BUDGETS["fused_herm_pallas"].vmem_limit,
               footprint_bytes("fused_herm_pallas",
                               tm=cfg.tm, tk=cfg.tk, F=F),
               unit="bytes", check="le", mode=cfg.mode)
    led.record("vmem/batch_solve_pallas",
               BUDGETS["batch_solve_pallas"].vmem_limit,
               footprint_bytes("batch_solve_pallas", tb=cfg.tb, F=F),
               unit="bytes", check="le", mode=cfg.mode)

    return factors, history, StreamTelemetry.from_registry(
        reg, capacity_bytes=sched.capacity_bytes, topology=topo_desc,
        ledger=led.to_obj())
