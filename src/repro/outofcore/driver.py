"""Streaming ALS driver: execute a wave schedule end to end (§4.4).

Per iteration the driver runs the two halves of the schedule:

- **solve-X**: Theta resident on device; each wave's R row-slice is double-
  buffered host->device through ``data.prefetch.Prefetcher`` while the
  current wave solves its X rows (``core.als.update_rows``); solved slices
  are written straight back to the host ``FactorStore``.
- **accumulate-Theta**: A/B Hermitian accumulators resident; each wave
  streams its batches' R^T column shards together with the freshly solved X
  slices (``core.als.partial_herm``), and after the last wave the
  accumulated systems are solved (``core.als.solve_accumulated``).

Every wave completion checkpoints the full resumable state (factors +
accumulators + global step) through ``checkpoint.CheckpointManager``, so a
killed run restarts mid-iteration — the paper's §4.4 fault tolerance at wave
rather than iteration granularity.

A ``MemoryMeter`` tracks the *simulated device* footprint: the meter models
one device of the ``n_data`` axis (wave payloads are divided by ``n_data``;
replicated residents — the fixed factor, the accumulators — are counted in
full), which is what the planner's eq. (8) budget prices.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import als as als_mod
from repro.core.objective import rmse_padded
from repro.data.prefetch import Prefetcher
from repro.outofcore.runtime import (MemoryMeter, SimulatedFailure,
                                     StreamTelemetry, WaveCheckpointer)
from repro.outofcore.schedule import IterationSchedule
from repro.outofcore.store import FactorStore, RatingStore, triplet_nbytes

__all__ = ["MemoryMeter", "SimulatedFailure", "StreamTelemetry",
           "run_streaming_als"]


def _zeros_ckpt_tree(m_pad: int, n: int, f: int) -> dict:
    return {
        "x": np.zeros((m_pad, f), np.float32),
        "theta": np.zeros((n, f), np.float32),
        "a_acc": np.zeros((n, f, f), np.float32),
        "b_acc": np.zeros((n, f), np.float32),
        "c_acc": np.zeros((n,), np.float32),
    }


def run_streaming_als(
    ratings: RatingStore,
    sched: IterationSchedule,
    cfg: als_mod.AlsConfig,
    *,
    factors: Optional[FactorStore] = None,
    ckpt_dir: Optional[str] = None,
    keep: int = 3,
    prefetch_depth: int = 2,
    train_eval=None,                 # (idx, val, cnt) for per-iteration RMSE
    test_eval=None,
    fail_after_waves: Optional[int] = None,
    update_rows_fn: Optional[Callable] = None,
    partial_herm_fn: Optional[Callable] = None,
    solve_acc_fn: Optional[Callable] = None,
    callback=None,
) -> tuple[FactorStore, List[dict], StreamTelemetry]:
    """Run ``cfg.iters`` streaming ALS iterations of ``sched`` over ``ratings``.

    Returns (factor store, per-iteration history, telemetry).  With
    ``ckpt_dir`` set the run resumes from the latest committed wave; the
    ``*_fn`` hooks default to the in-process ``core.als`` entry points and
    accept e.g. ``distributed.su_als.make_wave_update_fn`` on a real mesh.
    """
    assert ratings.m_pad == sched.m_pad and ratings.n == sched.n, \
        "RatingStore and IterationSchedule were built for different shapes"
    f = cfg.f
    m_pad, n, n_data = sched.m_pad, sched.n, sched.n_data
    W = len(sched.waves)
    wpi = sched.waves_per_iteration            # 2 * W checkpoint steps/iter
    update_rows_fn = update_rows_fn or (
        lambda fixed, i, v, c: als_mod.update_rows(fixed, i, v, c, cfg))
    partial_herm_fn = partial_herm_fn or (
        lambda xb, i, v, c: als_mod.partial_herm(xb, i, v, c, cfg))
    solve_acc_fn = solve_acc_fn or (
        lambda A, B, c: als_mod.solve_accumulated(A, B, c, cfg))

    meter = MemoryMeter()
    tel = StreamTelemetry(capacity_bytes=sched.capacity_bytes)
    t_start = time.perf_counter()

    mgr = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None
    acc_restored = None
    start_step = 0
    if mgr is not None:
        tree, start_step = mgr.restore_or_init(
            _zeros_ckpt_tree(m_pad, n, f), lambda: None)
        if start_step:
            factors = FactorStore.from_arrays(tree["x"], tree["theta"])
            if start_step % wpi > W:       # killed mid-accumulate-Theta
                acc_restored = (tree["a_acc"], tree["b_acc"], tree["c_acc"])
    tel.resumed_from_step = start_step
    if factors is None:
        st = als_mod.als_init(ratings.m, n, cfg)
        x0 = np.zeros((m_pad, f), np.float32)
        x0[:ratings.m] = np.asarray(st.x)
        factors = FactorStore.from_arrays(x0, np.asarray(st.theta))

    ckpt = WaveCheckpointer(mgr, fail_after_waves)

    def _save(step: int, acc=None):
        def tree_fn():
            tree = _zeros_ckpt_tree(m_pad, n, f)
            # snapshot copies: the manager commits async while later waves
            # keep mutating the live factor arrays
            tree["x"], tree["theta"] = factors.x.copy(), factors.theta.copy()
            if acc is not None:
                tree["a_acc"] = np.asarray(acc[0])
                tree["b_acc"] = np.asarray(acc[1])
                tree["c_acc"] = np.asarray(acc[2])
            return tree
        ckpt.save(step, tree_fn)

    # ------------------------------------------------------------------
    # solve-X half: stream R row slices, solve rows, write back.
    # ------------------------------------------------------------------
    def _x_half(it: int, first_wave: int):
        theta_dev = jnp.asarray(factors.theta)
        meter.alloc("fixed_theta", factors.theta.nbytes)
        scratch = (sched.waves[0].rows * (f * f + 2 * f) * 4) // n_data

        def gen():
            for wave in sched.waves[first_wave:]:
                yield wave, ratings.x_slice_triplet(
                    wave.row_start, wave.row_stop)

        def put(item):
            wave, trip = item
            nb = triplet_nbytes(trip)
            # per-device share: each device on the axis takes ONE batch of
            # the wave (a ragged last wave has fewer batches than n_data)
            meter.alloc(f"xwave{wave.index}", nb // len(wave.batches))
            dev = tuple(jnp.asarray(a) for a in trip)
            return wave, dev, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put) as pf:
                for wave, (idx, val, cnt), nb in pf:
                    meter.alloc("x_scratch", scratch)
                    rows = np.asarray(update_rows_fn(theta_dev, idx, val, cnt))
                    meter.free("x_scratch")
                    factors.write_slice("x", wave.row_start, wave.row_stop,
                                        rows)
                    meter.free(f"xwave{wave.index}")
                    tel.waves_run += 1
                    tel.batches_loaded += len(wave.batches)
                    tel.bytes_streamed += nb
                    _save(it * wpi + wave.index + 1)
        finally:
            meter.free("fixed_theta")

    # ------------------------------------------------------------------
    # accumulate-Theta half: stream R^T shards + X slices, accumulate,
    # solve after the last wave.
    # ------------------------------------------------------------------
    def _theta_half(it: int, first_wave: int, acc0=None):
        acc_bytes = n * (f * f + f + 1) * 4
        meter.alloc("acc", acc_bytes)
        if acc0 is not None:
            A = jnp.asarray(acc0[0], jnp.float32)
            B = jnp.asarray(acc0[1], jnp.float32)
            c = jnp.asarray(acc0[2], jnp.float32)
        else:
            A = jnp.zeros((n, f, f), jnp.float32)
            B = jnp.zeros((n, f), jnp.float32)
            c = jnp.zeros((n,), jnp.float32)

        def gen():
            for wave in sched.waves[first_wave:]:
                payload = [
                    (b, ratings.theta_batch_triplet(b.index),
                     factors.read_slice("x", b.row_start, b.row_stop))
                    for b in wave.batches]
                yield wave, payload

        def put(item):
            wave, payload = item
            nb = sum(triplet_nbytes(t) + x.nbytes for _, t, x in payload)
            # each simulated device holds ONE batch's shard + X slice
            meter.alloc(f"twave{wave.index}", nb // len(payload))
            dev = [(b, tuple(jnp.asarray(a) for a in t), jnp.asarray(x))
                   for b, t, x in payload]
            return wave, dev, nb

        try:
            with Prefetcher(gen(), depth=prefetch_depth, put=put) as pf:
                for wave, payload, nb in pf:
                    for _, (idx, val, cnt), x_dev in payload:
                        Aj, Bj = partial_herm_fn(x_dev, idx, val, cnt)
                        A = A + Aj
                        B = B + Bj
                        c = c + cnt.astype(jnp.float32)
                    meter.free(f"twave{wave.index}")
                    tel.waves_run += 1
                    tel.batches_loaded += len(payload)
                    tel.bytes_streamed += nb
                    last = wave.index == W - 1
                    if last:
                        meter.alloc("theta_out", n * f * 4)
                        factors.write_slice(
                            "theta", 0, n, np.asarray(solve_acc_fn(A, B, c)))
                        meter.free("theta_out")
                    _save(it * wpi + W + wave.index + 1,
                          acc=None if last else (A, B, c))
        finally:
            meter.free("acc")

    # ------------------------------------------------------------------
    history: List[dict] = []
    it0 = start_step // wpi
    for it in range(it0, cfg.iters):
        resume_here = it == it0
        r = start_step % wpi if resume_here else 0
        if r < W:
            _x_half(it, first_wave=r)
        if r < wpi:
            _theta_half(it, first_wave=max(0, r - W),
                        acc0=acc_restored if resume_here else None)
        rec = {"iteration": it + 1, "waves_run": tel.waves_run,
               "peak_bytes": meter.peak_bytes}
        if train_eval is not None or test_eval is not None:
            x_dev = jnp.asarray(factors.x[:ratings.m])
            t_dev = jnp.asarray(factors.theta)
            if test_eval is not None:
                rec["test_rmse"] = float(rmse_padded(x_dev, t_dev, *test_eval))
            if train_eval is not None:
                rec["train_rmse"] = float(
                    rmse_padded(x_dev, t_dev, *train_eval))
        history.append(rec)
        if callback is not None:
            callback(it, rec)
    if mgr is not None:
        mgr.wait()
    tel.peak_bytes = meter.peak_bytes
    tel.wall_seconds = time.perf_counter() - t_start
    return factors, history, tel
