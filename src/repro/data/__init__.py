"""Data pipelines: LM token batches + rating-matrix streaming with prefetch."""

from repro.data.tokens import TokenDataset, synthetic_lm_batches
from repro.data.prefetch import Prefetcher

__all__ = ["TokenDataset", "synthetic_lm_batches", "Prefetcher"]
