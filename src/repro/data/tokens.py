"""LM token pipeline: deterministic synthetic streams + packed file-backed
datasets.

Synthetic batches are a seeded Zipf-ish unigram stream with local n-gram
structure (so losses actually go down during example training runs and
convergence is assertable in tests); the file-backed path memory-maps a
flat uint16/uint32 token file and yields packed (tokens, labels, mask)
triples — the production entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenDataset:
    """Memory-mapped flat token file, packed into fixed-length rows."""
    path: str
    seq_len: int
    vocab: int
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")

    def __len__(self):
        return (len(self._data) - 1) // self.seq_len

    def batches(self, batch: int, *, seed: int = 0,
                host_id: int = 0, n_hosts: int = 1) -> Iterator[dict]:
        """Shuffled, host-sharded epoch iterator (each host reads only its
        1/n_hosts row subset — no cross-host data traffic)."""
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self))[host_id::n_hosts]
        for lo in range(0, len(order) - batch + 1, batch):
            rows = order[lo:lo + batch]
            tok = np.stack([
                self._data[r * self.seq_len: r * self.seq_len + self.seq_len + 1]
                for r in rows]).astype(np.int32)
            yield {
                "tokens": tok[:, :-1] % self.vocab,
                "labels": tok[:, 1:] % self.vocab,
                "mask": np.ones((batch, self.seq_len), np.float32),
            }


def synthetic_lm_batches(vocab: int, seq_len: int, batch: int, *,
                         seed: int = 0, order: int = 2) -> Iterator[dict]:
    """Infinite synthetic stream with learnable order-``order`` structure:
    token_{t} = (a * token_{t-1} + b * token_{t-order} + noise) mod vocab.
    A model that learns the linear rule drops well below the unigram
    entropy — used by example trainers and convergence tests."""
    rng = np.random.default_rng(seed)
    a, b = 31, 17
    while True:
        tok = np.zeros((batch, seq_len + 1), np.int64)
        tok[:, :order] = rng.integers(0, vocab, (batch, order))
        noise = (rng.random((batch, seq_len + 1)) < 0.1)
        for t in range(order, seq_len + 1):
            nxt = (a * tok[:, t - 1] + b * tok[:, t - order]) % vocab
            rnd = rng.integers(0, vocab, batch)
            tok[:, t] = np.where(noise[:, t], rnd, nxt)
        yield {
            "tokens": tok[:, :-1].astype(np.int32),
            "labels": tok[:, 1:].astype(np.int32),
            "mask": np.ones((batch, seq_len), np.float32),
        }
