"""Host->device prefetch: the paper's out-of-core streaming, JAX-style.

cuMF (§4.4 'Out-of-core computation') plans partitions ahead of time, then
uses CPU threads + CUDA streams to preload the next q-batch while the
current one computes, hiding load time "except for the first load".  The
JAX equivalent: a background thread calls ``jax.device_put`` (async on TPU)
``depth`` batches ahead; dispatching the next step's computation overlaps
its transfer with the current step's compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class Prefetcher:
    def __init__(self, it: Iterator, *, depth: int = 2,
                 put: Optional[Callable] = None):
        self._it = it
        self._put = put or (lambda x: jax.tree.map(jax.device_put, x))
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for item in self._it:
                self._q.put(self._put(item))   # device_put is async: the
        except BaseException as e:             # transfer runs while compute
            self._q.put(e)                     # proceeds on earlier batches
            return
        self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        if isinstance(item, BaseException):
            raise item
        return item
