"""Host->device prefetch: the paper's out-of-core streaming, JAX-style.

cuMF (§4.4 'Out-of-core computation') plans partitions ahead of time, then
uses CPU threads + CUDA streams to preload the next q-batch while the
current one computes, hiding load time "except for the first load".  The
JAX equivalent: a background thread calls ``jax.device_put`` (async on TPU)
``depth`` batches ahead; dispatching the next step's computation overlaps
its transfer with the current step's compute.

Lifecycle: a consumer that abandons iteration early (break, exception, a
wave driver resuming past the end of a half) must call ``close()`` — or use
the prefetcher as a context manager — otherwise the worker thread would sit
blocked forever on a full queue.  ``close()`` wakes a blocked worker, drains
the queue, and joins the thread; it is idempotent and safe after normal
exhaustion.

Observability (``tracer=`` / ``registry=``): the worker thread records one
``prefetch_load`` span per item around the ``put`` transform (the actual
load + device_put work, on its own named thread track), and the consumer
records one ``prefetch`` span per ``__next__`` around the queue wait — the
time compute actually stalled on streaming.  A well-hidden pipeline shows
long ``prefetch_load`` spans and near-zero ``prefetch`` spans; the inverse
means the budget or depth is wrong.  The registry additionally counts
``prefetch/items`` and samples queue depth at each hand-off.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax

from repro.obs.trace import phase

_POLL_S = 0.05


class Prefetcher:
    def __init__(self, it: Iterator, *, depth: int = 2,
                 put: Optional[Callable] = None,
                 tracer=None, registry=None):
        self._it = it
        self._put = put or (lambda x: jax.tree.map(jax.device_put, x))
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._stop = threading.Event()
        self._tracer = tracer
        self._registry = registry
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="prefetch-worker")
        self._thread.start()

    def _offer(self, item) -> bool:
        """put() that a concurrent close() can interrupt; False if stopped."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                with phase("prefetch.load", cat="prefetch_load",
                           tracer=self._tracer, registry=self._registry):
                    loaded = self._put(item)      # device_put is async: the
                if not self._offer(loaded):       # transfer runs while
                    return                        # compute proceeds on
        except BaseException as e:                # earlier batches
            self._offer(e)
            return
        self._offer(self._done)

    def close(self):
        """Stop the worker, drain queued items, join the thread (idempotent)."""
        self._stop.set()
        while self._thread.is_alive():
            try:
                self._q.get_nowait()     # unblock a worker stuck in _offer
            except queue.Empty:
                pass
            self._thread.join(timeout=_POLL_S)

    @property
    def closed(self) -> bool:
        return self._stop.is_set() and not self._thread.is_alive()

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        with phase("prefetch.wait", cat="prefetch",
                   tracer=self._tracer, registry=self._registry):
            item = self._q.get()
        if self._registry is not None:
            self._registry.gauge("prefetch/queue_depth").set(
                self._q.qsize())
            if not (item is self._done or isinstance(item, BaseException)):
                self._registry.counter("prefetch/items").inc()
        if item is self._done:
            raise StopIteration
        if isinstance(item, BaseException):
            raise item
        return item
