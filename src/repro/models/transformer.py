"""Decoder assembly: params, scan-over-layers forward, KV/recurrent caches.

Layer stacking: ``block_pattern`` is cycled over ``n_layers`` and grouped
into scan "periods" (e.g. recurrentgemma's (rglru, rglru, attn) -> 8
scanned periods + a 2-layer tail group), so HLO size stays O(pattern), not
O(layers), at 512-way SPMD.

Modes:
- train   : full-sequence forward, all-position logits (for the loss).
- prefill : full-sequence forward, last-position logits + caches.
- decode  : one token per call against the caches.

Caches are pytrees stacked over the scan dimension.  "No cache" is the
empty dict (scan-friendly).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.flash_decode import flash_decode
from repro.distributed.sharding import constrain, dp_axes
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def layer_pattern(cfg: ModelConfig) -> tuple[str, ...]:
    return (cfg.block_pattern * cfg.n_layers)[: cfg.n_layers]


def scan_groups(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(pattern, repeat)] — full periods then the remainder tail."""
    period = len(cfg.block_pattern)
    n_full, rem = divmod(cfg.n_layers, period)
    groups = []
    if n_full:
        groups.append((tuple(cfg.block_pattern), n_full))
    if rem:
        groups.append((tuple(cfg.block_pattern[:rem]), 1))
    return groups


# ---------------------------------------------------------------------------
# parameter shapes (value = (shape, logical_axes))
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: ModelConfig):
    D, dh, KV = cfg.d_model, cfg.d_head, cfg.padded_kv
    H = cfg.padded_heads
    s = {
        "ln1": ((D,), ("norm",)),
        "ln2": ((D,), ("norm",)),
        "wq": ((D, H, dh), ("attn_din", "qheads", "head_dim")),
        "wk": ((D, KV, dh), ("attn_din", "kv_heads", "head_dim")),
        "wv": ((D, KV, dh), ("attn_din", "kv_heads", "head_dim")),
        "wo": ((H, dh, D), ("qheads", "head_dim", "attn_dout")),
    }
    if cfg.qkv_bias:
        s["bq"] = ((H, dh), ("qheads", "head_dim"))
        s["bk"] = ((KV, dh), ("kv_heads", "head_dim"))
        s["bv"] = ((KV, dh), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        s["qnorm"] = ((dh,), ("norm",))
        s["knorm"] = ((dh,), ("norm",))
    s.update(_mlp_shapes(cfg))
    return s


def _mlp_shapes(cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    if cfg.moe is not None:
        return {k: v for k, v in moe_mod.moe_param_shapes(D, F, cfg.moe).items()}
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": ((D, F), ("d_model_in", "ff")),
            "w_up": ((D, F), ("d_model_in", "ff")),
            "w_down": ((F, D), ("ff", "d_model_out")),
        }
    return {  # gelu
        "w_in": ((D, F), ("d_model_in", "ff")),
        "b_in": ((F,), ("ff",)),
        "w_out": ((F, D), ("ff", "d_model_out")),
        "b_out": ((D,), ("norm",)),
    }


def _rglru_shapes(cfg: ModelConfig):
    s = {"ln1": ((cfg.d_model,), ("norm",)),
         "ln2": ((cfg.d_model,), ("norm",))}
    s.update(rglru_mod.rglru_param_shapes(cfg.d_model, cfg.d_rnn or cfg.d_model))
    # recurrent blocks pair with the same MLP as attention blocks
    s.update(_mlp_shapes(cfg))
    return s


def _rwkv_shapes(cfg: ModelConfig):
    s = {"ln1": ((cfg.d_model,), ("norm",)),
         "ln2": ((cfg.d_model,), ("norm",))}
    s.update(rwkv_mod.rwkv_param_shapes(cfg.d_model, cfg.d_ff))
    return s


_BLOCK_SHAPES = {"attn": _attn_shapes, "rglru": _rglru_shapes, "rwkv": _rwkv_shapes}


def param_shapes(cfg: ModelConfig):
    """Full logical parameter tree: {name: (shape, logical_axes)}."""
    tree: dict[str, Any] = {
        "embed": ((cfg.padded_vocab, cfg.d_model), ("vocab", "embed_d")),
        "final_norm": ((cfg.d_model,), ("norm",)),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed_d"))
    blocks = []
    for pattern, repeat in scan_groups(cfg):
        grp = {}
        for pi, kind in enumerate(pattern):
            shapes = _BLOCK_SHAPES[kind](cfg)
            grp[str(pi)] = {
                k: ((repeat,) + shp, ("layers",) + axes)
                for k, (shp, axes) in shapes.items()
            }
        blocks.append(grp)
    tree["blocks"] = blocks
    return tree


def logical_axes_tree(cfg: ModelConfig):
    return jax.tree.map(lambda sa: sa[1], param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], tuple))


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))
    keys = jax.random.split(key, len(leaves))

    def make(k, leaf):
        shape, axes = leaf
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    arrs = [make(k, lf) for k, lf in zip(keys, leaves)]
    params = jax.tree.unflatten(treedef, arrs)

    # targeted re-inits for special leaves (norm scales zero, decays, biases)
    def fix(path, arr):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln1", "ln2", "final_norm", "qnorm", "knorm", "ln_w",
                    "b_in", "b_out", "bq", "bk", "bv", "ln_b"):
            return jnp.zeros_like(arr)
        if name.startswith("mu_"):
            return jnp.full_like(arr, 0.5)
        if name == "w0":
            return jnp.full_like(arr, -6.0)
        if name == "u":
            return jnp.zeros_like(arr)
        if name == "lam":
            k = jax.random.fold_in(key, hash(name) % (1 << 30))
            un = jax.random.uniform(k, arr.shape, jnp.float32, 0.9, 0.999)
            a = -jnp.log(un) / rglru_mod.C_SCALE
            return jnp.log(jnp.expm1(jnp.maximum(a, 1e-6))).astype(arr.dtype)
        return arr
    params = jax.tree_util.tree_map_with_path(fix, params)

    # zero the padded head slices so padding is exact identity
    if cfg.padded_heads != cfg.n_heads:
        hmask = (jnp.arange(cfg.padded_heads) < cfg.n_heads).astype(dtype)
        def zero_pad(path, arr):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("wq", "bq"):
                return arr * hmask[..., :, None].astype(arr.dtype)
            if name == "wo":
                return arr * hmask[..., :, None, None].astype(arr.dtype)
            return arr
        params = jax.tree_util.tree_map_with_path(zero_pad, params)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _block_cache_shape(cfg: ModelConfig, kind: str, batch: int, smax: int,
                       dtype):
    D, dh, KV = cfg.d_model, cfg.d_head, cfg.padded_kv
    if kind == "attn":
        w = cfg.sliding_window
        slots = min(w, smax) if w else smax
        c = {"k": jnp.zeros((batch, slots, KV, dh), dtype),
             "v": jnp.zeros((batch, slots, KV, dh), dtype)}
        if w:
            c["pos"] = jnp.full((batch, slots), -1, jnp.int32)
        return c
    if kind == "rglru":
        R = cfg.d_rnn or D
        return {"conv": jnp.zeros((batch, 3, R), dtype),
                "h": jnp.zeros((batch, R), jnp.float32)}
    if kind == "rwkv":
        H = D // rwkv_mod.HEAD_DIM
        return {"s": jnp.zeros((batch, H, rwkv_mod.HEAD_DIM, rwkv_mod.HEAD_DIM),
                               jnp.float32),
                "x_prev_t": jnp.zeros((batch, D), dtype),
                "x_prev_c": jnp.zeros((batch, D), dtype)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, smax: int, dtype=jnp.bfloat16,
               stacked: bool = True):
    """``stacked=True``: leaves carry a leading layer dim (scan layout,
    produced by prefill).  ``stacked=False``: one subtree per layer (decode
    layout — donation then aliases every per-layer buffer in place, where a
    stacked buffer chain defeats XLA aliasing and doubles cache memory)."""
    groups = []
    for pattern, repeat in scan_groups(cfg):
        if stacked:
            grp = {}
            for pi, kind in enumerate(pattern):
                one = _block_cache_shape(cfg, kind, batch, smax, dtype)
                grp[str(pi)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (repeat,) + a.shape),
                    one)
            groups.append(grp)
        else:
            groups.append([
                {str(pi): _block_cache_shape(cfg, kind, batch, smax, dtype)
                 for pi, kind in enumerate(pattern)}
                for _ in range(repeat)])
    return {"blocks": groups}


def unstack_cache(cfg: ModelConfig, cache):
    """Stacked (prefill) cache -> per-layer (decode) layout."""
    groups = []
    for gi, (pattern, repeat) in enumerate(scan_groups(cfg)):
        gc = cache["blocks"][gi]
        groups.append([
            jax.tree.map(lambda a: a[li], gc) for li in range(repeat)])
    return {"blocks": groups}


# ---------------------------------------------------------------------------
# block forwards
# ---------------------------------------------------------------------------

def _mlp_forward(cfg: ModelConfig, p, x, mesh, mode="train"):
    if cfg.moe is not None:
        fsdp = (mode == "train" and mesh is not None
                and "data" in mesh.axis_names)
        return moe_mod.moe_ffn(p, x, cfg.moe, mesh=mesh, fsdp_gather=fsdp)
    if cfg.mlp == "swiglu":
        return L.swiglu_mlp(x, p["w_gate"].astype(x.dtype),
                            p["w_up"].astype(x.dtype),
                            p["w_down"].astype(x.dtype))
    if cfg.mlp == "geglu":
        return L.geglu_mlp(x, p["w_gate"].astype(x.dtype),
                           p["w_up"].astype(x.dtype),
                           p["w_down"].astype(x.dtype))
    return L.gelu_mlp(x, p["w_in"].astype(x.dtype), p["b_in"].astype(x.dtype),
                      p["w_out"].astype(x.dtype), p["b_out"].astype(x.dtype))


def _attn_forward(cfg, p, x, positions, cache, *, mode, mesh, lengths,
                  serve_seq_shard, causal_skip, chunk_q, chunk_kv):
    B, S, D = x.shape
    dt = x.dtype
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", xn, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dke->bske", xn, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", xn, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)[None, None]
        k = k + p["bk"].astype(dt)[None, None]
        v = v + p["bv"].astype(dt)[None, None]
    if cfg.qk_norm:
        q = L.rms_norm(q, p["qnorm"], cfg.norm_eps)
        k = L.rms_norm(k, p["knorm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window
    new_cache = {}
    if mode in ("train", "prefill"):
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.padded_heads % mesh.shape["model"] == 0:
            # keep q/k/v head-sharded through the attention math — the
            # serve policy replicates projection weights, and without this
            # constraint a 32k prefill materializes multi-GiB full-head
            # q/k/v per device
            dpn = 1
            for a in dp_axes(mesh):
                dpn *= mesh.shape[a]
            dpx = dp_axes(mesh) if q.shape[0] % max(dpn, 1) == 0 else None
            q = constrain(q, mesh, dpx, None, "model", None)
            if cfg.padded_kv % mesh.shape["model"] == 0:
                k = constrain(k, mesh, dpx, None, "model", None)
                v = constrain(v, mesh, dpx, None, "model", None)
        if S <= max(chunk_q, 256):
            out = L.attention_full(q, k, v, causal=True, window=window)
        else:
            out = L.attention_chunked(
                q, k, v, causal=True, window=window,
                chunk_q=chunk_q, chunk_kv=chunk_kv, causal_skip=causal_skip)
        if mode == "prefill":
            if window:
                # ring-buffer invariant: global position p lives in slot
                # p % slots, so later decode writes replace the oldest entry
                slots = min(window, S)
                shift = S % slots
                new_cache = {
                    "k": jnp.roll(k[:, -slots:], shift, axis=1),
                    "v": jnp.roll(v[:, -slots:], shift, axis=1),
                    "pos": jnp.roll(positions[:, -slots:].astype(jnp.int32),
                                    shift, axis=1),
                }
            else:
                new_cache = {"k": k, "v": v}
    else:  # decode: S == 1
        kc, vc = cache["k"].astype(dt), cache["v"].astype(dt)
        slots = kc.shape[1]
        bidx = jnp.arange(B)
        if window:
            slot = (lengths % slots).astype(jnp.int32)
            kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
            posbuf = cache["pos"].at[bidx, slot].set(lengths.astype(jnp.int32))
            new_cache = {"k": kc, "v": vc, "pos": posbuf}
            out1 = _decode_ring(q[:, 0], kc, vc, posbuf, lengths)
        elif mesh is None:
            bidx2 = jnp.arange(B)
            kc = kc.at[bidx2, lengths].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[bidx2, lengths].set(v[:, 0].astype(vc.dtype))
            new_cache = {"k": kc, "v": vc}
            out1 = L.attention_decode(q[:, 0], kc, vc, lengths + 1)
        else:
            # fused cache-write + attention in ONE shard_map region: a
            # GSPMD dynamic scatter would replicate the whole cache, and
            # separate regions each materialize a cache copy per layer
            from repro.distributed.flash_decode import flash_decode_update
            tp_ok = "model" in mesh.axis_names
            seq_axis = "model" if (serve_seq_shard and tp_ok) else None
            kv_axis = ("model" if (tp_ok and not serve_seq_shard and
                                   cfg.padded_kv % mesh.shape["model"] == 0)
                       else None)
            axes = dp_axes(mesh)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            dpd = axes if (axes and B % n == 0) else None
            out1, kc, vc = flash_decode_update(
                q[:, 0], kc, vc, k[:, 0], v[:, 0], lengths,
                mesh=mesh, dp=dpd, seq_axis=seq_axis, kv_axis=kv_axis)
            new_cache = {"k": kc, "v": vc}
        out = out1[:, None]

    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt))
    x = x + o
    xn2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _mlp_forward(cfg, p, xn2, mesh, mode), new_cache


def _decode_ring(q, kc, vc, posbuf, lengths):
    """Decode attention over a ring (sliding-window) cache with explicit
    per-slot global positions."""
    b, h, dh = q.shape
    kv = kc.shape[2]
    qg = q.reshape(b, kv, h // kv, dh)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, kc,
                    preferred_element_type=jnp.float32) * (dh ** -0.5)
    msk = (posbuf >= 0) & (posbuf <= lengths[:, None])
    sc = jnp.where(msk[:, None, None, :], sc, L.NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", pr.astype(vc.dtype), vc)
    return out.reshape(b, h, dh)


def _rglru_forward(cfg, p, x, positions, cache, *, mode, mesh, lengths, **_):
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    branch_cache = None if mode in ("train", "prefill") else \
        {"conv": cache["conv"], "h": cache["h"]}
    y, nc = rglru_mod.recurrent_branch(
        {k: p[k] for k in ("w_in_rnn", "w_in_gate", "conv", "w_a", "w_x",
                           "lam", "w_out")},
        xn, cache=branch_cache)
    x = x + y
    xn2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp_forward(cfg, p, xn2, mesh, mode)
    new_cache = nc if mode in ("prefill", "decode") else {}
    return x, new_cache


def _rwkv_forward(cfg, p, x, positions, cache, *, mode, mesh, lengths, **_):
    xn = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    tcache = None if mode in ("train", "prefill") else \
        {"s": cache["s"], "x_prev": cache["x_prev_t"]}
    y, ntc = rwkv_mod.time_mix(p, xn, cache=tcache)
    x = x + y
    xn2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    ccache = None if mode in ("train", "prefill") else \
        {"x_prev": cache["x_prev_c"]}
    y2, ncc = rwkv_mod.channel_mix(p, xn2, cache=ccache)
    x = x + y2
    if mode == "train":
        return x, {}
    return x, {"s": ntc["s"], "x_prev_t": ntc["x_prev"],
               "x_prev_c": ncc["x_prev"]}


_BLOCK_FWD = {"attn": _attn_forward, "rglru": _rglru_forward,
              "rwkv": _rwkv_forward}


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params,
    batch: dict,
    *,
    mode: str,                    # train | prefill | decode
    mesh=None,
    cache=None,
    lengths: Optional[jax.Array] = None,
    remat: bool = True,
    causal_skip: bool = False,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    serve_seq_shard: bool = False,
    compute_dtype=jnp.bfloat16,
    return_hidden: bool = False,
):
    """Returns (logits, new_cache).  logits: [B, S, V] for train,
    [B, 1, V] for prefill (last position) and decode.
    ``return_hidden`` skips the output projection and returns the final
    hidden states instead (used by the fused chunked loss)."""
    dp = dp_axes(mesh) if mesh is not None else None

    if "embeds" in batch:
        x = batch["embeds"].astype(compute_dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if mesh is not None:
        x = constrain(x, mesh, dp, None, None)

    if mode == "decode":
        assert lengths is not None
        positions = lengths[:, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    new_groups = []
    for gi, (pattern, repeat) in enumerate(scan_groups(cfg)):
        gp = params["blocks"][gi]
        gc = cache["blocks"][gi] if cache is not None else {
            k: {} for k in gp}

        # sequence parallelism on the residual stream (Megatron-SP): the
        # inter-block x is sharded over "model" on the sequence dim, so the
        # remat-saved per-layer carries shrink by tp (8.8 GiB -> 0.55 GiB
        # for mistral-large train) and the TP all-reduce splits into
        # reduce-scatter + all-gather (same wire bytes).
        seq_shard = (mesh is not None and "model" in mesh.axis_names
                     and mode in ("train", "prefill")
                     and x.shape[1] % mesh.shape["model"] == 0)

        def body(xc, per_layer, pattern=pattern):
            lp, lc = per_layer
            newc = {}
            for pi, kind in enumerate(pattern):
                xc, nc = _BLOCK_FWD[kind](
                    cfg, lp[str(pi)], xc, positions,
                    lc.get(str(pi)) or None,
                    mode=mode, mesh=mesh, lengths=lengths,
                    serve_seq_shard=serve_seq_shard,
                    causal_skip=causal_skip,
                    chunk_q=chunk_q, chunk_kv=chunk_kv)
                if mesh is not None:
                    xc = constrain(xc, mesh, dp,
                                   "model" if seq_shard else None, None)
                newc[str(pi)] = nc
            return xc, newc

        if mode == "decode":
            # unrolled layer loop: a scan would double-buffer the cache in
            # its xs/ys (tens of GiB/device for 32k decode)
            if isinstance(gc, (list, tuple)):
                # per-layer cache layout: each layer's buffers are separate
                # (donated) arrays, aliased in place by XLA
                newc = []
                for li in range(repeat):
                    lp = jax.tree.map(lambda a: lax.index_in_dim(
                        a, li, 0, keepdims=False), gp)
                    x, nc = body(x, (lp, gc[li]))
                    newc.append(nc)
                new_groups.append(newc)
                continue
            # stacked layout (CPU/smoke path)
            newc = gc
            for li in range(repeat):
                lp = jax.tree.map(lambda a: lax.index_in_dim(
                    a, li, 0, keepdims=False), gp)
                lc = jax.tree.map(lambda a: lax.index_in_dim(
                    a, li, 0, keepdims=False), newc)
                x, nc = body(x, (lp, lc))
                newc = jax.tree.map(
                    lambda buf, new: lax.dynamic_update_index_in_dim(
                        buf, new.astype(buf.dtype), li, 0), newc, nc)
            new_groups.append(newc)
            continue

        if remat and mode == "train":
            body = jax.checkpoint(body)
        x, newc = lax.scan(body, x, (gp, gc))
        new_groups.append(newc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if mode == "prefill":
        x = x[:, -1:]
    if return_hidden:
        return x, {"blocks": new_groups}
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
    if mesh is not None:
        from repro.distributed.sharding import vocab_axis
        logits = constrain(logits, mesh, dp, None, vocab_axis(dp))
    return logits, {"blocks": new_groups}
