"""LM objectives and jitted step builders (train / prefill / decode).

- loss: masked softmax cross-entropy over the (vocab-sharded) logits;
  ``fused_loss=True`` fuses the output projection into a sequence-chunked
  scan so the full [B, S, V] logits are never materialized (a beyond-paper
  §Perf optimization; the baseline materializes them like most stacks do).
- train_step: grad accumulation over microbatches, AdamW/Adafactor update,
  optional int8 stochastic-rounding gradient sync over the "pod" axis
  (DCI-bound multi-pod runs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.configs.base import ArchSpec, ModelConfig, ShapeConfig
from repro.distributed.sharding import constrain, dp_axes
from repro.models import transformer as T
from repro.training import optimizer as opt_mod


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token NLL, [B, S].  Stable; works with vocab-sharded logits
    (reductions over the sharded axis lower to psum, the label pick is a
    one-hot contraction rather than a gather)."""
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    m = lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
    picked = jnp.einsum("bsv,bsv->bs", shifted, onehot)
    return lse - picked


def cast_params(params, dtype=jnp.bfloat16):
    """Cast fp32 master params to the compute dtype *outside* the layer
    scan, so FSDP all-gathers move bf16 (2x fewer wire+HBM bytes than
    letting the per-layer cast happen after the gather)."""
    return jax.tree.map(
        lambda p: p.astype(dtype) if p.dtype == jnp.float32 else p, params)


def lm_loss(
    cfg: ModelConfig,
    params,
    batch: dict,
    *,
    mesh=None,
    remat: bool = True,
    fused_loss: bool = False,
    loss_chunk: int = 1024,
    causal_skip: bool = False,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """batch: {tokens|embeds, labels [B,S], mask [B,S] optional}."""
    params = cast_params(params, compute_dtype)
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)

    if not fused_loss:
        logits, _ = T.forward(cfg, params, batch, mode="train", mesh=mesh,
                              remat=remat, causal_skip=causal_skip,
                              chunk_q=chunk_q, chunk_kv=chunk_kv)
        nll = _xent(logits, labels)
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)

    hidden, _ = T.forward(cfg, params, batch, mode="train", mesh=mesh,
                          remat=remat, causal_skip=causal_skip,
                          chunk_q=chunk_q, chunk_kv=chunk_kv,
                          return_hidden=True)
    head = params.get("lm_head", params["embed"])
    B, S, D = hidden.shape
    c = min(loss_chunk, S)
    assert S % c == 0
    n = S // c
    hx = hidden.reshape(B, n, c, D).swapaxes(0, 1)       # [n, B, c, D]
    lx = labels.reshape(B, n, c).swapaxes(0, 1)
    mx = mask.reshape(B, n, c).swapaxes(0, 1)

    def chunk(carry, inp):
        h, lb, mk = inp
        logits = jnp.einsum("bsd,vd->bsv", h, head.astype(h.dtype))
        if mesh is not None:
            from repro.distributed.sharding import vocab_axis
            logits = constrain(logits, mesh, dp_axes(mesh), None,
                               vocab_axis(dp_axes(mesh)))
        nll = _xent(logits, lb)
        return carry + jnp.sum(nll * mk), None

    total, _ = lax.scan(chunk, jnp.zeros((), jnp.float32), (hx, lx, mx))
    return total / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# int8 stochastic-rounding gradient compression (multi-pod DCI sync)
# ---------------------------------------------------------------------------

def quantize_int8(g: jax.Array, key: jax.Array):
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-30
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale + noise), -127, 127)
    return q.astype(jnp.int8), scale


def compressed_pod_psum(grads, key, axis: str = "pod"):
    """All-reduce grads over the pod axis in int8 (4x fewer DCI bytes).
    Must run inside shard_map with ``axis`` manual."""
    n = lax.psum(1, axis)
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, scale = quantize_int8(g, k)
        qs = lax.psum(q.astype(jnp.int32), axis)
        ss = lax.pmax(scale, axis)          # shared scale: conservative max
        out.append((qs.astype(jnp.float32) * ss / n).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_train_state(cfg: ModelConfig, key, opt_cfg: opt_mod.OptConfig,
                     dtype=jnp.float32) -> TrainState:
    params = T.init_params(cfg, key, dtype=dtype)
    opt_init, _ = opt_mod.make_optimizer(opt_cfg)
    return TrainState(params=params, opt=opt_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_mod.OptConfig,
    *,
    mesh=None,
    microbatch: int = 1,
    remat: bool = True,
    fused_loss: bool = False,
    causal_skip: bool = False,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    pod_compress: bool = False,
    compute_dtype=jnp.bfloat16,
):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have leading [global_batch, ...]; with microbatch > 1 the
    batch splits into that many accumulation steps (lax.scan)."""
    _, opt_update = opt_mod.make_optimizer(opt_cfg)
    loss_fn = functools.partial(
        lm_loss, cfg, mesh=mesh, remat=remat, fused_loss=fused_loss,
        causal_skip=causal_skip, chunk_q=chunk_q, chunk_kv=chunk_kv,
        compute_dtype=compute_dtype)

    def grads_of(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
        mb = jax.tree.map(
            lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                + x.shape[1:]), batch)

        def acc(carry, sub):
            tot, g = carry
            l, gi = jax.value_and_grad(lambda p: loss_fn(p, sub))(params)
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g, gi)
            return (tot + l, g), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tot, g), _ = lax.scan(acc, (jnp.zeros(()), zeros), mb)
        inv = 1.0 / microbatch
        return tot * inv, jax.tree.map(lambda a: a * inv, g)

    def step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        if pod_compress and mesh is not None and "pod" in mesh.axis_names:
            from jax.sharding import PartitionSpec as P
            key = jax.random.fold_in(jax.random.PRNGKey(17), state.step)

            def sync(g):
                return compressed_pod_psum(g, key)
            grads = compat.shard_map(
                sync, mesh=mesh,
                in_specs=jax.tree.map(lambda _: P(), grads),
                out_specs=jax.tree.map(lambda _: P(), grads),
                axis_names={"pod"}, check_vma=False)(grads)
        params, opt, gnorm = opt_update(grads, state.opt, state.params)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return step


def make_prefill_step(cfg: ModelConfig, *, mesh=None, serve_seq_shard=False,
                      chunk_q: int = 512, chunk_kv: int = 512,
                      causal_skip: bool = False, max_seq: Optional[int] = None):
    """``max_seq`` pads the produced (non-window) KV caches so subsequent
    decode steps have slots to write into."""
    def pad_cache(cache):
        if max_seq is None:
            return cache

        def fix(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name in ("k", "v") and not cfg.sliding_window:
                pad = max_seq - leaf.shape[2]
                if pad > 0:
                    widths = [(0, 0)] * leaf.ndim
                    widths[2] = (0, pad)
                    return jnp.pad(leaf, widths)
            return leaf
        return jax.tree_util.tree_map_with_path(fix, cache)

    def prefill(params, batch):
        logits, cache = T.forward(
            cfg, params, batch, mode="prefill", mesh=mesh,
            serve_seq_shard=serve_seq_shard, remat=False,
            causal_skip=causal_skip, chunk_q=chunk_q, chunk_kv=chunk_kv)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, pad_cache(cache)
    return prefill


def make_decode_step(cfg: ModelConfig, *, mesh=None, serve_seq_shard=False):
    def decode(params, cache, tokens_or_embeds, lengths):
        """tokens [B] int32 (or embeds [B, D]); lengths [B] = cache fill."""
        if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
            batch = {"tokens": tokens_or_embeds[:, None]}
        else:
            batch = {"embeds": tokens_or_embeds[:, None]}
        logits, cache = T.forward(
            cfg, params, batch, mode="decode", mesh=mesh, cache=cache,
            lengths=lengths, serve_seq_shard=serve_seq_shard, remat=False)
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return next_tok, cache, lengths + 1
    return decode
