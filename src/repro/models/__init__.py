"""LM model stack: layers, attention, MoE, RG-LRU, RWKV6, decoder assembly."""
