"""Primitive layers: norms, RoPE, MLPs, attention math (GQA, chunked, decode).

All functions are pure; parameters are plain arrays.  Attention comes in
three execution paths:

- ``attention_full``    : O(S^2) masked attention — smoke tests, short seq.
- ``attention_chunked`` : online-softmax over (q-chunk, kv-chunk) tiles via
  ``lax.scan`` — bounded memory for 32k prefill / 4k train.  With
  ``causal_skip=True`` the strictly-upper-triangular chunk pairs are skipped
  at runtime through ``lax.cond`` (a §Perf optimization; the baseline sweeps
  all pairs with masking).
- ``attention_decode``  : one query position against a KV cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)).reshape(
        (1,) * (out.ndim - 1) + (-1,))
    return (out * w).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x [..., S, H, dh]; positions [..., S] (int32)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freq = freq.reshape((1,) * positions.ndim + (-1,))
    angles = positions[..., None].astype(jnp.float32) * freq      # [..., S, half]
    sin = jnp.sin(angles)[..., None, :]                           # [..., S, 1, half]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Classic transformer sinusoidal embeddings (musicgen backbone)."""
    half = d // 2
    freq = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freq = freq.reshape((1,) * positions.ndim + (-1,))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_mlp(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    lead = (1,) * (x.ndim - 1)
    h = jnp.einsum("...d,df->...f", x, w_in) + b_in.reshape(lead + (-1,))
    return (jnp.einsum("...f,fd->...d", jax.nn.gelu(h), w_out)
            + b_out.reshape(lead + (-1,)))


def geglu_mlp(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u, w_down)


# ---------------------------------------------------------------------------
# Attention math (GQA throughout; H must be a multiple of KV)
# ---------------------------------------------------------------------------

def _split_groups(q, n_kv):
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def attention_full(q, k, v, *, causal=True, window=None,
                   q_positions=None, k_positions=None):
    """Masked O(S^2) attention.  q [B,S,H,dh]; k/v [B,T,KV,dh]."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    qg = _split_groups(q, kv)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * (dh ** -0.5)
    qp = q_positions if q_positions is not None else jnp.arange(s)
    kp = k_positions if k_positions is not None else jnp.arange(k.shape[1])
    mask = jnp.ones((s, k.shape[1]), dtype=bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b, s, h, dh)


def attention_chunked(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    chunk_q: int = 512,
    chunk_kv: int = 512,
    causal_skip: bool = False,
):
    """Online-softmax chunked attention (memory O(S * chunk))."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    cq = min(chunk_q, s)
    ck = min(chunk_kv, t)
    assert s % cq == 0 and t % ck == 0, (s, cq, t, ck)
    nq, nk = s // cq, t // ck
    scale = dh ** -0.5

    qr = q.reshape(b, nq, cq, kv, g, dh)
    kr = k.reshape(b, nk, ck, kv, dh).swapaxes(0, 1)   # [nk, b, ck, kv, dh]
    vr = v.reshape(b, nk, ck, kv, dh).swapaxes(0, 1)

    def q_chunk(carry, inp):
        i, qc = inp                                  # qc [b, cq, kv, g, dh]
        qpos = i * cq + jnp.arange(cq)

        def kv_chunk(state, kin):
            j, kc, vc = kin                          # kc/vc [b, ck, kv, dh]
            m, l, acc = state

            def compute(state):
                m, l, acc = state
                kpos = j * ck + jnp.arange(ck)
                sc = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc,
                                preferred_element_type=jnp.float32) * scale
                msk = jnp.ones((cq, ck), dtype=bool)
                if causal:
                    msk &= kpos[None, :] <= qpos[:, None]
                if window is not None:
                    msk &= kpos[None, :] > qpos[:, None] - window
                sc = jnp.where(msk[None, None, None], sc, NEG_INF)
                m_new = jnp.maximum(m, sc.max(axis=-1))
                p = jnp.exp(sc - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bkgqt,btkd->bkgqd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc_new

            if causal_skip:
                # chunk-level bounds: any (q, k) pair inside the tile live?
                live = jnp.asarray(True)
                if causal:
                    live &= j * ck <= i * cq + cq - 1
                if window is not None:
                    live &= j * ck + ck - 1 > i * cq - window
                state = lax.cond(live, compute, lambda st: st, state)
            else:
                state = compute(state)
            return state, None

        init = (
            jnp.full((b, kv, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kv, g, cq), jnp.float32),
            jnp.zeros((b, kv, g, cq, dh), jnp.float32),
        )
        (m, l, acc), _ = lax.scan(kv_chunk, init, (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.astype(q.dtype)            # [b, kv, g, cq, dh]

    _, outs = lax.scan(q_chunk, None, (jnp.arange(nq), qr.swapaxes(0, 1)))
    # outs [nq, b, kv, g, cq, dh] -> [b, s, h, dh]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, dh)
    return out


def attention_decode(q, k_cache, v_cache, length, *, window=None):
    """One-token decode.  q [B,H,dh]; caches [B,Smax,KV,dh]; length [B] int32
    = number of valid cache positions (including the token just written)."""
    b, h, dh = q.shape
    kv = k_cache.shape[2]
    qg = q.reshape(b, kv, h // kv, dh)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                    preferred_element_type=jnp.float32) * (dh ** -0.5)
    idx = jnp.arange(k_cache.shape[1])
    msk = idx[None, :] < length[:, None]
    if window is not None:
        msk &= idx[None, :] >= (length[:, None] - window)
    sc = jnp.where(msk[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, h, dh)
