"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent per-channel decay + squared-ReLU channel mix.

Time-mix recurrence per head (dh = 64), state S [B, H, dh_k, dh_v]:

    w_t = exp(-exp(w0 + tanh(x_w A) B))          (data-dependent decay, the
                                                  defining Finch feature)
    y_t[i->:] = sum_i r_t[i] * (S_{t-1}[i, :] + u[i] k_t[i] v_t[:])
    S_t[i, :] = w_t[i] * S_{t-1}[i, :] + k_t[i] v_t[:]

Token shift is the RWKV static mix (x + (shift(x) - x) * mu); the full
ddlerp of the paper is a small LoRA refinement we fold into the decay path
only — noted in DESIGN.md.  Train/prefill runs ``lax.scan`` over time (a
chunked-parallel variant is a §Perf candidate); decode is one step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

HEAD_DIM = 64


def _token_shift(x, prev=None):
    """[B, S, D] -> previous timestep (zeros / carried at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype).reshape(
        (1,) * (x.ndim - 1) + (-1,))


def _decay(params, xw):
    """Data-dependent decay w_t in (0, 1).  xw [B, S, D] -> [B, S, D]."""
    lora = jnp.einsum("bsd,dl->bsl", xw, params["w_lora_a"].astype(xw.dtype))
    lora = jnp.einsum("bsl,ld->bsd", jnp.tanh(lora), params["w_lora_b"].astype(xw.dtype))
    return jnp.exp(-jnp.exp(
        params["w0"].astype(jnp.float32)[None, None]
        + lora.astype(jnp.float32)))


def _wkv_scan(r, k, v, w, u, s0=None, chunk: int = 64):
    """Recurrent WKV.  r/k/v/w [B, S, H, dh]; u [H, dh].
    Returns (y [B, S, H, dh], s_last [B, H, dh, dh]).

    Two-level scan: outer over S/chunk with remat, inner over time steps —
    the backward pass then stores one [B, H, dh, dh] state per *chunk*
    boundary instead of per step (S x state would be GBs at 4k train)."""
    B, S, H, dh = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, dh, dh), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                        # [B, H, dh]
        kv = kt[..., :, None] * vt[..., None, :]    # [B, H, dh, dh]
        att = s + u[None, :, :, None] * kv
        yt = jnp.einsum("bhi,bhij->bhj", rt, att)
        s_new = wt[..., :, None] * s + kv
        return s_new, yt

    xs = tuple(t.swapaxes(0, 1).astype(jnp.float32) for t in (r, k, v, w))

    if S <= chunk or S % chunk != 0:
        s_last, ys = lax.scan(step, s0, xs)
        return ys.swapaxes(0, 1), s_last

    n = S // chunk
    xs_c = tuple(t.reshape((n, chunk) + t.shape[1:]) for t in xs)

    @jax.checkpoint
    def chunk_body(s, inp):
        s_new, ys = lax.scan(step, s, inp)
        return s_new, ys

    s_last, ys = lax.scan(chunk_body, s0, xs_c)     # ys [n, chunk, B, H, dh]
    ys = ys.reshape((S,) + ys.shape[2:])
    return ys.swapaxes(0, 1), s_last


def time_mix(params, x, *, cache=None):
    """RWKV6 attention replacement.  x [B, S, D] -> (y, new_cache).
    cache = {"s": [B,H,dh,dh], "x_prev": [B, D]} for decode."""
    B, S, D = x.shape
    H = D // HEAD_DIM
    xs = _token_shift(x, None if cache is None else cache["x_prev"])
    xr = _mix(x, xs, params["mu_r"])
    xk = _mix(x, xs, params["mu_k"])
    xv = _mix(x, xs, params["mu_v"])
    xw = _mix(x, xs, params["mu_w"])
    xg = _mix(x, xs, params["mu_g"])

    r = jnp.einsum("bsd,de->bse", xr, params["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, params["w_v"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"].astype(x.dtype)))
    w = _decay(params, xw)

    hd = lambda t: t.reshape(B, S, H, HEAD_DIM)
    u = params["u"].astype(jnp.float32).reshape(H, HEAD_DIM)
    s0 = None if cache is None else cache["s"]
    y, s_last = _wkv_scan(hd(r), hd(k), hd(v), hd(w.astype(x.dtype)), u, s0)

    # per-head group norm
    yf = y.reshape(B, S, H, HEAD_DIM)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mean) * lax.rsqrt(var + 64e-5)
    yn = (yn.reshape(B, S, D) * params["ln_w"].astype(jnp.float32)[None, None]
          + params["ln_b"].astype(jnp.float32)[None, None])

    out = jnp.einsum("bse,ed->bsd", (yn.astype(x.dtype) * g), params["w_o"].astype(x.dtype))
    new_cache = {"s": s_last, "x_prev": x[:, -1]}
    return out, new_cache


def channel_mix(params, x, *, cache=None):
    """RWKV squared-ReLU FFN with receptance gate.  x [B,S,D] -> (y, cache)."""
    xs = _token_shift(x, None if cache is None else cache["x_prev"])
    xk = _mix(x, xs, params["mu_ck"])
    xr = _mix(x, xs, params["mu_cr"])
    k = jnp.einsum("bsd,df->bsf", xk, params["w_ck"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_cv"].astype(x.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_cr"].astype(x.dtype)))
    return rgate * kv, {"x_prev": x[:, -1]}


def rwkv_param_shapes(d_model: int, d_ff: int, lora_dim: int = 64):
    D, FF = d_model, d_ff
    return {
        # time mix
        "mu_r": ((D,), ("norm",)), "mu_k": ((D,), ("norm",)),
        "mu_v": ((D,), ("norm",)), "mu_w": ((D,), ("norm",)),
        "mu_g": ((D,), ("norm",)),
        "w_r": ((D, D), ("d_model_in", "rnn")),
        "w_k": ((D, D), ("d_model_in", "rnn")),
        "w_v": ((D, D), ("d_model_in", "rnn")),
        "w_g": ((D, D), ("d_model_in", "rnn")),
        "w_o": ((D, D), ("rnn", "d_model_out")),
        "w0": ((D,), ("norm",)),
        "w_lora_a": ((D, lora_dim), ("d_model_in", "lora")),
        "w_lora_b": ((lora_dim, D), ("lora", None)),
        "u": ((D,), ("norm",)),
        "ln_w": ((D,), ("norm",)), "ln_b": ((D,), ("norm",)),
        # channel mix
        "mu_ck": ((D,), ("norm",)), "mu_cr": ((D,), ("norm",)),
        "w_ck": ((D, FF), ("d_model_in", "ff")),
        "w_cv": ((FF, D), ("ff", "d_model_out")),
        "w_cr": ((D, D), ("d_model_in", None)),
    }
