"""Mixture-of-Experts FFN with sort-based (dropping) dispatch + EP sharding.

Routing: softmax router, top-k experts per token, capacity-bucketed.  The
dispatch is *sort-based* (argsort token-expert pairs by expert, scatter into
[E, C, d] buffers) rather than GShard one-hot einsums — the one-hot dispatch
matmul costs T*E*C*d flops which can exceed the expert FFN itself at small
d_ff (olmoe: d_ff=1024); the sort variant moves T*k*d bytes and spends no
MXU flops on routing.

Expert parallelism: experts shard over the "model" axis; activations are
replicated over "model" (standard TP residual stream), so each model rank
routes identical tokens into its *local* experts and the weighted expert
outputs are combined with one psum over "model" — the same collective
pattern as a row-parallel matmul, no all_to_all needed.  This is expressed
with `compat.shard_map(..., axis_names={"model"})`, leaving the batch axes
in auto mode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def router_topk(logits: jax.Array, k: int):
    """logits [T, E] -> (weights [T, k] softmaxed over chosen, idx [T, k])."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = lax.top_k(gates, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def _dispatch_local(x, w_topk, idx_topk, n_experts_local, e_lo, capacity):
    """Sort-based dispatch of this rank's share of token-expert pairs.

    x [T, D]; w_topk/idx_topk [T, k] (global expert ids).  Selects pairs
    routed to experts [e_lo, e_lo + n_experts_local), buckets them into
    [E_loc, C, D] with per-expert capacity C, returns (buffers, combine
    metadata)."""
    T, D = x.shape
    k = idx_topk.shape[1]
    flat_e = idx_topk.reshape(-1)                       # [T*k]
    flat_w = w_topk.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    local = (flat_e >= e_lo) & (flat_e < e_lo + n_experts_local)
    le = jnp.where(local, flat_e - e_lo, n_experts_local)   # overflow bucket
    # position of each pair within its expert bucket
    onehot = jax.nn.one_hot(le, n_experts_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                 # [T*k, E_loc+1]
    slot_in_e = jnp.take_along_axis(pos, le[:, None], axis=1)[:, 0]
    keep = local & (slot_in_e < capacity)
    slot = jnp.where(keep, le * capacity + slot_in_e, n_experts_local * capacity)
    # scatter in f32: combine precision + works around an XLA SPMD
    # partitioner failure on bf16 scatter-add (opcode-copy check)
    buf = jnp.zeros((n_experts_local * capacity + 1, D), jnp.float32)
    buf = buf.at[slot].add(
        jnp.where(keep[:, None], x[flat_t], 0).astype(jnp.float32))
    return (buf[:-1].reshape(n_experts_local, capacity, D).astype(x.dtype),
            (slot, flat_t, flat_w, keep))


def _combine_local(y_buf, meta, T, out_dtype):
    """Scatter expert outputs back to token order with routing weights
    (f32 accumulation)."""
    slot, flat_t, flat_w, keep = meta
    E_loc, C, D = y_buf.shape
    flat = jnp.concatenate([y_buf.reshape(E_loc * C, D).astype(jnp.float32),
                            jnp.zeros((1, D), jnp.float32)], axis=0)
    gathered = flat[jnp.minimum(slot, E_loc * C)]        # [T*k, D]
    contrib = jnp.where(keep[:, None],
                        gathered * flat_w[:, None].astype(jnp.float32), 0.0)
    out = jnp.zeros((T, D), jnp.float32)
    return out.at[flat_t].add(contrib).astype(out_dtype)


def moe_ffn(params, x, cfg: MoEConfig, mesh=None, fsdp_gather=False):
    """x [B, S, D] -> [B, S, D].  params: router [D,E], w_gate/w_up [E,D,F],
    w_down [E,F,D].

    Distributed layout: experts shard over "model"; tokens stay sharded
    over the batch axes (the shard_map is manual over both, so routing,
    capacity and dispatch buffers are all *per-data-shard local*); each
    model rank computes its local experts' contribution for the local
    tokens and one f32 psum over "model" combines them — the same
    collective pattern as a row-parallel matmul, no all_to_all.

    ``fsdp_gather``: training shards expert weights 2D (experts over
    "model" x d_model over "data" — a 27B-param MoE's optimizer state
    must divide by all 256 chips, not 16); the d_model shards are
    all-gathered here, ZeRO-3 style, right before use."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(B * S, D)
    T = B * S

    def routed(router, wg, wu, wd, xloc, e_loc, e_lo, t_loc, out_dtype):
        logits = jnp.einsum("td,de->te", xloc, router.astype(xloc.dtype))
        wt, it = router_topk(logits, k)
        capacity = int(cfg.capacity_factor * t_loc * k / E) or 1
        buf, meta = _dispatch_local(xloc, wt, it, e_loc, e_lo, capacity)
        h = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd.astype(buf.dtype))
        return _combine_local(y, meta, t_loc, out_dtype)

    tp = mesh.shape["model"] if (mesh is not None
                                 and "model" in mesh.axis_names) else 1
    if tp == 1:
        out = routed(params["router"], params["w_gate"], params["w_up"],
                     params["w_down"], xf, E, 0, T, xf.dtype)
        return out.reshape(B, S, D)

    from jax.sharding import PartitionSpec as P
    assert E % tp == 0
    e_loc = E // tp
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    if B % dp_n != 0:
        dp, dp_n = (), 1          # replicated batch (e.g. batch=1 decode)
    t_loc = T // dp_n
    tok_spec = P(dp) if dp else P()
    fsdp = fsdp_gather and "data" in mesh.axis_names
    ws = "data" if fsdp else None

    def ranked(router, wg, wu, wd, xloc):
        if fsdp:  # ZeRO-3 gather of the d_model shards, right before use
            router = lax.all_gather(router, "data", axis=0, tiled=True)
            wg = lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = lax.all_gather(wd, "data", axis=2, tiled=True)
        e_lo = lax.axis_index("model") * e_loc
        # psum in f32: bf16 psum under shard_map trips an XLA SPMD
        # partitioner check (f32 combine is numerically right anyway)
        y = routed(router, wg, wu, wd, xloc, e_loc, e_lo, t_loc, jnp.float32)
        return lax.psum(y, "model").astype(xloc.dtype)

    out = compat.shard_map(
        ranked, mesh=mesh,
        in_specs=(P(ws), P("model", ws), P("model", ws),
                  P("model", None, ws), tok_spec),
        out_specs=tok_spec,
        axis_names=set(dp) | {"model"} | ({"data"} if fsdp else set()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"],
      params["w_down"], xf)
    return out.reshape(B, S, D)


def moe_param_shapes(d_model: int, d_ff: int, cfg: MoEConfig):
    """(shape, logical axes) for every MoE parameter.

    Expert weights are 2D-shardable: experts over "model" (EP) and the
    d_model dim over "data" (FSDP, gathered in moe_ffn when training)."""
    E = cfg.n_experts
    return {
        "router": ((d_model, E), ("d_model_in", None)),
        "w_gate": ((E, d_model, d_ff), ("experts", "d_model_in", None)),
        "w_up":   ((E, d_model, d_ff), ("experts", "d_model_in", None)),
        "w_down": ((E, d_ff, d_model), ("experts", None, "d_model_in")),
    }
