"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (the "recurrent block" of Griffin):

    x -> [linear in (2 branches)] -> conv1d(w=4, depthwise) -> RG-LRU -> *gate -> linear out

RG-LRU recurrence (real-gated linear recurrent unit), per channel:

    r_t = sigmoid(W_a x_t)              recurrence gate
    i_t = sigmoid(W_x x_t)              input gate
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``lax.associative_scan`` (parallel prefix over the
(a, b) affine maps — O(log S) depth, TPU-friendly); decode is a single
affine step with carried state.  Gates are computed from the branch input
(simplification noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

C_SCALE = 8.0  # Griffin's c constant


def _lru_coeffs(params, x):
    """x [B, S, R] -> (a, b) with h_t = a_t h_{t-1} + b_t."""
    r = jax.nn.sigmoid(jnp.einsum("bsr,rk->bsk", x, params["w_a"].astype(x.dtype)))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rk->bsk", x, params["w_x"].astype(x.dtype)))
    lam = jax.nn.softplus(params["lam"].astype(jnp.float32))
    log_a = -C_SCALE * lam.reshape((1,) * (r.ndim - 1) + (-1,)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * x.astype(jnp.float32))
    return a, b


def rg_lru_scan(params, x, h0=None):
    """Parallel-scan RG-LRU over a sequence.  x [B, S, R] -> (y, h_last)."""
    a, b = _lru_coeffs(params, x)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, ar * bl + br

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(params, x, h):
    """Single decode step.  x [B, R], h [B, R] -> (y, h_new)."""
    a, b = _lru_coeffs(params, x[:, None])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x.dtype), h_new


def causal_conv1d(x, kernel, state=None):
    """Depthwise causal conv, width W.  x [B, S, R]; kernel [W, R].

    ``state`` [B, W-1, R] carries the last W-1 inputs for decode; returns
    (y, new_state)."""
    W = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B, S+W-1, R]
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)[None, None]
            for i in range(W))
    return y, xp[:, -(W - 1):]


def recurrent_branch(params, x, *, cache=None):
    """Full Griffin recurrent block body (pre-norm residual handled by caller).

    x [B, S, D] -> (y [B, S, D], new_cache).
    cache = {"conv": [B, W-1, R], "h": [B, R]} for decode, None for scan.
    params: w_in_rnn [D,R], w_in_gate [D,R], conv [W,R], w_a [R,R], w_x [R,R],
            lam [R], w_out [R,D].
    """
    u = jnp.einsum("bsd,dr->bsr", x, params["w_in_rnn"].astype(x.dtype))
    g = jnp.einsum("bsd,dr->bsr", x, params["w_in_gate"].astype(x.dtype))
    if cache is None:
        u, conv_state = causal_conv1d(u, params["conv"])
        y, h_last = rg_lru_scan({k: params[k] for k in ("w_a", "w_x", "lam")}, u)
        new_cache = {"conv": conv_state, "h": h_last}
    else:
        u2, conv_state = causal_conv1d(u, params["conv"], state=cache["conv"])
        y1, h_new = rg_lru_step(
            {k: params[k] for k in ("w_a", "w_x", "lam")}, u2[:, 0], cache["h"])
        y = y1[:, None]
        new_cache = {"conv": conv_state, "h": h_new}
    y = y * jax.nn.gelu(g)
    out = jnp.einsum("bsr,rd->bsd", y, params["w_out"].astype(x.dtype))
    return out, new_cache


def rglru_param_shapes(d_model: int, d_rnn: int, conv_width: int = 4):
    return {
        "w_in_rnn":  ((d_model, d_rnn), ("d_model_in", "rnn")),
        "w_in_gate": ((d_model, d_rnn), ("d_model_in", "rnn")),
        "conv":      ((conv_width, d_rnn), (None, "rnn")),
        "w_a":       ((d_rnn, d_rnn), (None, "rnn")),
        "w_x":       ((d_rnn, d_rnn), (None, "rnn")),
        "lam":       ((d_rnn,), ("rnn",)),
        "w_out":     ((d_rnn, d_model), ("rnn", "d_model_out")),
    }
