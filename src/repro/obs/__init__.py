"""repro.obs — span tracing, metrics, and Chrome-trace export.

The observability layer for the streaming/mesh stack (see
OBSERVABILITY.md for the span/metric catalog and a how-to):

- ``obs.trace`` — thread-aware span tracer (no-op unless enabled), the
  ``phase`` helper that also feeds per-phase metrics, and the
  process-wide ``set_tracer``/``current_tracer`` hook ``--trace``
  installs.
- ``obs.metrics`` — counters / gauges / fixed-bucket histograms;
  ``StreamTelemetry`` is a view over one of these registries.
- ``obs.export`` — Chrome-trace / Perfetto JSON emission + the schema
  validator CI runs over the emitted file.
- ``obs.ledger`` — plan-vs-actual records (predicted vs measured bytes /
  peaks / reduce traffic / fill waste) with recomputed verdicts;
  ``obs.report`` renders one, ``obs.regress`` exit-codes it (and the
  bench history) for CI.

Stdlib-only on purpose (like ``repro.analysis``): the lint job and the
import sweep load it in any environment the repo loads in, and nothing in
the hot path pulls jax/numpy through the instrumentation.
"""
from repro.obs.export import (chrome_trace, load_and_validate, span_counts,
                              validate_chrome_trace, write_trace)
from repro.obs.ledger import (LEDGER_SCHEMA, Ledger, merge_ledgers,
                              validate_ledger)
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.trace import (NOOP_SPAN, NULL_TRACER, NullTracer, SpanEvent,
                             Tracer, current_tracer, phase, set_tracer,
                             traced)

__all__ = [
    "Counter", "DEFAULT_LATENCY_BUCKETS", "Gauge", "Histogram",
    "LEDGER_SCHEMA", "Ledger", "MetricsRegistry", "NOOP_SPAN",
    "NULL_TRACER", "NullTracer", "SpanEvent", "Tracer", "chrome_trace",
    "current_tracer", "load_and_validate", "merge_ledgers", "phase",
    "set_tracer", "span_counts", "traced", "validate_chrome_trace",
    "validate_ledger", "write_trace",
]
