"""Plan-vs-actual ledger: the repo's predictions confronted with its meters.

The paper's whole argument is a cost model — eq. 5-8 price the bytes a wave
moves, §4.4 prices what stays resident, Fig. 5b prices the reduction — and
the repo both *predicts* those numbers (``core.partition.plan_for``,
``outofcore.schedule.required_capacity_bytes``, ``kernels.budgets``) and
*measures* them (``MemoryMeter``, the ``obs`` registry counters).  A
:class:`Ledger` is the closing of that loop: one structured record per
predicted quantity, each carrying the prediction, the measurement, a
relative-drift number, and a verdict under a declared check:

- ``"exact"`` — measured must equal predicted.  Byte and count metrics are
  deterministic functions of the store shapes, so anything but equality
  means the model (or the instrumentation) is wrong.
- ``"le"``    — measured must not exceed predicted: capacity bounds
  (metered peak vs budget, kernel footprint vs VMEM limit).
- ``"rel"``   — |measured - predicted| <= rel_tol * |predicted|: noisy
  quantities (times, float ratios).

``severity="warn"`` records never fail the ledger as a whole (time metrics
are warn-only by design); ``severity="error"`` records decide ``ok``.

The ledger serializes to one JSON object (:meth:`Ledger.to_obj`) that the
streaming drivers attach to their :class:`StreamTelemetry`, benches write
next to their BENCH rows, ``python -m repro.obs.report`` renders, and
``python -m repro.obs.regress --ledger`` exit-codes for CI.
:func:`validate_ledger` is the schema gate: it checks structure AND
recomputes every verdict, so a ledger whose ``ok`` flags disagree with its
own numbers is rejected, not trusted.

Stdlib-only, like the rest of ``repro.obs``.
"""
from __future__ import annotations

from typing import Mapping, Optional

LEDGER_SCHEMA = "repro.obs/ledger-v1"
CHECKS = ("exact", "le", "rel")
SEVERITIES = ("error", "warn")


def _drift(predicted, measured) -> Optional[float]:
    """Relative drift (measured - predicted) / predicted; None when the
    prediction is zero and the measurement is not (undefined, and JSON has
    no clean infinity)."""
    if predicted:
        return (measured - predicted) / predicted
    return 0.0 if not measured else None


def _verdict(check: str, predicted, measured, rel_tol: float) -> bool:
    if check == "exact":
        return measured == predicted
    if check == "le":
        return measured <= predicted
    if check == "rel":
        if predicted:
            return abs(measured - predicted) <= rel_tol * abs(predicted)
        return abs(measured) <= rel_tol
    raise ValueError(f"unknown check {check!r}")


class Ledger:
    """One run's plan-vs-actual records plus its run context.

    ``**run`` is free-form context (solver, mesh shape, wave counts,
    phase_seconds, ...) carried verbatim into the serialized object —
    whatever the report CLI needs to label the run.
    """

    def __init__(self, **run):
        self.run = dict(run)
        self.records: list[dict] = []

    def record(self, name: str, predicted, measured, *, unit: str,
               check: str = "exact", rel_tol: float = 0.0,
               severity: str = "error", **context) -> dict:
        """Append one plan-vs-actual record and return it.

        The verdict is computed here, from the numbers — callers never set
        ``ok`` themselves, which is what lets ``validate_ledger`` recompute
        and reject a tampered or stale ledger.
        """
        assert check in CHECKS, check
        assert severity in SEVERITIES, severity
        predicted = predicted if isinstance(predicted, int) else float(predicted)
        measured = measured if isinstance(measured, int) else float(measured)
        rec = {
            "name": str(name),
            "unit": str(unit),
            "check": check,
            "severity": severity,
            "predicted": predicted,
            "measured": measured,
            "rel_tol": float(rel_tol),
            "drift": _drift(predicted, measured),
            "ok": _verdict(check, predicted, measured, rel_tol),
        }
        if context:
            rec["context"] = context
        self.records.append(rec)
        return rec

    @property
    def ok(self) -> bool:
        """True iff every error-severity record holds."""
        return all(r["ok"] for r in self.records if r["severity"] == "error")

    @property
    def flags(self) -> list[str]:
        """``severity:name`` of every failing record (warn ones included —
        they are reported, they just do not decide ``ok``)."""
        return [f"{r['severity']}:{r['name']}"
                for r in self.records if not r["ok"]]

    def to_obj(self) -> dict:
        """The JSON-ready serialized form (``validate_ledger``'s input)."""
        return {
            "schema": LEDGER_SCHEMA,
            "run": dict(self.run),
            "records": [dict(r) for r in self.records],
            "ok": self.ok,
            "flags": self.flags,
        }


def validate_ledger(obj) -> dict:
    """Schema + consistency gate over a serialized ledger.

    Raises ``ValueError`` on any structural problem or on a verdict that
    does not follow from its own record's numbers; returns a summary
    ``{"records", "errors", "warnings", "ok"}`` (errors/warnings count the
    *failing* records per severity).
    """
    def fail(msg):
        raise ValueError(f"invalid ledger: {msg}")

    if not isinstance(obj, dict):
        fail(f"expected object, got {type(obj).__name__}")
    if obj.get("schema") != LEDGER_SCHEMA:
        fail(f"schema {obj.get('schema')!r} != {LEDGER_SCHEMA!r}")
    for key in ("run", "records", "ok", "flags"):
        if key not in obj:
            fail(f"missing top-level key {key!r}")
    if not isinstance(obj["run"], dict):
        fail("run context must be an object")
    if not isinstance(obj["records"], list):
        fail("records must be a list")

    n_err = n_warn = 0
    flags = []
    for i, rec in enumerate(obj["records"]):
        where = f"records[{i}]"
        if not isinstance(rec, dict):
            fail(f"{where} is not an object")
        for key in ("name", "unit", "check", "severity",
                    "predicted", "measured", "rel_tol", "drift", "ok"):
            if key not in rec:
                fail(f"{where} missing key {key!r}")
        if rec["check"] not in CHECKS:
            fail(f"{where} unknown check {rec['check']!r}")
        if rec["severity"] not in SEVERITIES:
            fail(f"{where} unknown severity {rec['severity']!r}")
        for key in ("predicted", "measured"):
            if isinstance(rec[key], bool) or \
                    not isinstance(rec[key], (int, float)):
                fail(f"{where}.{key} is not a number: {rec[key]!r}")
        want_ok = _verdict(rec["check"], rec["predicted"], rec["measured"],
                           rec["rel_tol"])
        if bool(rec["ok"]) != want_ok:
            fail(f"{where} ({rec['name']}) verdict ok={rec['ok']} "
                 f"inconsistent with predicted={rec['predicted']} "
                 f"measured={rec['measured']} under check={rec['check']}")
        want_drift = _drift(rec["predicted"], rec["measured"])
        got_drift = rec["drift"]
        if want_drift is None:
            if got_drift is not None:
                fail(f"{where} drift should be null")
        elif got_drift is None or abs(got_drift - want_drift) > 1e-9:
            fail(f"{where} drift {got_drift!r} != {want_drift!r}")
        if not rec["ok"]:
            flags.append(f"{rec['severity']}:{rec['name']}")
            if rec["severity"] == "error":
                n_err += 1
            else:
                n_warn += 1
    want_overall = n_err == 0
    if bool(obj["ok"]) != want_overall:
        fail(f"overall ok={obj['ok']} but {n_err} error record(s) fail")
    if list(obj["flags"]) != flags:
        fail(f"flags {obj['flags']!r} != recomputed {flags!r}")
    return {"records": len(obj["records"]), "errors": n_err,
            "warnings": n_warn, "ok": want_overall}


def merge_ledgers(parts: Mapping[str, Optional[dict]]) -> dict:
    """One ledger over a multi-phase run (the hybrid driver's telemetry
    merge).  ``parts`` maps phase name -> serialized ledger (None for a
    phase that did not run); record names and flags are prefixed with the
    phase name (``als/bytes_streamed``), run contexts nest under their
    phase keys, and the merged ``ok`` is the conjunction.
    """
    live = {k: v for k, v in parts.items() if v}
    assert live, "merge_ledgers needs at least one non-empty ledger"
    records = []
    flags = []
    for name, obj in live.items():
        for rec in obj["records"]:
            r = dict(rec)
            r["name"] = f"{name}/{rec['name']}"
            records.append(r)
            if not r["ok"]:
                flags.append(f"{r['severity']}:{r['name']}")
    return {
        "schema": LEDGER_SCHEMA,
        "run": {name: dict(obj["run"]) for name, obj in live.items()},
        "records": records,
        "ok": all(obj["ok"] for obj in live.values()),
        "flags": flags,
    }
