"""Chrome-trace / Perfetto JSON export of a recorded ``Tracer``.

Emits the JSON *object* flavor of the Trace Event Format — the shape both
``chrome://tracing`` and https://ui.perfetto.dev load directly:

    {"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}

Span events are ``ph: "X"`` (complete) with microsecond ``ts``/``dur``;
each thread that recorded at least one event gets a ``ph: "M"``
``thread_name`` metadata record so the prefetch worker shows up as its own
named track next to the main thread.  Counter samples (``ph: "C"``, e.g.
prefetch queue depth) render as Perfetto counter tracks.  When a
``MetricsRegistry`` is passed along, its snapshot rides in ``otherData``
so one file carries the timeline *and* the numbers.

``validate_chrome_trace`` is the schema gate the tests and the CI
bench-smoke job run over the emitted file: required keys per event,
non-negative times, and — per thread — properly *nested* spans (a span
must either contain or be disjoint from any span it overlaps; partial
overlap on one thread means broken instrumentation).
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs.trace import Tracer, process_id


def chrome_trace(tracer: Tracer, registry=None,
                 process_name: str = "repro") -> dict:
    """Render a tracer's events as a Chrome-trace JSON object."""
    pid = process_id()
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    for tid, tname in sorted(tracer.thread_names.items()):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": tname}})
    for ev in sorted(tracer.events, key=lambda e: (e.ts, -e.dur)):
        rec = {"name": ev.name, "cat": ev.cat or "default", "ph": ev.ph,
               "ts": ev.ts, "pid": pid, "tid": ev.tid, "args": ev.args}
        if ev.ph == "X":
            rec["dur"] = ev.dur
        elif ev.ph == "i":
            rec["s"] = "t"              # thread-scoped instant
        events.append(rec)
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if registry is not None:
        out["otherData"] = {"metrics": registry.snapshot()}
    return out


def write_trace(path: str, tracer: Tracer, registry=None,
                process_name: str = "repro") -> dict:
    """Serialize :func:`chrome_trace` to ``path``; returns the object."""
    obj = chrome_trace(tracer, registry=registry, process_name=process_name)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj: dict) -> dict:
    """Schema-check a trace object (or raise ``ValueError``).

    Checks: the top-level shape, per-event required keys, non-negative
    microsecond times, and per-thread span nesting.  Returns summary
    stats ``{"events": n, "spans": n, "cats": {...}, "tids": {...}}`` so
    callers (CI) can assert coverage on top.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")

    spans_by_tid: dict[int, list[tuple[float, float, str]]] = {}
    cats: set[str] = set()
    n_spans = 0
    for i, ev in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing {key!r}: {ev}")
        if ev["ph"] == "M":
            continue
        if "ts" not in ev:
            raise ValueError(f"event {i} missing 'ts': {ev}")
        if ev["ts"] < 0:
            raise ValueError(f"event {i} has negative ts: {ev}")
        cats.add(ev.get("cat", "default"))
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if dur is None or dur < 0:
                raise ValueError(f"span {i} missing/negative 'dur': {ev}")
            n_spans += 1
            spans_by_tid.setdefault(ev["tid"], []).append(
                (ev["ts"], dur, ev["name"]))

    # per-thread nesting: walking spans by (start, longest-first), every
    # span must close before any enclosing span closes
    for tid, spans in spans_by_tid.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple[float, str]] = []     # (end, name)
        for ts, dur, name in spans:
            while stack and stack[-1][0] <= ts:
                stack.pop()
            end = ts + dur
            if stack and end > stack[-1][0]:
                raise ValueError(
                    f"tid {tid}: span {name!r} [{ts}, {end}] partially "
                    f"overlaps enclosing {stack[-1][1]!r} "
                    f"(ends {stack[-1][0]})")
            stack.append((end, name))

    return {"events": len(events), "spans": n_spans, "cats": sorted(cats),
            "tids": sorted(spans_by_tid)}


def load_and_validate(path: str) -> dict:
    """Read a trace file and validate it; returns the summary stats."""
    with open(path) as f:
        return validate_chrome_trace(json.load(f))


def span_counts(obj: dict, by: str = "cat") -> dict[str, int]:
    """Count ``ph == "X"`` spans per category (or per name): the helper
    the per-wave-span-count regression and the CI schema check share."""
    out: dict[str, int] = {}
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "X":
            key = ev.get(by, "default") if by != "name" else ev["name"]
            out[key] = out.get(key, 0) + 1
    return out
