"""Run-summary renderer for ledger (and trace) files.

::

    python -m repro.obs.report LEDGER.json [--trace trace.json] [--top N]

Prints, for one serialized :mod:`repro.obs.ledger` object: the run context,
the phase-seconds breakdown (where the wall clock went), the plan-vs-actual
table with per-record drift and verdicts, and the drift flags.  With
``--trace`` it also lists the top spans by duration and the per-category
span counts from a Chrome-trace file (the ``--trace`` output of the
drivers/benches).

Rendering only — the exit-coded CI gate over the same files is
``python -m repro.obs.regress``.  Stdlib-only.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

from repro.obs.ledger import validate_ledger


def _fmt_qty(v, unit: str) -> str:
    if v is None:
        return "-"
    if unit == "bytes":
        for thresh, suf in ((1 << 30, "GiB"), (1 << 20, "MiB"),
                            (1 << 10, "KiB")):
            if abs(v) >= thresh:
                return f"{v / thresh:.2f}{suf}"
        return f"{v}B"
    if unit == "seconds":
        return f"{v:.3f}s"
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)


def _fmt_drift(drift) -> str:
    if drift is None:
        return "undef"
    return f"{drift * 100:+.2f}%"


def _table(rows: list[list[str]], header: list[str]) -> list[str]:
    widths = [max(len(r[i]) for r in [header] + rows)
              for i in range(len(header))]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    return [line(header), line(["-" * w for w in widths])] + \
        [line(r) for r in rows]


def render_ledger(obj: dict) -> str:
    """The full text report of one serialized ledger (validates first)."""
    summary = validate_ledger(obj)
    out = [f"ledger: {summary['records']} records, "
           f"ok={summary['ok']} "
           f"({summary['errors']} error, {summary['warnings']} warn flags)"]

    run = obj["run"]
    phase_seconds = None
    ctx_rows = []
    for key in sorted(run):
        if key == "phase_seconds":
            phase_seconds = run[key]
            continue
        val = run[key]
        if isinstance(val, dict):      # merged hybrid ledgers nest contexts
            val = json.dumps(val, sort_keys=True)
        ctx_rows.append(f"  {key} = {val}")
    out.append("")
    out.append("run:")
    out.extend(ctx_rows)

    if phase_seconds:
        total = sum(phase_seconds.values())
        driver = phase_seconds.get("driver", total)
        out.append("")
        out.append("phase breakdown:")
        rows = [[cat, f"{secs:.3f}s",
                 f"{secs / driver * 100:.1f}%" if driver else "-"]
                for cat, secs in sorted(phase_seconds.items(),
                                        key=lambda kv: -kv[1])]
        out.extend("  " + l for l in
                   _table(rows, ["phase", "seconds", "% of driver"]))

    out.append("")
    out.append("plan vs actual:")
    rows = []
    for rec in obj["records"]:
        rows.append([
            rec["name"],
            _fmt_qty(rec["predicted"], rec["unit"]),
            _fmt_qty(rec["measured"], rec["unit"]),
            _fmt_drift(rec["drift"]),
            rec["check"],
            "ok" if rec["ok"] else f"DRIFT({rec['severity']})",
        ])
    out.extend("  " + l for l in
               _table(rows, ["record", "predicted", "measured", "drift",
                             "check", "verdict"]))

    out.append("")
    if obj["flags"]:
        out.append("drift flags: " + ", ".join(obj["flags"]))
    else:
        out.append("drift flags: none")
    return "\n".join(out)


def render_trace_tops(trace_obj: dict, top: int = 10) -> str:
    """Top spans by duration + per-category counts from a Chrome trace."""
    from repro.obs.export import span_counts
    spans = [e for e in trace_obj.get("traceEvents", [])
             if e.get("ph") == "X"]
    spans.sort(key=lambda e: -e.get("dur", 0))
    out = [f"top {min(top, len(spans))} spans (of {len(spans)}):"]
    rows = [[e.get("name", "?"), str(e.get("cat", "?")),
             f"{e.get('dur', 0) / 1e6:.3f}s"]
            for e in spans[:top]]
    out.extend("  " + l for l in _table(rows, ["span", "cat", "dur"]))
    counts = span_counts(trace_obj)
    out.append("span counts: " + ", ".join(
        f"{cat}={n}" for cat, n in sorted(counts.items())))
    return "\n".join(out)


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("ledger", help="serialized ledger JSON file")
    ap.add_argument("--trace", default=None, metavar="TRACE.json",
                    help="also summarize a Chrome-trace file")
    ap.add_argument("--top", type=int, default=10,
                    help="spans to list from --trace (default 10)")
    args = ap.parse_args(argv)

    with open(args.ledger) as f:
        obj = json.load(f)
    print(render_ledger(obj))
    if args.trace:
        with open(args.trace) as f:
            trace_obj = json.load(f)
        print()
        print(render_trace_tops(trace_obj, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
