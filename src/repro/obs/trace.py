"""Span tracer: where a wave's wall-clock actually goes.

The streaming stack's cost story is the paper's cost story — eq. 5-8 price
*bytes*, and the overlap argument (§4.4: "hide load time behind compute")
is a claim about *time*.  ``MemoryMeter`` already audits the bytes; this
module audits the time: every hot phase (prefetch wait, wave solve, staged
reduction, checkpoint commit) runs inside a span, and the spans export to
Chrome-trace JSON (``obs.export``) so a run opens directly in Perfetto.

Two instruments, two costs:

- :class:`Tracer` — retains one event per span for export.  The default
  tracer is :data:`NULL_TRACER`, whose ``span()`` returns one shared no-op
  context manager: no allocation, no clock read, no lock.  Tracing is
  strictly opt-in (``--trace`` in the example / benchmark CLIs, or
  ``set_tracer`` in library use), so the fast test lane pays nothing.
- :class:`phase` — the always-on accounting the drivers use.  One clock
  pair per phase; the elapsed time lands in a ``MetricsRegistry`` (counter
  ``phase_seconds/<cat>`` + latency histogram ``<cat>_seconds``) and, when
  a real tracer is active, also becomes a span.  This is what makes
  ``StreamTelemetry.wall_seconds`` and the per-phase breakdowns available
  with tracing off — metrics are cheap per wave, spans are opt-in.

Spans are thread-aware: each records the OS thread it ran on, so the
prefetch worker's load spans interleave correctly with the consumer's
solve spans in the exported timeline (two tracks, overlapping — the
overlap IS the paper's preload win, made visible).

Category vocabulary (the span/metric catalog in OBSERVABILITY.md):

==================  =====================================================
category            what runs under it
==================  =====================================================
``driver``          one whole streaming run (its total is wall_seconds)
``iteration``       one ALS iteration / ``epoch`` one SGD epoch
``half``            one ALS half (solve-X / accumulate-Theta)
``solve``           one wave's compute+writeback — exactly one span per
                    wave consumed, so ``count(cat="solve") == waves_run``
``prefetch``        consumer-side queue wait (pipeline stall time)
``prefetch_load``   worker-side host->device load (overlapped time)
``reduce``          topology-aware reduction + post-reduce shard solves
``checkpoint``      one per-wave checkpoint commit (snapshot + enqueue)
``serve``           serving-engine prefill / decode steps
==================  =====================================================
"""
from __future__ import annotations

import functools
import os
import threading
import time
from typing import Optional


class SpanEvent:
    """One recorded event.  ``ph`` follows the Chrome trace vocabulary:
    ``X`` complete span, ``i`` instant, ``C`` counter sample.  ``ts``/
    ``dur`` are microseconds relative to the tracer's epoch."""

    __slots__ = ("name", "cat", "ph", "ts", "dur", "tid", "args")

    def __init__(self, name, cat, ph, ts, dur, tid, args):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.args = args

    def __repr__(self):
        return (f"SpanEvent({self.name!r}, cat={self.cat!r}, ph={self.ph!r},"
                f" ts={self.ts:.1f}, dur={self.dur:.1f}, tid={self.tid})")


class _NoopSpan:
    """The shared do-nothing context manager a disabled tracer hands out."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class NullTracer:
    """Disabled tracer: every call is a constant-time no-op.

    ``span()`` returns the one shared :data:`NOOP_SPAN` — no event list,
    no clock read — so instrumentation left in hot paths costs a method
    call and nothing else when tracing is off.
    """

    enabled = False

    def span(self, name: str, cat: str = "", **args):
        return NOOP_SPAN

    def instant(self, name: str, cat: str = "", **args) -> None:
        return None

    def counter(self, name: str, value, cat: str = "") -> None:
        return None

    def spans(self, cat: Optional[str] = None) -> list:
        return []


NULL_TRACER = NullTracer()


class _Span:
    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.record(self._name, self._cat, self._t0,
                            time.perf_counter(), self._args)
        return False


class Tracer:
    """Recording tracer: spans, instants, counter samples, per thread.

    Thread-safe by a single lock around the event list — spans are
    recorded at *exit* (one append per span), so the lock is never held
    across user code.  Timestamps are ``time.perf_counter()`` relative to
    the tracer's construction (``epoch``), exported as microseconds.
    """

    enabled = True

    def __init__(self):
        self.epoch = time.perf_counter()
        self.events: list[SpanEvent] = []
        self.thread_names: dict[int, str] = {}
        self._lock = threading.Lock()

    # -- recording ----------------------------------------------------
    def span(self, name: str, cat: str = "", **args):
        """Context manager timing one span; ``args`` become span tags."""
        return _Span(self, name, cat, args)

    def record(self, name: str, cat: str, t0: float, t1: float,
               args: Optional[dict] = None, ph: str = "X") -> None:
        """Record a pre-timed span (the ``phase`` helper's entry point)."""
        tid = threading.get_ident()
        ev = SpanEvent(name, cat, ph, (t0 - self.epoch) * 1e6,
                       (t1 - t0) * 1e6, tid, dict(args or ()))
        with self._lock:
            self.events.append(ev)
            if tid not in self.thread_names:
                self.thread_names[tid] = threading.current_thread().name

    def instant(self, name: str, cat: str = "", **args) -> None:
        t = time.perf_counter()
        self.record(name, cat, t, t, args, ph="i")

    def counter(self, name: str, value, cat: str = "") -> None:
        """One sample of a time-varying quantity (queue depth, occupancy);
        exports as a Chrome counter track."""
        t = time.perf_counter()
        self.record(name, cat, t, t, {"value": value}, ph="C")

    # -- queries ------------------------------------------------------
    def spans(self, cat: Optional[str] = None) -> list[SpanEvent]:
        """Completed spans (``ph == "X"``), optionally one category."""
        with self._lock:
            return [e for e in self.events
                    if e.ph == "X" and (cat is None or e.cat == cat)]


# ---------------------------------------------------------------------------
# Process-wide current tracer (what --trace installs)
# ---------------------------------------------------------------------------

_CURRENT: NullTracer | Tracer = NULL_TRACER


def set_tracer(tracer) -> NullTracer | Tracer:
    """Install the process-wide tracer; returns the previous one.
    Instrumented code that was not handed an explicit tracer picks this
    up via :func:`current_tracer`."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer if tracer is not None else NULL_TRACER
    return prev


def current_tracer() -> NullTracer | Tracer:
    return _CURRENT


def traced(name: Optional[str] = None, cat: str = ""):
    """Decorator form: run the wrapped function inside a span on the
    *current* tracer (resolved per call, so enabling tracing later works)."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with current_tracer().span(label, cat=cat):
                return fn(*a, **kw)
        return wrapper
    return deco


class phase:
    """Span + always-on metrics in one context manager.

    The drivers' instrumentation point: one ``perf_counter`` pair per
    phase, fed to (a) ``registry`` — counter ``phase_seconds/<cat>`` and
    histogram ``<cat>_seconds`` — and (b) ``tracer`` as a span when one is
    recording.  Either sink may be None.  This is the only sanctioned way
    to time code under ``src/repro/`` outside ``obs/`` (reprolint rule
    ``obs-routing`` enforces it).
    """

    __slots__ = ("_tracer", "_registry", "_name", "_cat", "_args", "_t0")

    def __init__(self, name: str, *, cat: str, tracer=None, registry=None,
                 **args):
        self._tracer = tracer
        self._registry = registry
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        reg = self._registry
        if reg is not None:
            reg.add_phase(self._cat, t1 - self._t0)
        tr = self._tracer
        if tr is not None and tr.enabled:
            tr.record(self._name, self._cat, self._t0, t1, self._args)
        return False


def process_id() -> int:
    """The pid the exporter stamps on events (one process per trace)."""
    return os.getpid()
