"""Metrics registry: counters, gauges, fixed-bucket histograms.

The numeric side of the observability layer.  Where ``obs.trace`` answers
"what ran when", the registry answers "how much, how often, how long" —
waves run, bytes streamed, per-wave solve latency, prefetch queue depth —
and it is *always on* in the drivers: one dict lookup and one add per
event, cheap enough that ``StreamTelemetry`` is now just a view over it
(``StreamTelemetry.from_registry``).

Thread-safety: the registry is written from the prefetch worker and the
consumer concurrently, so creation is guarded by a registry lock and each
instrument guards its own mutation.  Instruments are create-on-first-use
(``registry.counter("waves_run")``), prometheus-style.

Naming convention: ``<subsystem>/<what>`` for plain instruments
(``prefetch/items``), ``phase_seconds/<category>`` for the per-phase time
accounting the :class:`~repro.obs.trace.phase` helper feeds, and
``<category>_seconds`` for the matching latency histograms.
"""
from __future__ import annotations

import bisect
import threading
from typing import Optional, Sequence

#: default latency buckets (seconds): 1 ms .. 100 s, ~3x steps — wide
#: enough for a CI smoke wave and a real-scale streaming wave alike
DEFAULT_LATENCY_BUCKETS = (0.001, 0.003, 0.01, 0.03, 0.1, 0.3,
                           1.0, 3.0, 10.0, 30.0, 100.0)


class Counter:
    """Monotonically increasing value (float so second-counters fit)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-set value, with the running max kept for peak-style reads."""

    __slots__ = ("_lock", "value", "max")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0
        self.max = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v
            if v > self.max:
                self.max = v


class Histogram:
    """Fixed-bucket histogram with less-or-equal bucket semantics.

    ``edges`` are the inclusive upper bounds: an observation ``v`` lands
    in the first bucket with ``v <= edges[i]``; anything above the last
    edge lands in the overflow bucket (``counts[-1]``), so ``counts`` has
    ``len(edges) + 1`` entries and every observation is counted exactly
    once.  ``sum``/``count`` give the mean without bucket math.
    """

    __slots__ = ("_lock", "edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float]):
        assert edges, "histogram needs at least one bucket edge"
        se = tuple(float(e) for e in edges)
        assert se == tuple(sorted(se)) and len(set(se)) == len(se), \
            f"bucket edges must be strictly increasing, got {edges}"
        self._lock = threading.Lock()
        self.edges = se
        self.counts = [0] * (len(se) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Create-on-first-use instrument registry (one per streaming run)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(
                    edges if edges is not None else DEFAULT_LATENCY_BUCKETS)
            elif edges is not None:
                assert h.edges == tuple(float(e) for e in edges), \
                    (f"histogram {name!r} already registered with edges "
                     f"{h.edges}, asked for {tuple(edges)}")
            return h

    # -- the phase-accounting hook obs.trace.phase drives ---------------
    def add_phase(self, category: str, seconds: float) -> None:
        """One completed phase: total seconds per category + a latency
        sample (``phase_seconds/<cat>`` counter, ``<cat>_seconds``
        histogram)."""
        self.counter(f"phase_seconds/{category}").inc(seconds)
        self.histogram(f"{category}_seconds").observe(seconds)

    def phase_seconds(self) -> dict[str, float]:
        """``{category: total seconds}`` across every phase seen so far."""
        with self._lock:
            items = list(self._counters.items())
        pre = "phase_seconds/"
        return {name[len(pre):]: c.value for name, c in items
                if name.startswith(pre)}

    def snapshot(self) -> dict:
        """Plain-data dump (JSON-ready) of every instrument — what the
        exporter embeds next to the trace events."""
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: {"value": g.value, "max": g.max}
                      for k, g in self._gauges.items()}
            hists = {k: {"edges": list(h.edges), "counts": list(h.counts),
                         "sum": h.sum, "count": h.count}
                     for k, h in self._histograms.items()}
        return {"counters": counters, "gauges": gauges, "histograms": hists}
