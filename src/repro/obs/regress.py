"""Exit-coded regression gate over bench history and ledger files.

::

    python -m repro.obs.regress --history BENCH_HISTORY.jsonl [--window 5]
    python -m repro.obs.regress --ledger LEDGER_outofcore.json
    python -m repro.obs.regress --history ... --ledger ... --strict-times

Two gates, both designed for CI:

- **History** (``--history``): each line of the JSONL file is one bench
  emission (``benchmarks/history.py`` appends them with provenance).  For
  every bench name, the newest entry is compared row-by-row against a
  rolling baseline built from up to ``--window`` prior entries of the same
  configuration (same quick flag / backend / device count).  Metrics are
  classified by key:

  - *deterministic* (bytes, waves, slots, nnz, counts, shapes) must match
    the baseline **exactly** — they are pure functions of the store shapes,
    so any drift is a real behavior change and fails the gate;
  - *time-like* metrics (seconds, rates) are compared against the rolling
    median with a relative threshold (``--time-tol``) and only **warn** by
    default (CI machines are noisy); ``--strict-times`` promotes them;
  - everything else (RMSE, ratios) warns beyond ``--noise-tol``.

  A bench with no baseline yet passes (first run seeds the history).

- **Ledger** (``--ledger``, repeatable): validates the file against the
  :mod:`repro.obs.ledger` schema (which recomputes every verdict) and
  fails on any error-severity record whose check does not hold — a seeded
  or real mis-prediction exits nonzero.

Exit code 0 = clean (warnings allowed), 1 = hard failure.  Stdlib-only.
"""
from __future__ import annotations

import argparse
import json
import re
import statistics
from typing import Optional

from repro.obs.ledger import validate_ledger

HISTORY_SCHEMA = "repro.obs/bench-history-v1"

#: metric keys that are deterministic functions of the problem shapes —
#: exact-match across runs of the same code, or the gate fails
DETERMINISTIC_RE = re.compile(
    r"(bytes|waves|batches|slots|nnz|epochs|iters|count|^m$|^n$|^f$|^p$"
    r"|^q$|^g$|^k$|n_data|mesh_shape|^fits$|fill_waste)", re.IGNORECASE)
#: wall-clock-derived keys — noisy, warn-only unless --strict-times
TIME_RE = re.compile(
    r"(seconds|_s$|_per_sec|per_iter_s|^t$|time)", re.IGNORECASE)
#: metered peaks depend on prefetch-pipeline timing (how many buffers were
#: simultaneously live), so they are bounded, not deterministic
NOISY_OVERRIDE_RE = re.compile(r"peak", re.IGNORECASE)
#: keys never compared (identity / bookkeeping)
SKIP_KEYS = frozenset({"provenance", "curve", "ledger", "name", "solver"})


def classify(key: str) -> str:
    if NOISY_OVERRIDE_RE.search(key):
        return "noisy"
    if TIME_RE.search(key):        # before DETERMINISTIC: epochs_per_sec
        return "time"
    if DETERMINISTIC_RE.search(key):
        return "deterministic"
    return "noisy"


def load_history(path: str) -> list[dict]:
    entries = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if obj.get("schema") != HISTORY_SCHEMA:
                raise ValueError(
                    f"{path}:{lineno}: schema {obj.get('schema')!r} != "
                    f"{HISTORY_SCHEMA!r}")
            entries.append(obj)
    return entries


def _config_key(entry: dict) -> tuple:
    prov = entry.get("provenance", {})
    return (entry.get("bench"), prov.get("quick"),
            prov.get("backend"), prov.get("device_count"))


def _row_key(row: dict) -> str:
    return str(row.get("name") or row.get("solver") or "?")


def _flatten(row: dict, prefix: str = "") -> dict:
    """Numeric leaves of one bench row, dotted keys for nested dicts
    (``phase_seconds.solve``); skip-listed and non-numeric leaves drop."""
    out = {}
    for key, val in row.items():
        if key in SKIP_KEYS:
            continue
        name = f"{prefix}{key}"
        if isinstance(val, bool):
            out[name] = int(val)
        elif isinstance(val, (int, float)):
            out[name] = val
        elif isinstance(val, dict):
            out.update(_flatten(val, prefix=name + "."))
    return out


def compare_history(entries: list[dict], *, window: int = 5,
                    time_tol: float = 0.5, noise_tol: float = 0.05,
                    strict_times: bool = False) -> tuple[list[str], int]:
    """(report lines, hard-failure count) of newest-vs-baseline per bench."""
    lines: list[str] = []
    failures = 0
    by_cfg: dict[tuple, list[dict]] = {}
    for entry in entries:
        by_cfg.setdefault(_config_key(entry), []).append(entry)

    for cfg, group in sorted(by_cfg.items(), key=lambda kv: str(kv[0])):
        newest, prior = group[-1], group[-window - 1:-1]
        label = f"{cfg[0]} (quick={cfg[1]}, backend={cfg[2]}, dev={cfg[3]})"
        if not prior:
            lines.append(f"SEED {label}: first run, no baseline yet")
            continue
        new_rows = {_row_key(r): _flatten(r) for r in newest["records"]}
        base_rows: dict[str, dict[str, list]] = {}
        for entry in prior:
            for row in entry["records"]:
                metrics = base_rows.setdefault(_row_key(row), {})
                for key, val in _flatten(row).items():
                    metrics.setdefault(key, []).append(val)
        checked = 0
        for rkey, metrics in sorted(new_rows.items()):
            base = base_rows.get(rkey)
            if base is None:
                lines.append(f"NEW  {label} :: {rkey}: no baseline row")
                continue
            for mkey, val in sorted(metrics.items()):
                hist = base.get(mkey)
                if not hist:
                    continue
                checked += 1
                kind = classify(mkey)
                if kind == "deterministic":
                    ref = hist[-1]       # exact lineage, not a median
                    if val != ref:
                        failures += 1
                        lines.append(
                            f"FAIL {label} :: {rkey}.{mkey}: {val} != "
                            f"baseline {ref} (deterministic metric drifted)")
                    continue
                ref = statistics.median(hist)
                tol = time_tol if kind == "time" else noise_tol
                if ref == 0:
                    drifted = abs(val) > tol
                    desc = f"{val} vs baseline 0"
                else:
                    rel = (val - ref) / abs(ref)
                    drifted = abs(rel) > tol
                    desc = f"{val:.6g} vs median {ref:.6g} ({rel:+.1%})"
                if drifted:
                    hard = strict_times if kind == "time" else False
                    failures += 1 if hard else 0
                    lines.append(
                        f"{'FAIL' if hard else 'WARN'} {label} :: "
                        f"{rkey}.{mkey}: {desc} beyond {tol:.0%}")
        lines.append(f"OK   {label}: {checked} metrics vs "
                     f"{len(prior)}-run baseline")
    return lines, failures


def check_ledger(path: str) -> tuple[list[str], int]:
    """(report lines, hard-failure count) for one serialized ledger."""
    lines: list[str] = []
    with open(path) as f:
        obj = json.load(f)
    try:
        summary = validate_ledger(obj)
    except ValueError as e:
        return [f"FAIL {path}: {e}"], 1
    failures = summary["errors"]
    for rec in obj["records"]:
        if rec["ok"]:
            continue
        tag = "FAIL" if rec["severity"] == "error" else "WARN"
        lines.append(
            f"{tag} {path} :: {rec['name']}: predicted={rec['predicted']} "
            f"measured={rec['measured']} (check={rec['check']}, "
            f"drift={rec['drift']})")
    lines.append(f"{'FAIL' if failures else 'OK  '} {path}: "
                 f"{summary['records']} records, {failures} error flag(s), "
                 f"{summary['warnings']} warn flag(s)")
    return lines, failures


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--history", default=None, metavar="JSONL",
                    help="bench history file to gate (BENCH_HISTORY.jsonl)")
    ap.add_argument("--ledger", action="append", default=[],
                    metavar="JSON", help="ledger file to gate (repeatable)")
    ap.add_argument("--window", type=int, default=5,
                    help="rolling-baseline size (default 5 prior runs)")
    ap.add_argument("--time-tol", type=float, default=0.5,
                    help="relative threshold for time metrics (default 0.5)")
    ap.add_argument("--noise-tol", type=float, default=0.05,
                    help="relative threshold for other noisy metrics")
    ap.add_argument("--strict-times", action="store_true",
                    help="promote time-metric drift from warn to fail")
    args = ap.parse_args(argv)
    if not args.history and not args.ledger:
        ap.error("nothing to check: pass --history and/or --ledger")

    failures = 0
    if args.history:
        entries = load_history(args.history)
        lines, n = compare_history(
            entries, window=args.window, time_tol=args.time_tol,
            noise_tol=args.noise_tol, strict_times=args.strict_times)
        failures += n
        print(f"history: {len(entries)} run(s) in {args.history}")
        for line in lines:
            print(" " + line)
    for path in args.ledger:
        lines, n = check_ledger(path)
        failures += n
        for line in lines:
            print(line)
    print(f"regress: {'FAIL' if failures else 'PASS'} "
          f"({failures} hard failure(s))")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
