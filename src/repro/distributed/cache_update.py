"""Sharded KV-cache writes for decode.

GSPMD handles a per-row dynamic scatter into a sequence- or kv-sharded
cache by "involuntary full rematerialization" — it replicates the whole
multi-TB cache on every device (observed in the dry-run: +17..31 GiB of
temp).  Under ``shard_map`` the write is local arithmetic: each shard
checks whether the target position falls inside its slice and writes (or
keeps) its rows — zero communication, zero replication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat


def _local_write_seq(kc, kn, ln, offset):
    """kc [B, S_loc, KV, dh]; kn [B, KV, dh]; ln [B] global positions;
    offset: global index of this shard's first slot (scalar)."""
    s_loc = kc.shape[1]
    pos = ln - offset
    ok = (pos >= 0) & (pos < s_loc)
    pos_c = jnp.clip(pos, 0, s_loc - 1)
    bidx = jnp.arange(kc.shape[0])
    cur = kc[bidx, pos_c]
    new = jnp.where(ok[:, None, None], kn.astype(kc.dtype), cur)
    return kc.at[bidx, pos_c].set(new)


def cache_write(kc, kn, lengths, *, mesh=None, dp=None,
                seq_axis: str | None = None, kv_axis: str | None = None):
    """Write one token into the cache at per-row ``lengths``.

    kc [B, S, KV, dh]; kn [B, KV, dh]; layouts:
      - seq_axis: cache sequence dim sharded over that mesh axis,
      - kv_axis:  cache KV-head dim sharded over that mesh axis,
      - dp:       batch axes (or None = replicated batch).
    """
    if mesh is None:
        bidx = jnp.arange(kc.shape[0])
        return kc.at[bidx, lengths].set(kn.astype(kc.dtype))

    manual = set()
    if dp:
        manual |= set(dp if isinstance(dp, tuple) else (dp,))
    if seq_axis:
        manual.add(seq_axis)
    if kv_axis:
        manual.add(kv_axis)
    if not manual:
        manual = {"model"}   # run local on a trivial manual axis set

    cache_spec = P(dp, seq_axis, kv_axis, None)
    new_spec = P(dp, kv_axis, None)
    len_spec = P(dp)

    # shard offsets come from a sharded iota, not lax.axis_index: the
    # PartitionId instruction it lowers to breaks the XLA SPMD partitioner
    # in large unrolled programs ("meaning is ambiguous" UNIMPLEMENTED)
    pos_iota = jnp.arange(kc.shape[1], dtype=jnp.int32)

    def body(kc_loc, kn_loc, ln_loc, pos_loc):
        return _local_write_seq(kc_loc, kn_loc, ln_loc, pos_loc[0])

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(cache_spec, new_spec, len_spec, P(seq_axis)),
        out_specs=cache_spec,
        axis_names=manual, check_vma=False,
    )(kc, kn, lengths, pos_iota)
