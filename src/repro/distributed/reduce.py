"""Topology-aware staged reduction for host-combined partial sums (§4.2).

``distributed.collectives`` maps the paper's two-phase reduction onto XLA
collectives *inside* a jitted program.  The streaming drivers need the same
scheme one level up: each simulated data-shard device accumulates its own
partial Hermitians across waves, and the *host* combines the per-device
partials once per half-iteration — exactly the explicitly-scheduled
reduction of the paper's Fig. 5, where the host drives which PCIe links
carry which partial when.

``topology_reduce`` executes that schedule deterministically:

- **stage 1 (intra-group ring)**: within each fast domain (PCIe socket /
  ICI pod) the members' partials are folded in ascending device order —
  the ring pass where every fast link is busy and no traffic leaves the
  domain.
- **stage 2 (inter-group tree)**: the group partials are combined in
  pairwise tree rounds (ascending group order), so each slow link crosses
  once per round with already-reduced data — the paper's
  intra-socket-then-inter-socket scheme.

All arithmetic is float64.  The partials the drivers feed in are float32
device results; a float64 sum of float32 summands is exact (hence
association-independent) as long as their exponent spread stays under the
~29 binades of f64 headroom — the regime of same-matrix Hermitian partials.
That is what makes the scheme *testably* correct: ``topology_reduce`` must
match ``allreduce_oracle`` (the naive flat fold) bit for bit, for any
grouping, which the mesh-streaming suite pins down.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """Declared fast-domain grouping of the devices on a reduction axis.

    ``groups[s]`` holds the device ids sharing fast links (one PCIe socket
    in the paper, one ICI pod on a TPU).  Groups must be disjoint and cover
    ``0..n_devices-1``; order within a group is normalized to ascending so
    the reduction schedule depends only on the declared topology, never on
    how the caller happened to spell it.
    """

    groups: tuple[tuple[int, ...], ...]

    def __post_init__(self):
        norm = tuple(tuple(sorted(int(d) for d in g)) for g in self.groups)
        object.__setattr__(self, "groups", norm)
        flat = [d for g in norm for d in g]
        assert flat, "topology must contain at least one device"
        assert sorted(flat) == list(range(len(flat))), \
            f"groups must disjointly cover 0..n-1, got {norm}"

    @property
    def n_devices(self) -> int:
        return sum(len(g) for g in self.groups)

    def describe(self) -> str:
        return "topology[" + " | ".join(
            ",".join(str(d) for d in g) for g in self.groups) + "]"


def linear_topology(n_devices: int, group_size: int = 2) -> DeviceTopology:
    """Consecutive device ids grouped into fast domains of ``group_size``
    (the paper's machine: 2 GPUs per PCIe switch, 2 switches per node)."""
    assert n_devices >= 1 and group_size >= 1, (n_devices, group_size)
    return DeviceTopology(tuple(
        tuple(range(s, min(s + group_size, n_devices)))
        for s in range(0, n_devices, group_size)))


def allreduce_oracle(parts: Sequence[np.ndarray]) -> np.ndarray:
    """The naive all-reduce: one flat left fold over ascending device ids,
    in float64 — the reference ``topology_reduce`` is validated against."""
    out = np.asarray(parts[0], np.float64).copy()
    for part in parts[1:]:
        out += np.asarray(part, np.float64)
    return out


def topology_reduce(parts: Sequence[np.ndarray],
                    topo: DeviceTopology | None = None,
                    tracer=None) -> np.ndarray:
    """Staged ring/tree reduction of per-device partials (float64).

    ``parts[d]`` is device ``d``'s partial.  ``topo`` defaults to one flat
    group (pure ring).  The schedule is a pure function of the topology, so
    repeated runs — and runs from differently-ordered host containers, as
    long as indexing by device id is preserved — are bit-identical.

    With ``tracer`` set (an enabled ``obs.Tracer``) each stage records a
    ``reduce`` span tagged with the bytes it moves and which link class
    carries them — the ring stage with its fast-domain traffic, every tree
    round with its slow-link crossings — so a trace shows where the
    reduction's wall time and bytes actually went.
    """
    if topo is None:
        topo = linear_topology(len(parts), group_size=len(parts))
    assert topo.n_devices == len(parts), (topo.n_devices, len(parts))
    traced = tracer is not None and getattr(tracer, "enabled", False)
    nbytes = int(np.asarray(parts[0]).nbytes)
    traffic = reduce_traffic(nbytes, topo) if traced else None
    # stage 1: intra-group ring — ascending fold inside each fast domain
    if traced:
        with tracer.span("reduce.ring", cat="reduce", stage="ring",
                         link="fast", groups=len(topo.groups),
                         bytes=traffic["fast_link_bytes"]):
            stage = [allreduce_oracle([parts[d] for d in g])
                     for g in topo.groups]
    else:
        stage = [allreduce_oracle([parts[d] for d in g])
                 for g in topo.groups]
    # stage 2: inter-group tree — pairwise rounds over group partials
    rnd = 0
    while len(stage) > 1:
        rnd += 1
        nxt = []
        if traced:
            pairs = len(stage) // 2
            with tracer.span("reduce.tree", cat="reduce", stage="tree",
                             link="slow", round=rnd,
                             bytes=nbytes * pairs):
                for i in range(0, len(stage) - 1, 2):
                    nxt.append(stage[i] + stage[i + 1])
        else:
            for i in range(0, len(stage) - 1, 2):
                nxt.append(stage[i] + stage[i + 1])
        if len(stage) % 2:
            nxt.append(stage[-1])
        stage = nxt
    return stage[0]


def reduce_traffic(nbytes: int, topo: DeviceTopology) -> dict:
    """Analytic per-stage traffic of one ``topology_reduce`` for an
    ``nbytes`` partial, next to the flat all-reduce it replaces.

    Ring stage: the fold inside a fast domain of size k moves k-1 full
    partials ((k-1)/k * nbytes per device, k devices), over fast links
    only.  Tree stage: one already-reduced ``nbytes`` partial crosses a
    slow link per surviving pair and round — G-1 crossings total for G
    domains.  The flat scheme instead moves D-1 full partials across
    whatever link is in the way, slow links included — the paper's
    Fig. 5a vs 5b contrast (a single flat domain makes the staged and
    flat schemes identical, so their byte counts coincide).
    """
    groups = topo.groups
    d_total = topo.n_devices
    fast = sum(int(nbytes) * (len(g) - 1) for g in groups)
    slow = int(nbytes) * (len(groups) - 1)
    flat = int(nbytes) * (d_total - 1)
    return {
        "fast_link_bytes": fast,
        "slow_link_bytes": slow,
        "flat_all_links_bytes": flat,
        "slow_link_crossings": len(groups) - 1,
    }
