"""SU-ALS (paper Alg. 3) under shard_map: data + model parallel ALS.

Axis mapping (paper -> mesh):

- cuMF's **p** (Theta column shards; each GPU computes *partial* A_u, B_u
  from only its local theta_v — eq. 5-7)  ==  the ``"model"`` mesh axis, and
  jointly ``("pod", "model")`` on the multi-pod mesh.
- cuMF's **q** (X row partitions, solved independently) == the ``"data"``
  mesh axis; q beyond the axis size runs in waves (out-of-core batching).

One update-X step inside shard_map (update-Theta is symmetric):

  1. local fused hermitian:  A_i, B_i from local columns        (Alg.3 L11)
  2. parallel reduction:     psum_scatter over the column axes  (L13-16,
     Fig. 5a == one-phase; model-then-pod == two-phase Fig. 5b)
  3. local batch solve on the owned row slice                   (L17)
  4. all_gather the solved slices back                          (L19)

The synchronization barrier of Alg. 3 line 12 is implicit in the dataflow.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.kernels import ops as kops


def _col_axes(mesh: Mesh) -> tuple[tuple[str, ...], object]:
    """(col_axes, col_dim spec entry) — cuMF's p axes, fast -> slow."""
    col_axes = tuple(a for a in ("model", "pod") if a in mesh.axis_names)
    col_dim = col_axes[::-1] if len(col_axes) > 1 else col_axes[0]
    return col_axes, col_dim


def su_als_update(
    theta_loc: jax.Array,   # [n_loc, f]   local Theta column shard (rows here)
    idx_loc: jax.Array,     # [m_loc, K]   shard-local padded indices
    val_loc: jax.Array,     # [m_loc, K]
    cnt_loc: jax.Array,     # [m_loc]      *local* nnz counts
    lam: float,
    *,
    col_axes: tuple[str, ...] = ("model",),   # cuMF p axes, fast -> slow
    scheme: str = "two_phase",                # "one_phase" | "two_phase"
    mode: str = "ref",
    tm: int = 8, tk: int = 128, tb: int = 8, f_mult: int = 128,
    row_block: int = 0,
) -> jax.Array:
    """Runs inside shard_map.  Returns x_loc [m_loc, f] (replicated over col_axes).

    ``row_block`` > 0 processes rows in blocks of that size (cuMF's m_b
    batching, Table 3): bounds the live Hermitian buffer at
    row_block * f^2 floats and pipelines reduction with compute."""
    if row_block and row_block < idx_loc.shape[0]:
        m_loc = idx_loc.shape[0]
        assert m_loc % row_block == 0, (m_loc, row_block)
        nb = m_loc // row_block
        blk = lambda a: a.reshape((nb, row_block) + a.shape[1:])

        def one(args):
            i, v, c = args
            return su_als_update(
                theta_loc, i, v, c, lam, col_axes=col_axes, scheme=scheme,
                mode=mode, tm=tm, tk=tk, tb=tb, f_mult=f_mult, row_block=0)

        out = lax.map(one, (blk(idx_loc), blk(val_loc), blk(cnt_loc)))
        return out.reshape(m_loc, -1)
    # (1) local partial Hermitians — eq. (5)-(7)
    A, B = kops.fused_herm(
        theta_loc, idx_loc, val_loc, cnt_loc, lam,
        mode=mode, tm=tm, tk=tk, f_mult=f_mult, diag_fallback=False)
    cnt_f = cnt_loc.astype(jnp.float32)

    # (2) parallel reduction of partial results — paper §4.2
    if scheme == "one_phase" or len(col_axes) == 1:
        # Fig. 5a: single reduce-scatter over the (joint) column axis.
        axes = col_axes if len(col_axes) > 1 else col_axes[0]
        A_r = lax.psum_scatter(A, axes, scatter_dimension=0, tiled=True)
        B_r = lax.psum_scatter(B, axes, scatter_dimension=0, tiled=True)
        c_r = lax.psum_scatter(cnt_f, axes, scatter_dimension=0, tiled=True)
    else:
        # Fig. 5b: two-phase, topology-aware — scatter over the fast
        # intra-pod axis first; only 1/p_fast-sized slices cross the slow link.
        fast, slow = col_axes[0], col_axes[1]
        A_r = lax.psum_scatter(A, fast, scatter_dimension=0, tiled=True)
        B_r = lax.psum_scatter(B, fast, scatter_dimension=0, tiled=True)
        c_r = lax.psum_scatter(cnt_f, fast, scatter_dimension=0, tiled=True)
        A_r = lax.psum_scatter(A_r, slow, scatter_dimension=0, tiled=True)
        B_r = lax.psum_scatter(B_r, slow, scatter_dimension=0, tiled=True)
        c_r = lax.psum_scatter(c_r, slow, scatter_dimension=0, tiled=True)

    # singular guard for globally-empty rows (x_u = 0)
    f = A_r.shape[-1]
    empty = (c_r <= 0).astype(A_r.dtype)
    A_r = A_r + empty[:, None, None] * jnp.eye(f, dtype=A_r.dtype)

    # (3) solve owned slice — Alg. 3 line 17, p-way parallel batch_solve
    x_slice = kops.batch_solve(A_r, B_r, mode=mode, tb=tb)

    # (4) collect solved slices — Alg. 3 line 19
    if scheme == "one_phase" or len(col_axes) == 1:
        axes = col_axes if len(col_axes) > 1 else col_axes[0]
        x_loc = lax.all_gather(x_slice, axes, axis=0, tiled=True)
    else:
        x_loc = lax.all_gather(x_slice, col_axes[1], axis=0, tiled=True)
        x_loc = lax.all_gather(x_loc, col_axes[0], axis=0, tiled=True)
    return x_loc


def make_su_als_fns(
    mesh: Mesh,
    lam: float,
    *,
    scheme: str = "two_phase",
    mode: str = "ref",
    tm: int = 8, tk: int = 128, tb: int = 8, f_mult: int = 128,
    row_block: int = 0,
):
    """Build (update_x, update_theta, iteration) jitted on ``mesh``.

    Expected global layouts (see repro.sparse.partition_padded):
      R rows grid:   idx/val [m, P*K] rows over "data", col blocks over col axes
                     cnt    [m, P]
      R^T rows grid: idxT/valT [n, P*KT] rows over "data", cols over col axes
      theta [n, f]: rows over col axes (the fixed side of update-X)
      x     [m, f]: rows over col axes (the fixed side of update-Theta)
    Returned factors are row-sharded over "data".
    """
    col_axes = tuple(a for a in ("model", "pod") if a in mesh.axis_names)
    # fast axis first (intra-pod "model"), then slow ("pod")
    update = functools.partial(
        su_als_update, lam=lam, col_axes=col_axes, scheme=scheme,
        mode=mode, tm=tm, tk=tk, tb=tb, f_mult=f_mult, row_block=row_block)

    cols_spec = col_axes if len(col_axes) == 1 else (col_axes[::-1],)
    # column-block dim of R shards over (pod, model): pod-major ordering
    col_dim = col_axes[::-1] if len(col_axes) > 1 else col_axes[0]

    in_specs = (
        P(col_dim, None),        # theta_loc: rows sharded over column axes
        P("data", col_dim),      # idx
        P("data", col_dim),      # val
        P("data", col_dim),      # cnt [m, P]
    )
    out_spec = P("data", None)

    def _wrap(theta, idx, val, cnt):
        def inner(t, i, v, c):
            return update(t, i, v, c[:, 0])
        return compat.shard_map(
            inner, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
            check_vma=False,
        )(theta, idx, val, cnt)

    data_rows = NamedSharding(mesh, P("data", None))
    col_rows = NamedSharding(mesh, P(col_dim, None))

    @functools.partial(jax.jit, out_shardings=data_rows)
    def update_x(theta, idx, val, cnt):
        return _wrap(theta, idx, val, cnt)

    @functools.partial(jax.jit, out_shardings=data_rows)
    def update_theta(x, idxT, valT, cntT):
        return _wrap(x, idxT, valT, cntT)

    @jax.jit
    def iteration(x, theta, r, rt):
        """One full ALS iteration; factors come in and leave row-sharded
        over "data"; the reshard to column-axis rows between half-steps is
        an explicit constraint (XLA inserts the all-to-all)."""
        theta_c = lax.with_sharding_constraint(theta, col_rows)
        x_new = _wrap(theta_c, *r)
        x_c = lax.with_sharding_constraint(x_new, col_rows)
        theta_new = _wrap(x_c, *rt)
        x_out = lax.with_sharding_constraint(x_new, data_rows)
        t_out = lax.with_sharding_constraint(theta_new, data_rows)
        return x_out, t_out

    return update_x, update_theta, iteration


def make_wave_update_fn(
    mesh: Mesh,
    lam: float,
    *,
    scheme: str = "two_phase",
    mode: str = "ref",
    tm: int = 8, tk: int = 128, tb: int = 8, f_mult: int = 128,
    row_block: int = 0,
):
    """Per-slice update entry point for the out-of-core wave driver.

    Bridges one host-resident wave slice onto the mesh: the slice's rating
    arrays (in the ``shard_ratings`` layout — idx/val ``[m_slice, P*K]``,
    cnt ``[m_slice, P]``) are placed row-sharded over ``"data"`` so each
    device on the axis takes one q-batch of the wave, the fixed factor is
    placed over the column axes, the shard-mapped SU-ALS update runs, and
    the solved rows come back to host for the driver to write into its
    factor store.  ``m_slice`` must divide the "data" axis size.
    """
    update_x, _, _ = make_su_als_fns(
        mesh, lam, scheme=scheme, mode=mode,
        tm=tm, tk=tk, tb=tb, f_mult=f_mult, row_block=row_block)
    col_axes, col_dim = _col_axes(mesh)
    rows_sh = NamedSharding(mesh, P("data", col_dim))
    fixed_sh = NamedSharding(mesh, P(col_dim, None))

    def update_slice(fixed, idx, val, cnt):
        fixed_d = jax.device_put(fixed, fixed_sh)
        idx_d = jax.device_put(idx, rows_sh)
        val_d = jax.device_put(val, rows_sh)
        cnt_d = jax.device_put(cnt, rows_sh)
        return np.asarray(update_x(fixed_d, idx_d, val_d, cnt_d))

    return update_slice


def make_wave_herm_fn(
    mesh: Mesh,
    lam: float,
    *,
    mode: str = "ref",
    tm: int = 8, tk: int = 128, f_mult: int = 128,
):
    """Accumulate-Theta mesh entry point for the out-of-core wave driver.

    One call computes the partial Hermitians of one wave on the real mesh:
    device (d, k) holds data-shard ``d``'s fresh X slice plus *only* model
    shard ``k``'s rows of that batch's R^T shard, and produces the partial
    (A, B) for its owned theta rows (eq. 5-7 with the weighted-lambda
    diagonal, which telescopes over data shards).  Crucially there is **no
    cross-device reduction inside the program**: the per-data-shard partials
    come back to the host with the "data" axis intact, where the driver
    accumulates them across waves and combines them once per half-iteration
    through ``distributed.reduce.topology_reduce`` — the paper's explicitly
    host-scheduled Fig. 5 reduction, rather than an opaque psum.

    Expected stacks (host or device):
      x_stack [n_data, rows, f]   fresh X slices, one per data shard
      idxT/valT [n_data, n, K]    R^T shards, theta rows over the col axes
      cntT   [n_data, n]          per-shard local nnz counts
    Returns host (A [n_data, n, f, f], B [n_data, n, f]) float32 partials.
    """
    _, col_dim = _col_axes(mesh)
    in_specs = (
        P("data", None, None),       # x_stack: replicated over col axes
        P("data", col_dim, None),    # idxT: theta rows over col axes
        P("data", col_dim, None),    # valT
        P("data", col_dim),          # cntT
    )
    out_specs = (P("data", col_dim, None, None),   # A partials, un-reduced
                 P("data", col_dim, None))         # B partials

    def inner(x_loc, i_loc, v_loc, c_loc):
        # diag_fallback=False: a locally-empty theta row may be nonempty
        # globally — the guard is applied after the topology reduce
        A, B = kops.fused_herm(
            x_loc[0], i_loc[0], v_loc[0], c_loc[0], lam,
            mode=mode, tm=tm, tk=tk, f_mult=f_mult, diag_fallback=False)
        return A[None], B[None]

    mapped = jax.jit(compat.shard_map(
        inner, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False))
    x_sh = NamedSharding(mesh, P("data", None, None))
    rt_sh = NamedSharding(mesh, P("data", col_dim, None))
    cnt_sh = NamedSharding(mesh, P("data", col_dim))

    def herm_stack(x_stack, idxT, valT, cntT):
        A, B = mapped(jax.device_put(x_stack, x_sh),
                      jax.device_put(idxT, rt_sh),
                      jax.device_put(valT, rt_sh),
                      jax.device_put(cntT, cnt_sh))
        return np.asarray(A), np.asarray(B)

    return herm_stack


def shard_ratings(ell_parts, mesh: Mesh):
    """partition_padded output ([P, m, K] arrays) -> device arrays laid out
    for make_su_als_fns: idx/val [m, P*K] and cnt [m, P] with the right
    NamedSharding placements."""
    col_axes, col_dim = _col_axes(mesh)
    Pn, m, K = ell_parts.idx.shape
    idx = np.transpose(ell_parts.idx, (1, 0, 2)).reshape(m, Pn * K)
    val = np.transpose(ell_parts.val, (1, 0, 2)).reshape(m, Pn * K)
    cnt = np.transpose(ell_parts.cnt, (1, 0)).reshape(m, Pn)
    sh = NamedSharding(mesh, P("data", col_dim))
    return (jax.device_put(idx, sh), jax.device_put(val, sh),
            jax.device_put(cnt, sh))
