"""Sharding policies for the LM stack (logical-axis rules, t5x-style).

Every parameter is created together with a tuple of *logical* axis names
(see models/transformer.py); a policy maps logical names to mesh axes.

Policies:
- TRAIN   : FSDP + TP.  Weight matrices are 2D-sharded ("d_model" over the
            data axis, "ff"/"heads" over the model axis) so a 123B model's
            optimizer state divides by the full chip count; GSPMD inserts
            the ZeRO-3 all-gathers / reduce-scatters inside the layer scan.
- SERVE   : TP only on the model axis (weights replicated over data so
            decode needs no per-step param all-gathers); the big MLPs of
            >=100B models are 2D-sharded over (data, model) instead.
- The batch ("dp") axes are ("pod", "data") when the pod axis exists.

Archs whose head counts do not divide the model axis (musicgen 24H,
qwen1.5 20H, recurrentgemma 10H) zero-pad q (and, for MHA, kv) heads to the
next multiple of 16 — exact function, bounded extra projection flops.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The pure-data-parallel axes of a mesh: ('pod','data') or ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """logical axis name -> mesh axis (or None = replicate)."""
    rules: Mapping[str, Optional[str | tuple[str, ...]]]
    name: str = "custom"

    def spec(self, logical_axes: tuple[str | None, ...]) -> P:
        return P(*(self.rules.get(a) if a is not None else None
                   for a in logical_axes))

    def with_overrides(self, name=None, **overrides) -> "ShardingPolicy":
        rules = dict(self.rules)
        rules.update(overrides)
        return ShardingPolicy(rules=rules, name=name or self.name)


def train_policy(mesh: Mesh, *, tp_heads: bool, tp_kv: bool,
                 fsdp: bool = True) -> ShardingPolicy:
    fs = "data" if fsdp else None
    return ShardingPolicy(name="train", rules={
        "layers": None,
        "vocab": "model",
        "embed_d": fs,
        "d_model_in": fs,
        "d_model_out": fs,
        "attn_din": fs,
        "attn_dout": fs,
        "qheads": "model" if tp_heads else None,
        "kv_heads": "model" if tp_kv else None,
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "rnn": "model",
        "norm": None,
        "lora": None,
    })


def serve_policy(mesh: Mesh, *, tp_heads: bool, tp_kv: bool,
                 mlp_2d: bool = False, seq_shard_cache: bool = False
                 ) -> ShardingPolicy:
    """TP-only policy for decode.  ``mlp_2d`` spreads the FFN over
    (data, model) jointly (needed for >=100B params to fit without FSDP
    gathers); ``seq_shard_cache`` pairs with flash-decode (attention
    projections replicated, KV cache sharded on sequence over "model")."""
    heads = None if seq_shard_cache else ("model" if tp_heads else None)
    kv = None if seq_shard_cache else ("model" if tp_kv else None)
    # 100B-class serving (mlp_2d + replicated heads): spread the attention
    # projections over ("data","model") on d_model — row-parallel with a
    # tiny S=1 psum — so no multi-GB weight replica per chip.
    attn_2d = mlp_2d and heads is None
    return ShardingPolicy(name="serve", rules={
        "layers": None,
        "vocab": "model",
        "embed_d": None,
        "d_model_in": "data" if mlp_2d else None,
        "d_model_out": "data" if mlp_2d else None,
        "attn_din": ("data", "model") if attn_2d else (
            "data" if mlp_2d else None),
        "attn_dout": "model" if attn_2d else ("data" if mlp_2d else None),
        "qheads": heads,
        "kv_heads": kv,
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "rnn": "model",
        "norm": None,
        "lora": None,
    })


def tree_specs(logical_tree, policy: ShardingPolicy):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        policy.spec, logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def tree_shardings(logical_tree, policy: ShardingPolicy, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(logical_tree, policy),
                        is_leaf=lambda x: isinstance(x, P))


def vocab_axis(dp):
    """'model' for the activation vocab dim, unless 'model' is already a
    batch axis (tp1 remap) — a mesh axis may appear once per spec."""
    flat = ()
    if dp:
        for e in (dp if isinstance(dp, tuple) else (dp,)):
            flat += (e if isinstance(e, tuple) else (e,))
    return None if "model" in flat else "model"


def constrain(x, mesh: Mesh, *spec_entries):
    """with_sharding_constraint that tolerates meshes missing some axes."""
    fixed = tuple(
        e if (e is None or all(a in mesh.axis_names for a in ((e,) if isinstance(e, str) else e)))
        else None
        for e in spec_entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))
