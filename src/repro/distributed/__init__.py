"""Distribution layer: meshes, collective schedules, SU-ALS, LM sharding.

- collectives.py : one-phase (flat) and two-phase (topology-aware) parallel
                   reduction — paper §4.2 mapped to reduce-scatter on ICI/DCI.
- su_als.py      : SU-ALS (paper Alg. 3) under shard_map.
- sharding.py    : PartitionSpec policies for the LM stack (DP/FSDP/TP/SP/EP).
- flash_decode.py: sequence-sharded decode attention (partial-softmax psum).
"""

from repro.distributed.collectives import (
    reduce_scatter_flat,
    hierarchical_reduce_scatter,
    collective_bytes_reduce,
)
from repro.distributed.su_als import su_als_update, make_su_als_fns, shard_ratings

__all__ = [
    "reduce_scatter_flat",
    "hierarchical_reduce_scatter",
    "collective_bytes_reduce",
    "su_als_update",
    "make_su_als_fns",
    "shard_ratings",
]
