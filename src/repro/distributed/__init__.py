"""Distribution layer: meshes, collective schedules, SU-ALS, LM sharding.

- collectives.py : one-phase (flat) and two-phase (topology-aware) parallel
                   reduction — paper §4.2 mapped to reduce-scatter on ICI/DCI.
- reduce.py      : the same two-phase scheme as a host-scheduled staged
                   reduction (ring within fast domains, tree across them) —
                   combines the streaming drivers' per-data-shard partials.
- su_als.py      : SU-ALS (paper Alg. 3) under shard_map, plus the per-wave
                   mesh entry points the out-of-core drivers dispatch through.
- sharding.py    : PartitionSpec policies for the LM stack (DP/FSDP/TP/SP/EP).
- flash_decode.py: sequence-sharded decode attention (partial-softmax psum).
"""

from repro.distributed.collectives import (
    reduce_scatter_flat,
    hierarchical_reduce_scatter,
    collective_bytes_reduce,
)
from repro.distributed.reduce import (
    DeviceTopology,
    allreduce_oracle,
    linear_topology,
    reduce_traffic,
    topology_reduce,
)
from repro.distributed.su_als import (
    make_su_als_fns,
    make_wave_herm_fn,
    make_wave_update_fn,
    shard_ratings,
    su_als_update,
)

__all__ = [
    "DeviceTopology",
    "allreduce_oracle",
    "collective_bytes_reduce",
    "hierarchical_reduce_scatter",
    "linear_topology",
    "make_su_als_fns",
    "make_wave_herm_fn",
    "make_wave_update_fn",
    "reduce_scatter_flat",
    "reduce_traffic",
    "shard_ratings",
    "su_als_update",
    "topology_reduce",
]
