"""Flash-decode: single-token attention over a sequence-sharded KV cache.

For long-context decode (32k-524k) of large models the KV cache cannot live
on one device; we shard it on the *sequence* dimension over the "model"
axis.  Plain attention would force GSPMD to all-gather the cache (hundreds
of GB); instead each shard computes a partial softmax over its local slice
and the partials are combined with three tiny collectives (max, sum-of-
weights, weighted value sum) — the flash-decoding scheme, expressed under
``shard_map`` with the batch axes left in auto mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat

NEG_INF = -1e30


def _partial_decode(q, k_loc, v_loc, lengths, s_offset, *, window=None):
    """Partial-softmax stats for the local KV slice.

    q [B, H, dh]; k_loc/v_loc [B, S_loc, KV, dh]; lengths [B];
    s_offset: global position of local slice start.
    Returns (m [B,KV,G], l [B,KV,G], o [B,KV,G,dh])."""
    b, h, dh = q.shape
    kv = k_loc.shape[2]
    qg = q.reshape(b, kv, h // kv, dh)
    # NOTE: dots run at the cache dtype (bf16) without a preferred f32
    # output — XLA-CPU otherwise materializes an f32-converted COPY of the
    # whole cache slice per layer (the TPU MXU accumulates bf16 dots in
    # f32 internally, so the target loses nothing).  Softmax stats in f32.
    sc = jnp.einsum("bkgd,btkd->bkgt", qg.astype(k_loc.dtype),
                    k_loc).astype(jnp.float32) * (dh ** -0.5)
    pos = s_offset + jnp.arange(k_loc.shape[1])
    msk = pos[None, :] < lengths[:, None]
    if window is not None:
        msk &= pos[None, :] >= (lengths[:, None] - window)
    msk = msk[:, None, None, :]
    sc = jnp.where(msk, sc, NEG_INF)
    m = sc.max(axis=-1)
    p = jnp.where(msk, jnp.exp(sc - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_loc.dtype),
                   v_loc).astype(jnp.float32)
    return m, l, o


def flash_decode(q, k_cache, v_cache, lengths, *, mesh, axis="model",
                 window=None):
    """q [B,H,dh] (replicated over ``axis``); caches [B,S,KV,dh] sharded on
    dim 1 over ``axis``; lengths [B].  Returns [B,H,dh]."""
    b, h, dh = q.shape

    # shard offset via sharded iota (not lax.axis_index -> PartitionId,
    # which the XLA SPMD partitioner rejects in large unrolled programs)
    pos_iota = jnp.arange(k_cache.shape[1], dtype=jnp.int32)

    def local(qq, kc, vc, ln, pos_loc):
        m_i, l_i, o_i = _partial_decode(qq, kc, vc, ln, pos_loc[0],
                                        window=window)
        m = lax.pmax(m_i, axis)
        alpha = jnp.exp(m_i - m)
        l = lax.psum(l_i * alpha, axis)
        o = lax.psum(o_i * alpha[..., None], axis)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, h, dh).astype(qq.dtype)

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, axis), P(None, axis), P(), P(axis)),
        out_specs=P(),
        axis_names={axis},
        check_vma=False,
    )(q, k_cache, v_cache, lengths, pos_iota)


def flash_decode_update(q, k_cache, v_cache, k_new, v_new, lengths, *,
                        mesh, dp=None, seq_axis="model", kv_axis=None,
                        window=None):
    """Fused cache write + decode attention in ONE shard_map region.

    Every shard_map boundary materializes its cache operands once per
    layer under XLA-CPU buffer assignment; with three regions per layer
    (write-k, write-v, attend) the 32k decode cells leaked ~20 GiB of
    temp.  Fusing them means the k/v caches cross a boundary exactly once
    and the in->out buffers alias.

    Returns (out [B, H, dh], kc_new, vc_new).  Layouts as in cache_write:
    seq_axis xor kv_axis sharded over "model", batch over ``dp``.
    """
    b, h, dh = q.shape
    pos_iota = jnp.arange(k_cache.shape[1], dtype=jnp.int32)
    manual = set()
    if dp:
        manual |= set(dp if isinstance(dp, tuple) else (dp,))
    if seq_axis:
        manual.add(seq_axis)
    if kv_axis:
        manual.add(kv_axis)
    if not manual:
        manual = {"model"}

    cache_spec = P(dp, seq_axis, kv_axis, None)
    new_spec = P(dp, kv_axis, None)
    q_spec = P(dp, kv_axis, None) if kv_axis else P(dp)

    def write_rows(buf, new, pos_c, ok):
        """Per-row dynamic_update_slice chain.  A batched scatter here gets
        upcast to f32 by the XLA SPMD partitioner (bf16-scatter workaround)
        which materializes full f32 cache copies per layer; DUS is
        bf16-native and aliases in place."""
        kvd, dhd = buf.shape[2], buf.shape[3]
        for i in range(buf.shape[0]):
            cur = lax.dynamic_slice(buf, (i, pos_c[i], 0, 0),
                                    (1, 1, kvd, dhd))
            row = jnp.where(ok[i], new[i].astype(buf.dtype)[None, None],
                            cur)
            buf = lax.dynamic_update_slice(buf, row, (i, pos_c[i], 0, 0))
        return buf

    def local(qq, kc, vc, kn, vn, ln, pos_loc):
        off = pos_loc[0]
        s_loc = kc.shape[1]
        pos = ln - off
        ok = (pos >= 0) & (pos < s_loc)
        pos_c = jnp.clip(pos, 0, s_loc - 1)
        kc = write_rows(kc, kn, pos_c, ok)
        vc = write_rows(vc, vn, pos_c, ok)
        m_i, l_i, o_i = _partial_decode(qq, kc, vc, ln + 1, off,
                                        window=window)
        if seq_axis:
            m = lax.pmax(m_i, seq_axis)
            alpha = jnp.exp(m_i - m)
            l = lax.psum(l_i * alpha, seq_axis)
            o = lax.psum(o_i * alpha[..., None], seq_axis)
        else:
            l, o = l_i, o_i
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(qq.shape).astype(qq.dtype), kc, vc

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, new_spec, new_spec,
                  P(dp), P(seq_axis)),
        out_specs=(q_spec, cache_spec, cache_spec),
        axis_names=manual, check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, lengths, pos_iota)
