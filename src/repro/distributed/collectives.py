"""Topology-aware parallel reduction (paper §4.2) as TPU collectives.

The paper's one-phase scheme (Fig. 5a) — every GPU reduces 1/p of all
partial A matrices, using all PCIe links in both directions — is exactly a
**reduce-scatter**.  Its two-phase topology-aware scheme (Fig. 5b) — reduce
within a PCIe socket first, then cross the slower inter-socket link with
only partial results — maps to a **hierarchical reduce-scatter**: scatter
over the fast intra-pod ICI axis first, then reduce over the slow inter-pod
DCI axis with only the already-scattered 1/p-sized slice.

Bytes over the slow link:  flat = (P-1)/P * |T|  per device,
hierarchical = |T| / p_fast per device — a p_fast-times reduction, which is
the TPU restatement of the paper's 1.5x two-phase speedup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def reduce_scatter_flat(x: jax.Array, axis_names, scatter_axis: int = 0) -> jax.Array:
    """One-phase parallel reduction (paper Fig. 5a): reduce-scatter over all
    ``axis_names`` jointly, ignoring topology."""
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    out = x
    for name in axis_names:
        out = lax.psum_scatter(out, name, scatter_dimension=scatter_axis, tiled=True)
    return out


def hierarchical_reduce_scatter(
    x: jax.Array,
    fast_axis: str,
    slow_axis: str | None,
    scatter_axis: int = 0,
) -> jax.Array:
    """Two-phase topology-aware reduction (paper Fig. 5b).

    Phase 1 (intra-pod / intra-socket): reduce-scatter over ``fast_axis`` —
    every fast link busy in both directions, each device left with the
    fully-intra-pod-reduced 1/p_fast slice.
    Phase 2 (inter-pod / inter-socket): all-reduce the *scattered slice*
    over ``slow_axis`` — only |T|/p_fast bytes cross the slow link.
    """
    out = lax.psum_scatter(x, fast_axis, scatter_dimension=scatter_axis, tiled=True)
    if slow_axis is not None:
        out = lax.psum(out, slow_axis)
    return out


def collective_bytes_reduce(nbytes: int, p_fast: int, p_slow: int) -> dict:
    """Analytic per-device traffic of both schemes for a |T|=nbytes tensor —
    used by the roofline harness and asserted against HLO-parsed bytes."""
    flat_fast = nbytes * (p_fast - 1) / p_fast
    # flat scheme crosses the slow link with un-reduced full-size data:
    flat_slow = nbytes * (p_slow - 1) / p_slow if p_slow > 1 else 0.0
    hier_fast = nbytes * (p_fast - 1) / p_fast
    # two-phase: only the scattered slice crosses the slow link (ring allreduce)
    hier_slow = 2 * (nbytes / p_fast) * (p_slow - 1) / p_slow if p_slow > 1 else 0.0
    return {
        "flat": {"fast_link": flat_fast, "slow_link": flat_slow},
        "hierarchical": {"fast_link": hier_fast, "slow_link": hier_slow},
        "slow_link_saving": (flat_slow / hier_slow) if hier_slow else 1.0,
    }
