"""Optimizers (pytree-functional): AdamW and Adafactor.

AdamW keeps two fp32 moments per parameter (the memory planner in
DESIGN.md assumes 12 bytes/param + bf16 compute copy).  Adafactor factors
the second moment of every rank>=2 parameter into row/col statistics —
the memory option for >=100B-parameter training runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "constant"   # constant | inverse_time | cosine
    schedule_steps: int = 1000   # horizon for cosine / default inverse-time decay
    min_lr: float = 0.0          # cosine floor


def lr_schedule(name: str, step, *, base_lr: float = 1.0,
                total_steps: int = 1000, decay: float | None = None,
                min_lr: float = 0.0) -> jax.Array:
    """Learning rate at ``step`` (int or traced scalar) — shared by the
    SGD factorization driver and the LM optimizers.

    - ``constant``:     base_lr
    - ``inverse_time``: base_lr / (1 + decay * step); ``decay`` defaults
      to ``10 / total_steps`` (a 10x+ drop over the horizon)
    - ``cosine``:       min_lr + (base_lr - min_lr) * cos-anneal over
      ``total_steps``, flat at ``min_lr`` afterwards
    """
    t = jnp.asarray(step, jnp.float32)
    if name == "constant":
        return jnp.full((), base_lr, jnp.float32) + 0.0 * t
    if name == "inverse_time":
        d = (10.0 / max(total_steps, 1)) if decay is None else decay
        return base_lr / (1.0 + d * t)
    if name == "cosine":
        frac = jnp.clip(t / max(total_steps, 1), 0.0, 1.0)
        return min_lr + (base_lr - min_lr) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    raise ValueError(f"unknown lr schedule {name!r}")


def _cfg_lr(cfg: OptConfig, step) -> jax.Array:
    """The scheduled lr of an OptConfig at ``step`` (traced-safe)."""
    return lr_schedule(cfg.schedule, step, base_lr=cfg.lr,
                       total_steps=cfg.schedule_steps, min_lr=cfg.min_lr)


class AdamState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                     step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adam_update(grads, state: AdamState, params, cfg: OptConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _cfg_lr(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(m=new_m, v=new_v, step=step), gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory option for 100B+ runs)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    vr: Any     # row stats (rank>=2 leaves) or full v (rank<2)
    vc: Any     # col stats (rank>=2) or None placeholder
    step: jax.Array


def _factored(p):
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    vr = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
        else jnp.zeros(p.shape, jnp.float32), params)
    vc = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        if _factored(p) else jnp.zeros((1,), jnp.float32), params)
    return AdafactorState(vr=vr, vc=vc, step=jnp.zeros((), jnp.int32))


def adafactor_update(grads, state: AdafactorState, params, cfg: OptConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _cfg_lr(cfg, state.step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, vr, vc):
        g2 = jnp.square(g) + 1e-30
        if _factored(p):
            vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
            vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            precond = jax.lax.rsqrt(
                jnp.maximum(r[..., None] * vc[..., None, :], 1e-30))
        else:
            vr = decay * vr + (1 - decay) * g2
            precond = jax.lax.rsqrt(jnp.maximum(vr, 1e-30))
        delta = g * precond
        # relative-scale clipping (Adafactor's d=1 update clipping)
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
        delta = delta / jnp.maximum(1.0, rms)
        new_p = (p.astype(jnp.float32) - lr * delta)
        if cfg.weight_decay:
            new_p = new_p - lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), vr, vc

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    vr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    vc = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdafactorState(vr=vr, vc=vc, step=step), gnorm


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adam_init, lambda g, s, p: adam_update(g, s, p, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, lambda g, s, p: adafactor_update(g, s, p, cfg)
    raise ValueError(cfg.name)
