"""Training substrate: optimizers, train-step builders, compression."""
