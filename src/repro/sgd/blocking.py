"""CuMF_SGD matrix blocking: g x g rating grid + conflict-free schedule.

The rating COO is partitioned into a g x g grid of (user-block,
item-block) tiles.  Two tiles conflict iff they share a user block (both
update the same X rows) or an item block (same Theta rows); CuMF_SGD's
scheduler therefore runs the grid as ``g`` *diagonal block-sets*

    set s = { (i, (i + s) mod g) : i = 0..g-1 },   s = 0..g-1

— within a set every user block and every item block appears exactly
once, so the g tile updates are mutually independent (batch-Hogwild runs
them concurrently without locks), and the union over the g sets covers
every tile exactly once per epoch.

Each tile is stored as a block-local PaddedELL slice, built through the
same ``csr_from_coo`` / ``pad_csr_fast`` path as the ALS side, with K
padded to the grid-wide maximum so every tile presents one device shape
(one kernel compilation covers the whole epoch).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.sparse.padded import PaddedELL, csr_from_coo, pad_csr_fast


def diagonal_sets(g: int) -> List[List[Tuple[int, int]]]:
    """The g conflict-free block-sets; set s holds tiles (i, (i+s) % g)."""
    return [[(i, (i + s) % g) for i in range(g)] for s in range(g)]


def ell_to_coo(ell: PaddedELL) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover (rows, cols, vals) of the logical matrix from a PaddedELL."""
    cols_t, rows_t, vals = ell.transpose_coo()   # (orig cols, orig rows, vals)
    return rows_t, cols_t, vals


@dataclasses.dataclass
class BlockGrid:
    """g x g grid of block-local PaddedELL tiles, uniform device shape.

    ``idx[i, j]`` holds *item-block-local* column indices (< nb) of the
    nonzeros whose user falls in user-block i and item in item-block j;
    the row coordinate within the [mb, K] tile is the *user-block-local*
    user index.  ``m``/``n`` are the true matrix dims; ``g*mb >= m`` and
    ``g*nb >= n`` (factor rows in the padding range are never touched —
    every cnt there is 0).
    """

    idx: np.ndarray   # [g, g, mb, K] int32
    val: np.ndarray   # [g, g, mb, K] float32
    cnt: np.ndarray   # [g, g, mb]    int32
    g: int
    m: int
    n: int

    @property
    def mb(self) -> int:
        return self.idx.shape[2]

    @property
    def nb(self) -> int:
        return -(-self.n // self.g)

    @property
    def K(self) -> int:
        return self.idx.shape[3]

    @property
    def nnz(self) -> int:
        return int(self.cnt.sum())

    @property
    def fill(self) -> float:
        """Stored slots / true nonzeros across the whole grid (>= 1)."""
        return float(self.g * self.g * self.mb * self.K) / max(self.nnz, 1)

    def block(self, i: int, j: int) -> PaddedELL:
        """Tile (i, j) as a standalone block-local PaddedELL."""
        return PaddedELL(idx=self.idx[i, j], val=self.val[i, j],
                         cnt=self.cnt[i, j], n_cols=self.nb)

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Reassemble the global-coordinate COO (round-trip check)."""
        rows, cols, vals = [], [], []
        for i in range(self.g):
            for j in range(self.g):
                r, c, v = ell_to_coo(self.block(i, j))
                rows.append(r + i * self.mb)
                cols.append(c + j * self.nb)
                vals.append(v)
        return (np.concatenate(rows), np.concatenate(cols),
                np.concatenate(vals))


def block_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              m: int, n: int, g: int, k_multiple: int = 8) -> BlockGrid:
    """Partition a rating COO into a g x g BlockGrid.

    Block sizes are ``mb = ceil(m/g)`` users x ``nb = ceil(n/g)`` items;
    every tile is CSR-sorted and ELL-padded through the shared sparse
    stack, then K-padded to the grid maximum for a uniform kernel shape.
    """
    assert g >= 1
    mb = -(-m // g)
    nb = -(-n // g)
    bi = rows // mb            # user block of each nonzero
    bj = cols // nb            # item block
    # one pass over the COO: stable-sort by flat block id, then slice —
    # per-block boolean masks would rescan all nnz g*g times
    order = np.argsort(bi * g + bj, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    per_block = np.bincount((bi * g + bj)[order], minlength=g * g)
    ends = np.cumsum(per_block)
    tiles: list[list[PaddedELL]] = []
    kmax = k_multiple
    for i in range(g):
        row_tiles = []
        for j in range(g):
            hi = int(ends[i * g + j])
            lo = hi - int(per_block[i * g + j])
            ptr, cc, vv = csr_from_coo(
                rows[lo:hi] - i * mb, cols[lo:hi] - j * nb, vals[lo:hi], mb)
            ell = pad_csr_fast(ptr, cc, vv, nb, k_multiple=k_multiple)
            kmax = max(kmax, ell.K)
            row_tiles.append(ell)
        tiles.append(row_tiles)
    idx = np.zeros((g, g, mb, kmax), dtype=np.int32)
    val = np.zeros((g, g, mb, kmax), dtype=np.float32)
    cnt = np.zeros((g, g, mb), dtype=np.int32)
    for i in range(g):
        for j in range(g):
            e = tiles[i][j]
            idx[i, j, :, :e.K] = e.idx
            val[i, j, :, :e.K] = e.val
            cnt[i, j] = e.cnt
    return BlockGrid(idx=idx, val=val, cnt=cnt, g=g, m=m, n=n)


def block_ell(ell: PaddedELL, g: int, k_multiple: int = 8) -> BlockGrid:
    """Blocked view of an existing row-major PaddedELL (the ALS layout) —
    the shard-sharing entry point the hybrid driver uses."""
    rows, cols, vals = ell_to_coo(ell)
    return block_coo(rows, cols, vals, ell.m, ell.n_cols, g,
                     k_multiple=k_multiple)
