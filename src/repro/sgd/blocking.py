"""CuMF_SGD matrix blocking: g x g rating grid + conflict-free schedule.

The rating COO is partitioned into a g x g grid of (user-block,
item-block) tiles.  Two tiles conflict iff they share a user block (both
update the same X rows) or an item block (same Theta rows); CuMF_SGD's
scheduler therefore runs the grid as ``g`` *diagonal block-sets*

    set s = { (i, (i + s) mod g) : i = 0..g-1 },   s = 0..g-1

— within a set every user block and every item block appears exactly
once, so the g tile updates are mutually independent (batch-Hogwild runs
them concurrently without locks), and the union over the g sets covers
every tile exactly once per epoch.

Each tile is stored as a block-local PaddedELL slice, built through the
same ``csr_from_coo`` / ``pad_csr_fast`` path as the ALS side, with K
padded to the grid-wide maximum so every tile presents one device shape
(one kernel compilation covers the whole epoch).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.sparse.padded import PaddedELL, csr_from_coo, pad_csr_fast


def diagonal_sets(g: int) -> List[List[Tuple[int, int]]]:
    """The g conflict-free block-sets; set s holds tiles (i, (i+s) % g)."""
    return [[(i, (i + s) % g) for i in range(g)] for s in range(g)]


def ell_to_coo(ell: PaddedELL) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover (rows, cols, vals) of the logical matrix from a PaddedELL."""
    cols_t, rows_t, vals = ell.transpose_coo()   # (orig cols, orig rows, vals)
    return rows_t, cols_t, vals


@dataclasses.dataclass
class BlockGrid:
    """g x g grid of block-local PaddedELL tiles, uniform device shape.

    ``idx[i, j]`` holds *item-block-local* column indices (< nb) of the
    nonzeros whose user falls in user-block i and item in item-block j;
    the row coordinate within the [mb, K] tile is the *user-block-local*
    user index.  ``m``/``n`` are the true matrix dims; ``g*mb >= m`` and
    ``g*nb >= n`` (factor rows in the padding range are never touched —
    every cnt there is 0).
    """

    idx: np.ndarray   # [g, g, mb, K] int32
    val: np.ndarray   # [g, g, mb, K] float32
    cnt: np.ndarray   # [g, g, mb]    int32
    g: int
    m: int
    n: int
    #: per-tile kernel K [g, g] int32, on the quantized ladder of
    #: ``tile_k_ladder`` (degree binning at tile granularity: tile (i, j)
    #: dispatches/streams only its first tile_K[i,j] slot columns — the
    #: trailing columns are all-padding and masked, so slicing them off is
    #: exact).  None = uniform grid-wide K (today's layout, the default).
    tile_K: np.ndarray | None = None
    #: degree-sort row permutation [m] int64: ``user_perm[k]`` = original
    #: user id stored at grid row k (heavy users first, so they concentrate
    #: in few user blocks and most tiles earn a small tile_K — cuMF's
    #: degree binning applied at grid granularity).  None = identity.
    #: Factors inside the grid live in PERMUTED row order; map back with
    #: ``user_inv`` before any global-coordinate evaluation.
    user_perm: np.ndarray | None = None
    #: autotune decision record (``repro.core.autotune`` result dict) when
    #: the grid was built with ``per_tile_k="auto"``; the streaming SGD
    #: driver copies it into the ledger run context.  None on hand-picked
    #: grids.
    tune: dict | None = None

    @property
    def mb(self) -> int:
        return self.idx.shape[2]

    @property
    def nb(self) -> int:
        return -(-self.n // self.g)

    @property
    def K(self) -> int:
        return self.idx.shape[3]

    @property
    def nnz(self) -> int:
        return int(self.cnt.sum())

    @property
    def padded_slots(self) -> int:
        """Slots the kernels actually touch: per-tile K when binned."""
        if self.tile_K is None:
            return self.g * self.g * self.mb * self.K
        return int(self.mb * int(self.tile_K.sum()))

    @property
    def fill(self) -> float:
        """Dispatched slots / true nonzeros across the whole grid (>= 1);
        respects ``tile_K`` so the binned grid prices its real traffic."""
        return float(self.padded_slots) / max(self.nnz, 1)

    def tile_k(self, i: int, j: int) -> int:
        return self.K if self.tile_K is None else int(self.tile_K[i, j])

    @property
    def user_inv(self) -> np.ndarray:
        """[m] int64: grid row holding each original user (inverse of
        ``user_perm``; identity when the grid is unsorted)."""
        if self.user_perm is None:
            return np.arange(self.m, dtype=np.int64)
        inv = np.empty(self.m, dtype=np.int64)
        inv[self.user_perm] = np.arange(self.m, dtype=np.int64)
        return inv

    def block(self, i: int, j: int) -> PaddedELL:
        """Tile (i, j) as a standalone block-local PaddedELL, sliced to the
        tile's own K when the grid is per-tile binned."""
        k = self.tile_k(i, j)
        return PaddedELL(idx=self.idx[i, j, :, :k], val=self.val[i, j, :, :k],
                         cnt=self.cnt[i, j], n_cols=self.nb)

    def to_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Reassemble the global-coordinate COO (round-trip check)."""
        rows, cols, vals = [], [], []
        for i in range(self.g):
            for j in range(self.g):
                r, c, v = ell_to_coo(self.block(i, j))
                rows.append(r + i * self.mb)
                cols.append(c + j * self.nb)
                vals.append(v)
        out_rows = np.concatenate(rows)
        if self.user_perm is not None:
            out_rows = self.user_perm[out_rows]
        return (out_rows, np.concatenate(cols), np.concatenate(vals))


def tile_k_ladder(k: int, k_multiple: int = 8) -> int:
    """Quantize a tile's K up to the ``k_multiple * 2^j`` ladder.

    Per-tile K values land on a geometric ladder so a g x g grid compiles
    at most O(log(Kmax/k_multiple)) distinct kernel shapes per set instead
    of up to g — the same bounded-shapes argument as ``bin_caps`` on the
    ALS side, specialized to power-of-two rungs.
    """
    rung = k_multiple
    while rung < k:
        rung *= 2
    return rung


def block_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              m: int, n: int, g: int, k_multiple: int = 8,
              per_tile_k: bool | str = False,
              degree_sort: bool = False, tune_cache=None) -> BlockGrid:
    """Partition a rating COO into a g x g BlockGrid.

    Block sizes are ``mb = ceil(m/g)`` users x ``nb = ceil(n/g)`` items;
    every tile is CSR-sorted and ELL-padded through the shared sparse
    stack, then K-padded to the grid maximum for a uniform kernel shape.
    With ``per_tile_k`` the grid additionally records each tile's own
    ladder-quantized K (``tile_K``): storage stays one [g, g, mb, Kmax]
    array, but kernels and the streaming driver slice each tile to its
    tight K — cuMF's degree binning at item-block granularity.
    ``degree_sort`` additionally assigns users to blocks in descending
    degree order (recorded in ``user_perm``): without it heavy users
    scatter into every block and each tile's K stays near the global max;
    with it the heavy tail concentrates in the leading blocks and
    ``per_tile_k`` gets its multi-x fill win on power-law data.  Sorting
    re-partitions the grid, so it changes the (still-exact) Hogwild visit
    order — equivalent training, not a bit-identical trajectory.

    ``per_tile_k="auto"`` resolves both blocking knobs (``per_tile_k`` AND
    ``degree_sort``, overriding the latter) through
    ``repro.core.autotune.tune_sgd_layout`` — argmin of dispatched padded
    slots over the blocking ladder, cached in ``tune_cache`` — and records
    the decision on ``grid.tune`` for the streaming driver's ledger.
    """
    assert g >= 1
    if per_tile_k == "auto":
        from repro.core.autotune import tune_sgd_layout
        ptr, cc, vv = csr_from_coo(rows, cols, vals, m)
        ell = pad_csr_fast(ptr, cc, vv, n, k_multiple=k_multiple)
        res = tune_sgd_layout(ell, g, k_multiple=k_multiple,
                              cache=tune_cache)
        grid = res.grid
        if grid is None:       # cache hit carries config only — rebuild it
            grid = block_coo(rows, cols, vals, m, n, g,
                             k_multiple=k_multiple,
                             per_tile_k=res.config.per_tile_k,
                             degree_sort=res.config.degree_sort)
        grid.tune = res.to_obj()
        return grid
    user_perm = None
    if degree_sort:
        deg = np.bincount(rows, minlength=m)
        user_perm = np.argsort(-deg, kind="stable").astype(np.int64)
        inv = np.empty(m, dtype=np.int64)
        inv[user_perm] = np.arange(m, dtype=np.int64)
        rows = inv[rows]
    mb = -(-m // g)
    nb = -(-n // g)
    bi = rows // mb            # user block of each nonzero
    bj = cols // nb            # item block
    # one pass over the COO: stable-sort by flat block id, then slice —
    # per-block boolean masks would rescan all nnz g*g times
    order = np.argsort(bi * g + bj, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    per_block = np.bincount((bi * g + bj)[order], minlength=g * g)
    ends = np.cumsum(per_block)
    tiles: list[list[PaddedELL]] = []
    kmax = k_multiple
    for i in range(g):
        row_tiles = []
        for j in range(g):
            hi = int(ends[i * g + j])
            lo = hi - int(per_block[i * g + j])
            ptr, cc, vv = csr_from_coo(
                rows[lo:hi] - i * mb, cols[lo:hi] - j * nb, vals[lo:hi], mb)
            ell = pad_csr_fast(ptr, cc, vv, nb, k_multiple=k_multiple)
            kmax = max(kmax, ell.K)
            row_tiles.append(ell)
        tiles.append(row_tiles)
    idx = np.zeros((g, g, mb, kmax), dtype=np.int32)
    val = np.zeros((g, g, mb, kmax), dtype=np.float32)
    cnt = np.zeros((g, g, mb), dtype=np.int32)
    tile_K = np.zeros((g, g), dtype=np.int32) if per_tile_k else None
    for i in range(g):
        for j in range(g):
            e = tiles[i][j]
            idx[i, j, :, :e.K] = e.idx
            val[i, j, :, :e.K] = e.val
            cnt[i, j] = e.cnt
            if tile_K is not None:
                tile_K[i, j] = min(tile_k_ladder(e.K, k_multiple), kmax)
    return BlockGrid(idx=idx, val=val, cnt=cnt, g=g, m=m, n=n,
                     tile_K=tile_K, user_perm=user_perm)


def block_ell(ell: PaddedELL, g: int, k_multiple: int = 8,
              per_tile_k: bool | str = False,
              degree_sort: bool = False, tune_cache=None) -> BlockGrid:
    """Blocked view of an existing row-major PaddedELL (the ALS layout) —
    the shard-sharing entry point the hybrid driver uses.  Accepts
    ``per_tile_k="auto"`` like :func:`block_coo`."""
    rows, cols, vals = ell_to_coo(ell)
    return block_coo(rows, cols, vals, ell.m, ell.n_cols, g,
                     k_multiple=k_multiple, per_tile_k=per_tile_k,
                     degree_sort=degree_sort, tune_cache=tune_cache)
