"""ALS-warm-start -> SGD-refine hybrid solver (Tan et al. 1808.03843).

ALS makes large, stable moves in the first few iterations (each sweep is
a closed-form block solve) but every iteration costs the full Hermitian +
Cholesky pipeline; SGD epochs are far cheaper per pass but need many
epochs from a cold start.  The hybrid runs a few ALS iterations on the
row/column PaddedELL shards, then hands the factors to the blocked SGD
driver *on the same rating data* (the BlockGrid is built from the very
same shards via ``blocking.block_ell``) for cheap refinement.

``run_streaming_hybrid`` is the out-of-core variant: the warm start
streams R/R^T waves through ``outofcore.run_streaming_als`` and the
refinement streams grid tiles through ``outofcore.run_streaming_sgd``, so
the whole hybrid runs under the same fixed device budget — neither phase
ever holds the full problem resident.
"""
from __future__ import annotations

import os
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import als as als_mod
from repro.sgd.blocking import BlockGrid
from repro.sgd.train import SgdConfig, SgdState, pad_factor, sgd_train


def sgd_state_from_als(als_state: als_mod.AlsState,
                       grid: BlockGrid) -> SgdState:
    """Continue from an AlsState: pad factors to the grid's block shape.

    Padding rows (users/items beyond the true m/n) carry no ratings in
    any tile, so they are never touched by an epoch — the SGD trajectory
    starts exactly at the ALS iterate.  A degree-sorted grid stores user
    rows permuted, so the ALS factors (original order) are permuted into
    grid order on the way in.
    """
    x = jnp.asarray(als_state.x)
    if grid.user_perm is not None:
        x = jnp.take(x, jnp.asarray(grid.user_perm), axis=0)
    return SgdState(
        x=pad_factor(x, grid.g * grid.mb),
        theta=pad_factor(jnp.asarray(als_state.theta), grid.g * grid.nb),
        epoch=jnp.int32(0))


def hybrid_train(
    r, rt,
    grid: BlockGrid,
    als_cfg: als_mod.AlsConfig,
    sgd_cfg: SgdConfig,
    *,
    test: Optional[tuple] = None,
    train_eval: Optional[tuple] = None,
    ckpt_dir: Optional[str] = None,
    callback=None,
) -> tuple[SgdState, list[dict]]:
    """``als_cfg.iters`` ALS sweeps, then ``sgd_cfg.epochs`` SGD epochs.

    ``r`` / ``rt`` are the ALS-side (idx, val, cnt) triplets of R and R^T;
    ``grid`` is the blocked view of the same ratings.  History records are
    tagged ``phase: "als" | "sgd"`` (before the callback fires, so live
    progress printers see the tag too) and share the RMSE protocol.

    With ``ckpt_dir`` set and a committed checkpoint present, the ALS
    warm start is skipped entirely: the checkpoint already embeds it, and
    re-running ALS would burn its full cost only for ``sgd_train``'s
    restore to overwrite the result.
    """
    def tagged(phase):
        def cb(state, rec):
            rec["phase"] = phase
            if callback is not None:
                callback(state, rec)
        return cb

    state0 = None
    als_hist: list[dict] = []
    resuming = False
    if ckpt_dir is not None:
        from repro.checkpoint.store import latest_step
        resuming = (os.path.isdir(ckpt_dir)
                    and latest_step(ckpt_dir) is not None)
    if not resuming:
        als_state, als_hist = als_mod.als_train(
            r, rt, grid.m, grid.n, als_cfg, test=test,
            callback=tagged("als"))
        state0 = sgd_state_from_als(als_state, grid)
    final, sgd_hist = sgd_train(
        grid, sgd_cfg, test=test, train_eval=train_eval,
        init_state=state0, ckpt_dir=ckpt_dir, callback=tagged("sgd"))
    return final, als_hist + sgd_hist


def run_streaming_hybrid(
    ratings,                    # outofcore.RatingStore (warm-start phase)
    als_sched,                  # outofcore.IterationSchedule
    tiles,                      # outofcore.TileStore (refine phase)
    sgd_sched,                  # outofcore.SgdEpochSchedule
    als_cfg: als_mod.AlsConfig,
    sgd_cfg: SgdConfig,
    *,
    test_eval=None,
    train_eval=None,
    ckpt_dir: Optional[str] = None,
    keep: int = 3,
    prefetch_depth: int = 2,
    mesh=None,
    topology=None,
    callback=None,
):
    """Out-of-core hybrid: streaming ALS warm start, streaming SGD refine.

    Both phases run through the shared wave runtime under their own
    schedules' budgets; ``ratings`` and ``tiles`` are two host-resident
    layouts of the same rating matrix.  Returns ``(FactorStore, history,
    StreamTelemetry)`` — ONE merged telemetry over both phases
    (``outofcore.runtime.merge_telemetry``): traffic and wall time summed,
    capacity/peak the per-phase maxima, ``phase_seconds`` keys prefixed
    ``als/`` / ``sgd/``, and the individual phase telemetries still
    reachable under ``.phases["als"]`` / ``.phases["sgd"]`` (``"als"``
    absent when the warm start was skipped on resume).  History records
    are phase-tagged like ``hybrid_train``'s.  Checkpoints are
    phase-scoped (``<ckpt_dir>/als`` and ``<ckpt_dir>/sgd`` hold
    differently-shaped trees); once the SGD phase has committed a wave, a
    restart skips the warm start entirely — the SGD checkpoint already
    embeds it.
    """
    # imported here: repro.outofcore imports repro.sgd.train, so a
    # module-level import back into repro.sgd would be circular
    from repro.outofcore import (FactorStore, run_streaming_als,
                                 run_streaming_sgd)
    from repro.outofcore.runtime import merge_telemetry

    grid = tiles.grid
    assert grid.m == ratings.m and grid.n == ratings.n, \
        "RatingStore and TileStore hold different matrices"

    def tagged(phase):
        def cb(state, rec):
            rec["phase"] = phase
            if callback is not None:
                callback(state, rec)
        return cb

    als_ck = sgd_ck = None
    refine_started = False
    if ckpt_dir is not None:
        from repro.checkpoint.store import latest_step
        als_ck = os.path.join(ckpt_dir, "als")
        sgd_ck = os.path.join(ckpt_dir, "sgd")
        refine_started = (os.path.isdir(sgd_ck)
                          and latest_step(sgd_ck) is not None)

    als_hist: List[dict] = []
    als_tel = None
    warm = None
    if not refine_started:
        fac, als_hist, als_tel = run_streaming_als(
            ratings, als_sched, als_cfg, ckpt_dir=als_ck, keep=keep,
            prefetch_depth=prefetch_depth, test_eval=test_eval,
            train_eval=train_eval, mesh=mesh, topology=topology,
            callback=lambda it, rec:
                tagged("als")(None, rec))
        # re-block the streamed factors to the grid's padded shape: the ALS
        # store is [m_pad, f] / [n, f], the SGD store [g*mb, f] / [g*nb, f]
        f = als_cfg.f
        x0 = np.zeros((grid.g * grid.mb, f), np.float32)
        t0 = np.zeros((grid.g * grid.nb, f), np.float32)
        if grid.user_perm is not None:    # grid rows live in permuted order
            x0[:grid.m] = fac.x[:grid.m][grid.user_perm]
        else:
            x0[:grid.m] = fac.x[:grid.m]
        t0[:grid.n] = fac.theta[:grid.n]
        warm = FactorStore.from_arrays(x0, t0)
    final, sgd_hist, sgd_tel = run_streaming_sgd(
        tiles, sgd_sched, sgd_cfg, factors=warm, ckpt_dir=sgd_ck, keep=keep,
        prefetch_depth=prefetch_depth, test_eval=test_eval,
        train_eval=train_eval, mesh=mesh, callback=tagged("sgd"))
    tel = merge_telemetry({"als": als_tel, "sgd": sgd_tel})
    return final, als_hist + sgd_hist, tel
