"""ALS-warm-start -> SGD-refine hybrid solver (Tan et al. 1808.03843).

ALS makes large, stable moves in the first few iterations (each sweep is
a closed-form block solve) but every iteration costs the full Hermitian +
Cholesky pipeline; SGD epochs are far cheaper per pass but need many
epochs from a cold start.  The hybrid runs a few ALS iterations on the
row/column PaddedELL shards, then hands the factors to the blocked SGD
driver *on the same rating data* (the BlockGrid is built from the very
same shards via ``blocking.block_ell``) for cheap refinement.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import als as als_mod
from repro.sgd.blocking import BlockGrid
from repro.sgd.train import SgdConfig, SgdState, pad_factor, sgd_train


def sgd_state_from_als(als_state: als_mod.AlsState,
                       grid: BlockGrid) -> SgdState:
    """Continue from an AlsState: pad factors to the grid's block shape.

    Padding rows (users/items beyond the true m/n) carry no ratings in
    any tile, so they are never touched by an epoch — the SGD trajectory
    starts exactly at the ALS iterate.
    """
    return SgdState(
        x=pad_factor(jnp.asarray(als_state.x), grid.g * grid.mb),
        theta=pad_factor(jnp.asarray(als_state.theta), grid.g * grid.nb),
        epoch=jnp.int32(0))


def hybrid_train(
    r, rt,
    grid: BlockGrid,
    als_cfg: als_mod.AlsConfig,
    sgd_cfg: SgdConfig,
    *,
    test: Optional[tuple] = None,
    train_eval: Optional[tuple] = None,
    ckpt_dir: Optional[str] = None,
    callback=None,
) -> tuple[SgdState, list[dict]]:
    """``als_cfg.iters`` ALS sweeps, then ``sgd_cfg.epochs`` SGD epochs.

    ``r`` / ``rt`` are the ALS-side (idx, val, cnt) triplets of R and R^T;
    ``grid`` is the blocked view of the same ratings.  History records are
    tagged ``phase: "als" | "sgd"`` (before the callback fires, so live
    progress printers see the tag too) and share the RMSE protocol.

    With ``ckpt_dir`` set and a committed checkpoint present, the ALS
    warm start is skipped entirely: the checkpoint already embeds it, and
    re-running ALS would burn its full cost only for ``sgd_train``'s
    restore to overwrite the result.
    """
    def tagged(phase):
        def cb(state, rec):
            rec["phase"] = phase
            if callback is not None:
                callback(state, rec)
        return cb

    state0 = None
    als_hist: list[dict] = []
    resuming = False
    if ckpt_dir is not None:
        import os

        from repro.checkpoint.store import latest_step
        resuming = (os.path.isdir(ckpt_dir)
                    and latest_step(ckpt_dir) is not None)
    if not resuming:
        als_state, als_hist = als_mod.als_train(
            r, rt, grid.m, grid.n, als_cfg, test=test,
            callback=tagged("als"))
        state0 = sgd_state_from_als(als_state, grid)
    final, sgd_hist = sgd_train(
        grid, sgd_cfg, test=test, train_eval=train_eval,
        init_state=state0, ckpt_dir=ckpt_dir, callback=tagged("sgd"))
    return final, als_hist + sgd_hist
