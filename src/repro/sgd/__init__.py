"""SGD solver subsystem (CuMF_SGD, arxiv 1610.05838) — peer of core/als.py.

Layers:

- ``blocking``  — g x g (user-block, item-block) matrix blocking of the
  rating COO plus the conflict-free diagonal block-set schedule;
- ``train``     — the batch-Hogwild epoch driver (lr schedules, RMSE
  tracking, checkpointing);
- ``hybrid``    — ALS-warm-start -> SGD-refine (Tan et al. 1808.03843).

The per-block update kernel lives with the other Pallas kernels in
``repro.kernels.sgd_update`` (oracle in ``repro.kernels.ref``).
"""
from repro.sgd.blocking import (BlockGrid, block_coo, block_ell,
                                diagonal_sets, ell_to_coo)
from repro.sgd.hybrid import (hybrid_train, run_streaming_hybrid,
                              sgd_state_from_als)
from repro.sgd.train import (SgdConfig, SgdState, epoch_set_order, sgd_epoch,
                             sgd_init, sgd_train)

__all__ = [
    "BlockGrid", "block_coo", "block_ell", "diagonal_sets", "ell_to_coo",
    "SgdConfig", "SgdState", "epoch_set_order", "sgd_epoch", "sgd_init",
    "sgd_train", "hybrid_train", "run_streaming_hybrid",
    "sgd_state_from_als",
]
