"""Batch-Hogwild SGD epoch driver (CuMF_SGD) over a BlockGrid.

One epoch walks the g conflict-free diagonal block-sets in order; every
tile in a set touches disjoint X and Theta rows, so tile updates within a
set commute (the lock-free property CuMF_SGD exploits — here they also
make the epoch deterministic).  Every rating is visited exactly once per
epoch.  The per-tile sweep is ``repro.kernels.sgd_update`` (Pallas kernel
or jnp oracle, same dispatch vocabulary as the ALS ops).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import rmse_padded
from repro.kernels.sgd_update import sgd_block_update
from repro.sgd.blocking import BlockGrid, diagonal_sets
from repro.training.optimizer import lr_schedule


@dataclasses.dataclass(frozen=True)
class SgdConfig:
    f: int                      # latent dimension
    lam: float                  # per-sample L2 strength
    lr: float = 0.08            # base learning rate
    epochs: int = 30
    schedule: str = "inverse_time"  # constant | inverse_time | cosine
    decay: Optional[float] = None   # inverse-time decay (None = 10/epochs)
    min_lr: float = 0.0             # cosine floor
    mode: str = "ref"           # kernel dispatch: ref | kernel | kernel_interpret
    row_mult: int = 8
    col_mult: int = 128
    f_mult: int = 128
    seed: int = 0
    init_scale: float = 0.3


class SgdState(NamedTuple):
    x: jax.Array          # [g*mb, f] user factors (padded rows past m unused)
    theta: jax.Array      # [g*nb, f] item factors (padded rows past n unused)
    epoch: jax.Array      # scalar int32


def epoch_lr(cfg: SgdConfig, epoch: int) -> float:
    """The scheduled learning rate for one epoch (host-side float)."""
    return float(lr_schedule(cfg.schedule, epoch, base_lr=cfg.lr,
                             total_steps=cfg.epochs, decay=cfg.decay,
                             min_lr=cfg.min_lr))


def sgd_init(grid: BlockGrid, cfg: SgdConfig) -> SgdState:
    """Uniform init at the grid's padded sizes (matches ``als_init`` scale)."""
    kx, kt = jax.random.split(jax.random.PRNGKey(cfg.seed))
    mp, np_ = grid.g * grid.mb, grid.g * grid.nb
    x = jax.random.uniform(kx, (mp, cfg.f), jnp.float32) * cfg.init_scale
    theta = jax.random.uniform(kt, (np_, cfg.f), jnp.float32) * cfg.init_scale
    return SgdState(x=x, theta=theta, epoch=jnp.int32(0))


def grid_triplet(grid: BlockGrid):
    """BlockGrid -> device triplet (idx [g,g,mb,K], val, cnt)."""
    return (jnp.asarray(grid.idx, jnp.int32),
            jnp.asarray(grid.val, jnp.float32),
            jnp.asarray(grid.cnt, jnp.int32))


def sgd_epoch(state: SgdState, gt, g: int, cfg: SgdConfig,
              lr: float) -> SgdState:
    """One full epoch: g diagonal sets x g independent tiles per set."""
    idx, val, cnt = gt
    mb, nb = idx.shape[2], -(-state.theta.shape[0] // g)
    f = cfg.f
    xb = state.x.reshape(g, mb, f)
    tb = state.theta.reshape(g, nb, f)
    lr_t = jnp.float32(lr)     # traced, so the lr decay never retriggers jit
    for tiles in diagonal_sets(g):
        for i, j in tiles:
            xi, tj = sgd_block_update(
                xb[i], tb[j], idx[i, j], val[i, j], cnt[i, j], lr_t,
                cfg.lam, mode=cfg.mode, row_mult=cfg.row_mult,
                col_mult=cfg.col_mult, f_mult=cfg.f_mult)
            xb = xb.at[i].set(xi)
            tb = tb.at[j].set(tj)
    return SgdState(x=xb.reshape(g * mb, f), theta=tb.reshape(g * nb, f),
                    epoch=state.epoch + 1)


def sgd_train(
    grid: BlockGrid,
    cfg: SgdConfig,
    *,
    test: Optional[tuple] = None,
    train_eval: Optional[tuple] = None,
    init_state: Optional[SgdState] = None,
    ckpt_dir: Optional[str] = None,
    callback=None,
) -> tuple[SgdState, list[dict]]:
    """Epoch loop with lr schedule, RMSE tracking, and checkpoint/resume.

    ``test`` / ``train_eval`` are global-coordinate (idx, val, cnt)
    triplets (the same eval protocol as ``als_train``); evaluation slices
    the padded factors back to the true (m, n).  With ``ckpt_dir`` the
    driver restores the latest epoch on entry and saves after every epoch
    (async, paper §4.4 protocol), so a killed run resumes bit-exact.
    """
    state = sgd_init(grid, cfg) if init_state is None else init_state
    start = int(state.epoch)
    mgr = None
    if ckpt_dir is not None:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(ckpt_dir, keep=2)
        restored, ck_epoch = mgr.restore_or_init(
            {"x": state.x, "theta": state.theta}, lambda: None)
        if ck_epoch:
            state = SgdState(x=jnp.asarray(restored["x"]),
                             theta=jnp.asarray(restored["theta"]),
                             epoch=jnp.int32(ck_epoch))
            start = ck_epoch
    gt = grid_triplet(grid)
    m, n = grid.m, grid.n
    history: list[dict] = []
    for ep in range(start, cfg.epochs):
        lr = epoch_lr(cfg, ep)
        state = sgd_epoch(state, gt, grid.g, cfg, lr)
        rec = {"epoch": ep + 1, "lr": lr}
        x, th = state.x[:m], state.theta[:n]
        if test is not None:
            rec["test_rmse"] = float(rmse_padded(x, th, *test))
        if train_eval is not None:
            rec["train_rmse"] = float(rmse_padded(x, th, *train_eval))
        history.append(rec)
        if mgr is not None:
            mgr.save(ep + 1, {"x": state.x, "theta": state.theta})
        if callback is not None:
            callback(state, rec)
    if mgr is not None:
        mgr.wait()
    return state, history


def pad_factor(a: jax.Array, rows_to: int) -> jax.Array:
    """Zero-pad a factor's leading axis up to the grid's padded row count."""
    extra = rows_to - a.shape[0]
    assert extra >= 0, (a.shape, rows_to)
    if extra == 0:
        return a
    return jnp.pad(a, ((0, extra), (0, 0)))


def factors_np(state: SgdState, grid: BlockGrid) -> tuple[np.ndarray, np.ndarray]:
    """Unpadded (X [m, f], Theta [n, f]) as numpy."""
    return (np.asarray(state.x[:grid.m]), np.asarray(state.theta[:grid.n]))
