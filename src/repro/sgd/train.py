"""Batch-Hogwild SGD epoch driver (CuMF_SGD) over a BlockGrid.

One epoch walks the g conflict-free diagonal block-sets in a per-epoch
shuffled order (CuMF_SGD randomizes the schedule: a fixed order biases
late-set blocks toward fresher factors); the permutation is PRNG-keyed on
``(cfg.seed, epoch)`` so runs are reproducible and checkpoint resume stays
bit-exact.  Every tile in a set touches disjoint X and Theta rows, so tile
updates within a set commute (the lock-free property CuMF_SGD exploits —
here they also make the epoch deterministic), and every rating is visited
exactly once per epoch.

The epoch itself is a single jitted ``lax.scan`` over the g sets: because a
set's g tiles are disjoint in both factors, they stack into ONE
``sgd_block_update`` call on ``[g*mb]`` user rows against the set's
permuted ``[g*nb]`` item blocks (tile i's block-local item indices shift by
``i*nb``).  That is O(1) host dispatches per epoch after the first trace,
instead of the g^2 per-tile Python dispatches of the unrolled loop.  The
per-tile sweep is ``repro.kernels.sgd_update`` (Pallas kernel or jnp
oracle, same dispatch vocabulary as the ALS ops).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import rmse_padded
from repro.kernels.sgd_update import sgd_block_update
from repro.obs.trace import current_tracer, phase
from repro.sgd.blocking import BlockGrid
from repro.training.optimizer import lr_schedule


@dataclasses.dataclass(frozen=True)
class SgdConfig:
    f: int                      # latent dimension
    lam: float                  # per-sample L2 strength
    lr: float = 0.08            # base learning rate
    epochs: int = 30
    schedule: str = "inverse_time"  # constant | inverse_time | cosine
    decay: Optional[float] = None   # inverse-time decay (None = 10/epochs)
    min_lr: float = 0.0             # cosine floor
    mode: str = "ref"           # kernel dispatch: ref | kernel | kernel_interpret
    row_mult: int = 8
    col_mult: int = 128
    f_mult: int = 128
    seed: int = 0
    init_scale: float = 0.3


class SgdState(NamedTuple):
    x: jax.Array          # [g*mb, f] user factors (padded rows past m unused)
    theta: jax.Array      # [g*nb, f] item factors (padded rows past n unused)
    epoch: jax.Array      # scalar int32


def epoch_lr(cfg: SgdConfig, epoch: int) -> float:
    """The scheduled learning rate for one epoch (host-side float)."""
    return float(lr_schedule(cfg.schedule, epoch, base_lr=cfg.lr,
                             total_steps=cfg.epochs, decay=cfg.decay,
                             min_lr=cfg.min_lr))


def sgd_init(grid: BlockGrid, cfg: SgdConfig) -> SgdState:
    """Uniform init at the grid's padded sizes (matches ``als_init`` scale)."""
    kx, kt = jax.random.split(jax.random.PRNGKey(cfg.seed))
    mp, np_ = grid.g * grid.mb, grid.g * grid.nb
    x = jax.random.uniform(kx, (mp, cfg.f), jnp.float32) * cfg.init_scale
    theta = jax.random.uniform(kt, (np_, cfg.f), jnp.float32) * cfg.init_scale
    return SgdState(x=x, theta=theta, epoch=jnp.int32(0))


def grid_triplet(grid: BlockGrid):
    """BlockGrid -> device triplet (idx [g,g,mb,K], val, cnt)."""
    return (jnp.asarray(grid.idx, jnp.int32),
            jnp.asarray(grid.val, jnp.float32),
            jnp.asarray(grid.cnt, jnp.int32))


def epoch_set_order(seed: int, epoch: int, g: int) -> jax.Array:
    """The epoch's diagonal-set visit order: a PRNG permutation of
    ``range(g)`` keyed on ``(seed, epoch)`` — deterministic per epoch, so a
    checkpoint resume replays exactly the order the killed run would have
    used (CuMF_SGD's schedule randomization, made reproducible)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), epoch)
    return jax.random.permutation(key, g)


def sgd_tiles_update(x, theta, idx, val, cnt, lr, lam, *, mode, row_mult,
                     col_mult, f_mult):
    """One batch-Hogwild sweep over t mutually DISJOINT tiles, stacked
    into a single ``sgd_block_update`` dispatch.

    ``x [t, mb, f]`` / ``theta [t, nb, f]`` are tile k's two factor
    blocks; ``idx [t, mb, K]`` holds block-local item indices.  Shifting
    tile k's indices by ``k*nb`` turns the stack into one [t*mb] x [t*nb]
    block update with identical semantics: in-slot collisions only ever
    involve items of one tile, whose index ranges stay disjoint after the
    shift.  The in-core scan epoch and the streaming SGD driver both go
    through here — their parity depends on sharing this exact stacking.
    """
    t, mb, f = x.shape
    nb = theta.shape[1]
    K = idx.shape[-1]
    offs = (jnp.arange(t) * nb)[:, None, None]
    x2, t2 = sgd_block_update(
        x.reshape(t * mb, f), theta.reshape(t * nb, f),
        (idx + offs).reshape(t * mb, K), val.reshape(t * mb, K),
        cnt.reshape(t * mb), lr, lam, mode=mode, row_mult=row_mult,
        col_mult=col_mult, f_mult=f_mult)
    return x2.reshape(t, mb, f), t2.reshape(t, nb, f)


@functools.partial(
    jax.jit,
    static_argnames=("g", "lam", "mode", "row_mult", "col_mult", "f_mult"))
def _scan_epoch(xb, tb, idx, val, cnt, set_order, lr, *, g, lam,
                mode, row_mult, col_mult, f_mult):
    """lax.scan over diagonal sets; one stacked tile sweep per set.

    Set s's tiles are (i, (i+s) % g): disjoint user blocks AND disjoint
    item blocks, so gathering the permuted item blocks ``tb[(i+s) % g]``
    stacks the whole set into one ``sgd_tiles_update`` call.
    """
    ar = jnp.arange(g)

    def body(carry, s):
        xb, tb = carry
        j = (ar + s) % g                       # item block of tile i
        x_new, t_new = sgd_tiles_update(
            xb, tb[j], idx[ar, j], val[ar, j], cnt[ar, j], lr, lam,
            mode=mode, row_mult=row_mult, col_mult=col_mult, f_mult=f_mult)
        return (x_new, tb.at[j].set(t_new)), None

    (xb, tb), _ = jax.lax.scan(body, (xb, tb), set_order)
    return xb, tb


def _set_k_groups(grid: BlockGrid, s: int):
    """Diagonal set ``s``'s tiles grouped by per-tile K: [(K_t, ii, jj)].

    Tiles within a set are disjoint in both factors, so splitting the set
    into same-K groups and sweeping the groups sequentially is exactly the
    one-stack sweep — each group still batches through one
    ``sgd_tiles_update`` dispatch at its own (tighter) K.
    """
    by_k: dict[int, list[tuple[int, int]]] = {}
    for i in range(grid.g):
        j = (i + s) % grid.g
        by_k.setdefault(grid.tile_k(i, j), []).append((i, j))
    return [(k, np.array([ij[0] for ij in ts], dtype=np.int64),
             np.array([ij[1] for ij in ts], dtype=np.int64))
            for k, ts in sorted(by_k.items())]


def _grouped_epoch(xb, tb, idx, val, cnt, set_order, lr, grid: BlockGrid,
                   cfg: SgdConfig):
    """Per-tile-K epoch: host loop over sets, one stacked dispatch per
    same-K group, each sliced to that group's K (trailing slot columns of
    a tile are all-padding, so the slice drops only masked no-op slots —
    grouped and uniform epochs are numerically identical)."""
    for s in np.asarray(set_order).tolist():
        for k_t, ii, jj in _set_k_groups(grid, int(s)):
            x_new, t_new = sgd_tiles_update(
                xb[ii], tb[jj], idx[ii, jj, :, :k_t], val[ii, jj, :, :k_t],
                cnt[ii, jj], jnp.float32(lr), cfg.lam, mode=cfg.mode,
                row_mult=cfg.row_mult, col_mult=cfg.col_mult,
                f_mult=cfg.f_mult)
            xb = xb.at[ii].set(x_new)
            tb = tb.at[jj].set(t_new)
    return xb, tb


def sgd_epoch(state: SgdState, gt, grid: BlockGrid, cfg: SgdConfig,
              lr: float, *, set_order=None) -> SgdState:
    """One full epoch: g diagonal sets x g independent tiles per set.

    ``grid`` supplies the authoritative block shape — ``nb`` in particular
    must NOT be recomputed from ``state.theta.shape`` (a caller passing
    factors padded beyond ``g*nb`` would silently mis-slice every theta
    block), so shapes are asserted at entry instead.  ``set_order`` is the
    epoch's set permutation (``epoch_set_order``); None keeps the canonical
    0..g-1 order.

    A grid with a non-uniform ``tile_K`` routes through the grouped
    per-tile-K epoch (same math, tighter slot slices); uniform grids keep
    the single jitted ``lax.scan``.
    """
    idx, val, cnt = gt
    g, mb, nb, f = grid.g, grid.mb, grid.nb, cfg.f
    assert idx.shape == (g, g, mb, idx.shape[-1]), (idx.shape, g, mb)
    assert state.x.shape == (g * mb, f), (state.x.shape, g, mb, f)
    assert state.theta.shape == (g * nb, f), (state.theta.shape, g, nb, f)
    if set_order is None:
        set_order = jnp.arange(g)
    lr_t = jnp.float32(lr)     # traced, so the lr decay never retriggers jit
    binned = (grid.tile_K is not None
              and int(grid.tile_K.min()) < grid.K)
    if binned:
        xb, tb = _grouped_epoch(
            state.x.reshape(g, mb, f), state.theta.reshape(g, nb, f),
            idx, val, cnt, set_order, lr, grid, cfg)
    else:
        xb, tb = _scan_epoch(
            state.x.reshape(g, mb, f), state.theta.reshape(g, nb, f),
            idx, val, cnt, jnp.asarray(set_order), lr_t, g=g,
            lam=cfg.lam, mode=cfg.mode, row_mult=cfg.row_mult,
            col_mult=cfg.col_mult, f_mult=cfg.f_mult)
    return SgdState(x=xb.reshape(g * mb, f), theta=tb.reshape(g * nb, f),
                    epoch=state.epoch + 1)


def sgd_train(
    grid: BlockGrid,
    cfg: SgdConfig,
    *,
    test: Optional[tuple] = None,
    train_eval: Optional[tuple] = None,
    init_state: Optional[SgdState] = None,
    ckpt_dir: Optional[str] = None,
    callback=None,
    tracer=None,
    registry=None,
) -> tuple[SgdState, list[dict]]:
    """Epoch loop with lr schedule, RMSE tracking, and checkpoint/resume.

    ``test`` / ``train_eval`` are global-coordinate (idx, val, cnt)
    triplets (the same eval protocol as ``als_train``); evaluation slices
    the padded factors back to the true (m, n).  With ``ckpt_dir`` the
    driver restores the latest epoch on entry and saves after every epoch
    (async, paper §4.4 protocol), so a killed run resumes bit-exact.

    Each epoch runs in an ``epoch`` obs span (plus a ``checkpoint`` span
    per commit); ``tracer`` defaults to the process-wide tracer and the
    spans are no-ops unless one is enabled.
    """
    tracer = tracer if tracer is not None else current_tracer()
    state = sgd_init(grid, cfg) if init_state is None else init_state
    start = int(state.epoch)
    mgr = None
    if ckpt_dir is not None:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(ckpt_dir, keep=2)
        restored, ck_epoch = mgr.restore_or_init(
            {"x": state.x, "theta": state.theta}, lambda: None)
        if ck_epoch:
            state = SgdState(x=jnp.asarray(restored["x"]),
                             theta=jnp.asarray(restored["theta"]),
                             epoch=jnp.int32(ck_epoch))
            start = ck_epoch
    gt = grid_triplet(grid)
    history: list[dict] = []
    for ep in range(start, cfg.epochs):
        lr = epoch_lr(cfg, ep)
        with phase("sgd.epoch", cat="epoch", tracer=tracer,
                   registry=registry, epoch=ep + 1, lr=lr):
            state = sgd_epoch(state, gt, grid, cfg, lr,
                              set_order=epoch_set_order(cfg.seed, ep,
                                                        grid.g))
            jax.block_until_ready(state.x)
        rec = {"epoch": ep + 1, "lr": lr}
        x, th = eval_factors(state, grid)
        if test is not None:
            rec["test_rmse"] = float(rmse_padded(x, th, *test))
        if train_eval is not None:
            rec["train_rmse"] = float(rmse_padded(x, th, *train_eval))
        history.append(rec)
        if mgr is not None:
            # host copies, not the live device arrays: the manager commits
            # on a background thread, and a donated/in-place update of
            # state.x would race the writer (outofcore/driver.py snapshots
            # the same way)
            with phase("checkpoint.commit", cat="checkpoint",
                       tracer=tracer, registry=registry, step=ep + 1):
                mgr.save(ep + 1, {"x": np.array(state.x),
                                  "theta": np.array(state.theta)})
        if callback is not None:
            callback(state, rec)
    if mgr is not None:
        mgr.wait()
    return state, history


def pad_factor(a: jax.Array, rows_to: int) -> jax.Array:
    """Zero-pad a factor's leading axis up to the grid's padded row count."""
    extra = rows_to - a.shape[0]
    assert extra >= 0, (a.shape, rows_to)
    if extra == 0:
        return a
    return jnp.pad(a, ((0, extra), (0, 0)))


def eval_factors(state: SgdState, grid: BlockGrid):
    """(X [m, f], Theta [n, f]) in ORIGINAL global coordinates: undoes the
    grid's degree-sort user permutation (identity on unsorted grids) and
    slices off the block-padding rows — the only correct view for any
    global-coordinate evaluation or hand-off."""
    if grid.user_perm is None:
        return state.x[:grid.m], state.theta[:grid.n]
    return (jnp.take(state.x, jnp.asarray(grid.user_inv), axis=0),
            state.theta[:grid.n])


def factors_np(state: SgdState, grid: BlockGrid) -> tuple[np.ndarray, np.ndarray]:
    """Unpadded (X [m, f], Theta [n, f]) as numpy, original row order."""
    x, th = eval_factors(state, grid)
    return (np.asarray(x), np.asarray(th))
