"""Cell builders: (arch x shape x mesh) -> AOT-lowerable programs.

Shared by launch/dryrun.py (lower + compile + memory proof) and
benchmarks/roofline.py (cost extraction).  Everything here works on
ShapeDtypeStructs only — no device allocation ever happens.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ArchSpec, ModelConfig, ShapeConfig, SHAPES
from repro.distributed import sharding as shp
from repro.models import lm as lm_mod
from repro.models import transformer as T
from repro.training import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class CellOpts:
    """Implementation knobs a §Perf iteration can flip per cell."""
    causal_skip: bool = False
    fused_loss: bool = False
    chunk_q: int = 512
    chunk_kv: int = 512
    pod_compress: bool = False
    remat: bool = True
    microbatch: Optional[int] = None     # override ArchSpec.microbatch
    tp1: bool = False   # re-map "model" axis to pure data parallel (256-way
                        # FSDP, no tensor parallelism) — the fix for small
                        # dense models whose TP activation psums dominate


def dp_size(mesh: Mesh) -> int:
    return int(jax.numpy.prod(jnp.asarray(
        [mesh.shape[a] for a in shp.dp_axes(mesh)])))


def _dp_spec(mesh: Mesh, batch: int, tp1: bool = False):
    axes = shp.dp_axes(mesh)
    if tp1 and "model" in mesh.axis_names:
        axes = axes + ("model",)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if batch % n != 0:
        return None          # e.g. long_500k batch=1: replicate
    return axes if len(axes) > 1 else axes[0]


def param_structs(cfg: ModelConfig, policy: shp.ShardingPolicy, mesh: Mesh,
                  dtype=jnp.float32):
    shapes = T.param_shapes(cfg)

    def mk(leaf):
        shape, axes = leaf
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(mesh, policy.spec(axes)))

    return jax.tree.map(
        mk, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def _like(struct, ref_struct):
    """ShapeDtypeStruct with ref's sharding if shapes match, else replicate
    trailing-compatible spec (adafactor factored stats)."""
    return struct


def train_state_structs(spec: ArchSpec, mesh: Mesh, tp1: bool = False):
    cfg = spec.model
    policy = _train_policy(spec, mesh, tp1=tp1)
    p_structs = param_structs(cfg, policy, mesh, jnp.float32)

    if spec.opt == "adamw":
        m = jax.tree.map(lambda s: s, p_structs)
        v = jax.tree.map(lambda s: s, p_structs)
        opt = opt_mod.AdamState(m=m, v=v, step=jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P())))
    else:  # adafactor: row/col stats lose the last / second-to-last dim
        def vr_of(s):
            shape = s.shape[:-1] if len(s.shape) >= 2 else s.shape
            spec_ = s.sharding.spec
            sub = P(*spec_[:len(shape)]) if len(spec_) >= len(shape) else P()
            return jax.ShapeDtypeStruct(shape, jnp.float32,
                                        sharding=NamedSharding(mesh, sub))

        def vc_of(s):
            if len(s.shape) >= 2:
                shape = s.shape[:-2] + s.shape[-1:]
                spec_ = list(s.sharding.spec) + [None] * (len(s.shape) - len(s.sharding.spec))
                sub = P(*(spec_[:-2] + spec_[-1:]))
            else:
                shape, sub = (1,), P()
            return jax.ShapeDtypeStruct(shape, jnp.float32,
                                        sharding=NamedSharding(mesh, sub))
        opt = opt_mod.AdafactorState(
            vr=jax.tree.map(vr_of, p_structs),
            vc=jax.tree.map(vc_of, p_structs),
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())))
    return lm_mod.TrainState(
        params=p_structs, opt=opt,
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P())))


def _train_policy(spec: ArchSpec, mesh: Mesh,
                  tp1: bool = False) -> shp.ShardingPolicy:
    cfg = spec.model
    tp = mesh.shape["model"]
    tp_heads = cfg.padded_heads % tp == 0 and cfg.padded_heads >= tp
    tp_kv = cfg.n_kv % tp == 0
    pol = shp.train_policy(mesh, tp_heads=tp_heads, tp_kv=tp_kv,
                           fsdp=spec.fsdp)
    if tp1:
        # every weight 1D-sharded over the merged ("data","model") axis;
        # batch shards over both axes; zero TP collectives remain
        both = ("data", "model")
        pol = pol.with_overrides(
            name="train_tp1", vocab=both, embed_d=None,
            d_model_in=both, d_model_out=both, attn_din=both,
            attn_dout=both, qheads=None, kv_heads=None, ff=None,
            experts=None, rnn=None)
    return pol


def _serve_policy(spec: ArchSpec, mesh: Mesh) -> shp.ShardingPolicy:
    cfg = spec.model
    tp = mesh.shape["model"]
    tp_heads = cfg.padded_heads % tp == 0 and cfg.padded_heads >= tp
    tp_kv = cfg.n_kv % tp == 0
    return shp.serve_policy(mesh, tp_heads=tp_heads, tp_kv=tp_kv,
                            mlp_2d=spec.serve_mlp_2d,
                            seq_shard_cache=spec.serve_seq_shard)


def build_train_cell(spec: ArchSpec, shape: ShapeConfig, mesh: Mesh,
                     opts: CellOpts = CellOpts()):
    """Returns (fn, args) ready for jax.jit(fn, ...).lower(*args)."""
    cfg = spec.model
    mb = opts.microbatch or spec.microbatch
    dpn = dp_size(mesh) * (mesh.shape["model"] if opts.tp1 else 1)
    mb = max(1, min(mb, shape.batch // dpn))
    opt_cfg = opt_mod.OptConfig(name=spec.opt)
    step = lm_mod.make_train_step(
        cfg, opt_cfg, mesh=mesh, microbatch=mb,
        remat=opts.remat and spec.remat, fused_loss=opts.fused_loss,
        causal_skip=opts.causal_skip, chunk_q=opts.chunk_q,
        chunk_kv=opts.chunk_kv, pod_compress=opts.pod_compress)
    state = train_state_structs(spec, mesh, tp1=opts.tp1)
    dp = _dp_spec(mesh, shape.batch, tp1=opts.tp1)
    batch = registry.input_specs(cfg, shape, mesh=mesh, dp_spec=dp)
    meta = {"microbatch": mb, "opt": spec.opt, "tp1": opts.tp1}
    return step, (state, batch), {"donate_argnums": (0,)}, meta


def build_prefill_cell(spec: ArchSpec, shape: ShapeConfig, mesh: Mesh,
                       opts: CellOpts = CellOpts()):
    cfg = spec.model
    prefill = lm_mod.make_prefill_step(
        cfg, mesh=mesh, serve_seq_shard=spec.serve_seq_shard,
        chunk_q=opts.chunk_q, chunk_kv=opts.chunk_kv,
        causal_skip=opts.causal_skip)
    policy = _serve_policy(spec, mesh)
    params = param_structs(cfg, policy, mesh, jnp.bfloat16)
    dp = _dp_spec(mesh, shape.batch)
    batch = registry.input_specs(cfg, shape, mesh=mesh, dp_spec=dp)
    # pin the produced cache to the decode-time layout (seq over "model"
    # for flash-decode archs) — otherwise the [L, B, S, KV, dh] output is
    # only batch-sharded and blows the per-device budget.
    cache_struct = registry.cache_specs(
        cfg, shape, mesh=mesh, dp_spec=dp,
        seq_shard_cache=spec.serve_seq_shard, stacked=True)
    out_shardings = (NamedSharding(mesh, P(dp)),
                     jax.tree.map(lambda s: s.sharding, cache_struct))
    return prefill, (params, batch), {"out_shardings": out_shardings}, {}


def build_decode_cell(spec: ArchSpec, shape: ShapeConfig, mesh: Mesh,
                      opts: CellOpts = CellOpts()):
    cfg = spec.model
    decode = lm_mod.make_decode_step(
        cfg, mesh=mesh, serve_seq_shard=spec.serve_seq_shard)
    policy = _serve_policy(spec, mesh)
    params = param_structs(cfg, policy, mesh, jnp.bfloat16)
    dp = _dp_spec(mesh, shape.batch)
    inp = registry.input_specs(cfg, shape, mesh=mesh, dp_spec=dp)
    cache = registry.cache_specs(cfg, shape, mesh=mesh, dp_spec=dp,
                                 seq_shard_cache=spec.serve_seq_shard)
    out_shardings = (NamedSharding(mesh, P(dp)),
                     jax.tree.map(lambda s: s.sharding, cache),
                     NamedSharding(mesh, P(dp)))
    return (decode, (params, cache, inp["tokens_or_embeds"], inp["lengths"]),
            {"donate_argnums": (1,), "out_shardings": out_shardings}, {})


def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               opts: CellOpts = CellOpts()):
    """Dispatch on the shape kind.  Returns (fn, args, jit_kwargs, meta)."""
    spec = registry.get_arch(arch_id)
    shape = SHAPES[shape_name]
    skip = spec.skip_reason(shape)
    if skip:
        return None, None, None, {"skip": skip}
    if shape.kind == "train":
        return build_train_cell(spec, shape, mesh, opts)
    if shape.kind == "prefill":
        return build_prefill_cell(spec, shape, mesh, opts)
    return build_decode_cell(spec, shape, mesh, opts)


# ---------------------------------------------------------------------------
# ALS cells (the paper's own workload)
# ---------------------------------------------------------------------------

def build_als_cell(shape_name: str, mesh: Mesh, *, scheme: str = "two_phase",
                   row_block: int = 2048, f_pad: Optional[int] = None):
    """One SU-ALS update-X wave at a Table-5 dataset scale."""
    from repro.configs.cumf_als import ALS_SHAPES
    from repro.distributed import su_als

    als = ALS_SHAPES[shape_name]
    spec = als.spec
    col_axes = tuple(a for a in ("model", "pod") if a in mesh.axis_names)
    p_total = 1
    for a in col_axes:
        p_total *= mesh.shape[a]
    q = mesh.shape["data"]
    f = f_pad or spec.f

    m_wave = als.rows_per_wave
    granule = q * p_total * row_block
    m_wave = max(granule, (m_wave // granule) * granule)
    n_pad = -(-spec.n // p_total) * p_total
    k_loc = als.k_pad

    ux, ut, it = su_als.make_su_als_fns(
        mesh, spec.lam, scheme=scheme, mode="ref", row_block=row_block,
        f_mult=128)

    col_dim = col_axes[::-1] if len(col_axes) > 1 else col_axes[0]
    theta = jax.ShapeDtypeStruct(
        (n_pad, f), jnp.float32,
        sharding=NamedSharding(mesh, P(col_dim, None)))
    idx = jax.ShapeDtypeStruct(
        (m_wave, p_total * k_loc), jnp.int32,
        sharding=NamedSharding(mesh, P("data", col_dim)))
    val = jax.ShapeDtypeStruct(
        (m_wave, p_total * k_loc), jnp.float32,
        sharding=NamedSharding(mesh, P("data", col_dim)))
    cnt = jax.ShapeDtypeStruct(
        (m_wave, p_total), jnp.int32,
        sharding=NamedSharding(mesh, P("data", col_dim)))
    meta = {"m_wave": m_wave, "k_loc": k_loc, "p": p_total, "q": q,
            "f": f, "scheme": scheme, "row_block": row_block,
            "waves_total": max(1, -(-spec.m // m_wave))}
    return ux, (theta, idx, val, cnt), (), meta
