"""Production meshes.

All functions build meshes lazily — importing this module never touches JAX
device state (required so that smoke tests see 1 CPU device while the
dry-run sees 512 placeholder devices via XLA_FLAGS).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one TPU v5e pod (16 x 16 = 256 chips) or
    two pods (2 x 16 x 16 = 512 chips).

    Axis roles:
      "data"  — DP/FSDP for LMs; cuMF's q (X row shards) for ALS.
      "model" — TP/EP/SP for LMs; cuMF's p (Theta column shards) for ALS.
      "pod"   — extra DP replica set for LMs; extra column shards + the slow
                link of the two-phase topology-aware reduction for ALS.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, axis_types="auto")


def make_mesh(shape, axes):
    """Small/test meshes with the same axis conventions."""
    return compat.make_mesh(tuple(shape), tuple(axes), axis_types="auto")


# Hardware constants of the target (TPU v5e-class chip) — single source of
# truth for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # flop/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (intra-pod)
DCI_BW = 6.25e9               # bytes/s per chip (inter-pod data-center links)
HBM_BYTES = 16 * (1 << 30)    # 16 GiB HBM per chip
VMEM_BYTES = 16 * (1 << 20)
