import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
- the sharding is coherent (GSPMD partitions every op),
- it fits (memory_analysis against the 16 GiB/chip budget),
- and it yields the cost/collective numbers §Roofline consumes.

Artifacts land in experiments/dryrun/<cell>.json.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    python -m repro.launch.dryrun --als netflix
"""

import argparse
import json
import re
import traceback

import jax

from repro.launch.mesh import make_production_mesh, HBM_BYTES
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import phase

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")

_COLLECTIVE_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=\n]*\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^\n]*)")

_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))            # [n_groups, group_size]
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return m.group(1).count(",") + 1
    return 2


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire-byte estimate per collective kind from HLO text.

    The shape left of an HLO collective is its per-device RESULT; with
    replica-group size g, ring-algorithm bytes through each device's links:
      all-gather         r*(g-1)/g
      reduce-scatter     r*(g-1)      (result is 1/g of the input)
      all-reduce         2*r*(g-1)/g
      all-to-all         r*(g-1)/g
      collective-permute r
    Ops are counted once; loop-body trip-count scaling happens in the
    roofline harness where multiplicities are known."""
    per_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind, rest = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        r = float(n * _DTYPE_BYTES[dtype])
        g = max(_group_size(rest), 2)
        if kind == "all-gather":
            wire = r * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = r * (g - 1)
        elif kind == "all-reduce":
            wire = 2.0 * r * (g - 1) / g
        elif kind == "all-to-all":
            wire = r * (g - 1) / g
        else:
            wire = r
        per_kind[kind] = per_kind.get(kind, 0.0) + wire
        count[kind] = count.get(kind, 0) + 1
    return {"bytes": per_kind, "count": count,
            "total_bytes": sum(per_kind.values())}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             opts=None) -> dict:
    from repro.launch import builders

    mesh = make_production_mesh(multi_pod=multi_pod)
    opts = opts or builders.CellOpts()
    fn, args, jit_kwargs, meta = builders.build_cell(
        arch_id, shape_name, mesh, opts)
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
           "meta": meta}
    if fn is None:
        rec["status"] = "skip"
        return rec

    reg = MetricsRegistry()       # obs-clocked lower/compile timings
    with mesh:
        with phase("dryrun.lower", cat="lower", registry=reg,
                   arch=arch_id, shape=shape_name):
            lowered = jax.jit(fn, **jit_kwargs).lower(*args)
        with phase("dryrun.compile", cat="compile", registry=reg,
                   arch=arch_id, shape=shape_name):
            compiled = lowered.compile()
    ph = reg.phase_seconds()
    ma = compiled.memory_analysis()
    print(ma)
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    rec.update({
        "status": "ok",
        "lower_s": round(ph["lower"], 2),
        "compile_s": round(ph["compile"], 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
            "hbm_budget_bytes": HBM_BYTES,
        },
        "cost": {"flops": ca.get("flops", 0.0),
                 "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "collectives": parse_collectives(compiled.as_text()),
    })
    peak = rec["memory"]["peak_estimate_bytes"]
    rec["memory"]["fits_xla_cpu"] = bool(peak < HBM_BYTES)
    # XLA:CPU buffer assignment does not reuse shard_map boundary buffers
    # across an unrolled decode's layers (each layer's cache shard gets a
    # fresh temp), so temp_bytes overcounts by ~n_layers x per-layer
    # working set.  The true live set of a step is arguments (params +
    # donated caches, updated in place) + outputs-not-aliased + one layer's
    # working set; TPU compilation aliases donated buffers through manual
    # regions.  Both checks are recorded; EXPERIMENTS.md reports them.
    per_layer_ws = rec["memory"]["temp_bytes"] / max(
        _n_layers_of(arch_id), 1)
    live = (rec["memory"]["argument_bytes"]
            + rec["memory"]["output_bytes"]
            - rec["memory"]["alias_bytes"]
            + 2 * per_layer_ws)
    rec["memory"]["live_set_estimate_bytes"] = int(live)
    rec["memory"]["fits"] = bool(min(peak, live) < HBM_BYTES)
    return rec


def _n_layers_of(arch_id: str) -> int:
    from repro.configs import registry
    try:
        return registry.get_arch(arch_id).model.n_layers
    except Exception:
        return 1


def run_als_cell(als_name: str, multi_pod: bool, scheme="two_phase") -> dict:
    from repro.launch import builders

    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, _, meta = builders.build_als_cell(
        als_name, mesh, scheme=scheme)
    rec = {"arch": "cumf-als", "shape": als_name,
           "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
           "meta": meta}
    reg = MetricsRegistry()       # obs-clocked lower+compile timing
    with mesh:
        with phase("dryrun.compile", cat="compile", registry=reg,
                   als=als_name, scheme=scheme):
            lowered = jax.jit(fn).lower(*args)
            compiled = lowered.compile()
    ma = compiled.memory_analysis()
    print(ma)
    ca = compiled.cost_analysis()
    rec.update({
        "status": "ok",
        "compile_s": round(reg.phase_seconds()["compile"], 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes,
            "hbm_budget_bytes": HBM_BYTES,
        },
        "cost": {"flops": ca.get("flops", 0.0),
                 "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "collectives": parse_collectives(compiled.as_text()),
    })
    rec["memory"]["fits"] = bool(
        rec["memory"]["peak_estimate_bytes"] < HBM_BYTES)
    return rec


def _save(rec: dict, tag: str):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec.get("status")
    fits = rec.get("memory", {}).get("fits")
    print(f"[dryrun] {tag}: {status}"
          + (f" fits={fits}" if fits is not None else ""), flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--als")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--fused-loss", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact is already ok/skip")
    args = ap.parse_args()

    from repro.configs import registry
    from repro.configs.base import SHAPES
    from repro.configs.cumf_als import ALS_SHAPES
    from repro.launch import builders

    pods = []
    if args.multi_pod or not args.single_pod:
        pods.append(True)
    if args.single_pod or not args.multi_pod:
        pods.insert(0, False)

    opts = builders.CellOpts(causal_skip=args.causal_skip,
                             fused_loss=args.fused_loss)

    cells = []
    if args.als:
        for mp in pods:
            tag = f"als_{args.als}_{'mp' if mp else 'sp'}"
            try:
                _save(run_als_cell(args.als, mp), tag)
            except Exception:
                _save({"status": "error", "trace": traceback.format_exc()}, tag)
        return
    if args.all:
        cells = [(a, s) for a in registry.list_archs() for s in SHAPES]
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    else:
        ap.error("need --arch/--shape, --als, or --all")

    failures = 0
    for arch_id, shape_name in cells:
        for mp in pods:
            tag = f"{arch_id}_{shape_name}_{'mp' if mp else 'sp'}"
            path = os.path.join(ARTIFACT_DIR, f"{tag}.json")
            if args.resume and os.path.exists(path):
                try:
                    prev = json.load(open(path))
                    if prev.get("status") in ("ok", "skip") and (
                            prev.get("status") == "skip"
                            or prev.get("memory", {}).get("fits")):
                        print(f"[dryrun] {tag}: cached ok", flush=True)
                        continue
                except Exception:
                    pass
            try:
                rec = run_cell(arch_id, shape_name, mp, opts)
                _save(rec, tag)
                if rec.get("status") == "ok" and not rec["memory"]["fits"]:
                    failures += 1
            except Exception:
                _save({"arch": arch_id, "shape": shape_name,
                       "status": "error",
                       "trace": traceback.format_exc()}, tag)
                failures += 1
    print(f"[dryrun] done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
