"""Launchers: production mesh, multi-pod dry-run, train/serve/ALS drivers."""
