"""``python -m repro.analysis`` — run reprolint over the repo.

Exit status: 0 when every finding is suppressed or grandfathered in the
baseline, 1 when new findings exist (the CI lint job's failure signal),
2 on usage errors.

Selection mirrors ``benchmarks/run.py``: ``--rule <name>`` is repeatable
and unknown names fail loudly with the full catalog instead of silently
matching nothing.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis.engine import AnalysisConfig, Baseline, run_analysis
from repro.analysis.rules import ALL_RULES, get_rules, rule_names

DEFAULT_BASELINE = "reprolint_baseline.json"


def find_repo_root(start: pathlib.Path) -> pathlib.Path:
    """Nearest ancestor carrying pyproject.toml (the scan anchor)."""
    for cand in [start] + list(start.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", type=pathlib.Path,
                    help="explicit files to check (default: each rule's "
                         "declared roots under the repo root)")
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="repo root (default: nearest ancestor of cwd with "
                         "a pyproject.toml)")
    ap.add_argument("--rule", action="append", default=[], metavar="NAME",
                    help="run only the named rule (repeatable); names: "
                         f"{', '.join(rule_names())}")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="FILE", help="emit findings as JSON to FILE "
                                         "(or stdout with no argument)")
    ap.add_argument("--baseline", type=pathlib.Path, default=None,
                    metavar="FILE",
                    help=f"baseline file (default: <root>/{DEFAULT_BASELINE} "
                         "when it exists)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as the new baseline "
                         "(preserves existing justifications) and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.name}: {r.description}")
        return 0

    try:
        rules = get_rules(args.rule)
    except ValueError as e:
        ap.error(str(e))        # exits 2, like run.py's unknown --only

    root = (args.root or find_repo_root(pathlib.Path.cwd())).resolve()
    baseline_path = args.baseline or (root / DEFAULT_BASELINE)
    baseline = None
    if baseline_path.is_file():
        try:
            baseline = Baseline.load(baseline_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    paths = [p.resolve() for p in args.paths] or None
    cfg = AnalysisConfig(root=root, rules=rules, baseline=baseline,
                         paths=paths)
    new, grandfathered = run_analysis(cfg)

    if args.write_baseline:
        Baseline.write(baseline_path, new + grandfathered, old=baseline)
        print(f"wrote {len(new) + len(grandfathered)} finding(s) to "
              f"{baseline_path}")
        return 0

    if args.json is not None:
        payload = {
            "root": str(root),
            "rules": [r.name for r in rules],
            "new": [f.to_json() for f in new],
            "grandfathered": [f.to_json() for f in grandfathered],
        }
        if args.json == "-":
            json.dump(payload, sys.stdout, indent=2)
            print()
        else:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2)

    for f in new:
        print(f.format())
    n_rules = len(rules)
    print(f"reprolint: {len(new)} new finding(s), "
          f"{len(grandfathered)} grandfathered, {n_rules} rule(s)",
          file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
