"""reprolint — repo-native static analysis for the invariants PRs 1-5
learned the hard way.

The compiler never checks the contracts this codebase's performance and
bit-exactness story rests on: kernels must fit a declared per-device VMEM
budget (paper eq. 5-8), Hermitian partials must stay float64 for the
topology-aware reduction to be bit-exact, shard_map call sites must agree
with the mesh builders' axis vocabulary, version-sensitive JAX surfaces
must route through ``repro.compat``, and checkpoint commit paths must
receive materialized copies, not live device arrays.  Each of those was
re-discovered at runtime in an earlier PR; this package encodes them once
as AST rules so they are checked on every PR instead of re-debugged.

Usage::

    PYTHONPATH=src python -m repro.analysis                # human output
    PYTHONPATH=src python -m repro.analysis --json out.json
    PYTHONPATH=src python -m repro.analysis --rule compat-routing
    PYTHONPATH=src python -m repro.analysis --write-baseline

See ANALYSIS.md at the repo root for the rule catalog, the suppression
syntax (``# reprolint: disable=<rule>``) and the baseline workflow.
"""
from repro.analysis.engine import (AnalysisConfig, Baseline, Finding,
                                   ParsedModule, Rule, iter_python_files,
                                   run_analysis)
from repro.analysis.rules import ALL_RULES, get_rules, rule_names

__all__ = [
    "ALL_RULES", "AnalysisConfig", "Baseline", "Finding", "ParsedModule",
    "Rule", "get_rules", "iter_python_files", "rule_names", "run_analysis",
]
