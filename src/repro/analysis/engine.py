"""Rule engine: file walker, Finding records, suppressions, baseline.

Stdlib-only on purpose (``ast``, no jax/numpy): the lint CI job and the
import sweep must be able to load this module in any environment the repo
itself loads in.

Pieces:

- :class:`ParsedModule` — one parsed source file (text, lines, AST), cached
  so every rule shares one parse per file.
- :class:`Rule` — the interface a rule implements: a ``name``, the repo
  ``roots`` it applies to, and ``check_module``.
- :class:`Finding` — one structured diagnostic.  Its identity for baseline
  matching is ``(rule, path, snippet)`` — the *stripped source line*, not
  the line number, so unrelated edits above a grandfathered finding don't
  un-grandfather it.
- suppressions — a trailing ``# reprolint: disable=<rule>[,<rule>...]`` (or
  ``disable=all``) on the offending line silences findings on that line.
- :class:`Baseline` — a checked-in JSON file of grandfathered findings;
  every entry carries a human ``justification``.  ``run_analysis`` reports
  only findings *not* in the baseline, so the CI lint job fails on new
  violations while letting documented debt stand.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Iterable, Optional, Sequence

SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\- ]+)")

#: directories never scanned (caches, venvs, checkouts inside the tree)
SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".eggs",
             "build", "dist"}

#: repo-relative roots scanned when a rule doesn't narrow them
DEFAULT_ROOTS = ("src",)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: where, which rule, and what went wrong."""

    rule: str
    path: str          # repo-root-relative, posix separators
    line: int          # 1-based
    col: int           # 0-based
    message: str
    snippet: str       # stripped source line — the baseline identity

    @property
    def fingerprint(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ParsedModule:
    """A parsed source file, shared by every rule that looks at it."""

    path: pathlib.Path     # absolute
    rel: str               # repo-root-relative, posix
    text: str
    lines: list[str]
    tree: ast.Module

    @classmethod
    def parse(cls, path: pathlib.Path, root: pathlib.Path) -> "ParsedModule":
        text = path.read_text()
        rel = path.resolve().relative_to(root.resolve()).as_posix()
        return cls(path=path, rel=rel, text=text,
                   lines=text.splitlines(), tree=ast.parse(text))

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) else ""
        return Finding(rule=rule, path=self.rel, line=line, col=col,
                       message=message, snippet=snippet)

    def suppressed_rules(self, line: int) -> frozenset[str]:
        """Rules disabled on ``line`` via a reprolint comment."""
        if not 1 <= line <= len(self.lines):
            return frozenset()
        m = SUPPRESS_RE.search(self.lines[line - 1])
        if not m:
            return frozenset()
        return frozenset(p.strip() for p in m.group(1).split(",") if p.strip())


class Rule:
    """Base class; subclasses set ``name``/``description`` and override
    :meth:`check_module`.  ``roots`` are the repo-relative directories the
    rule scans; ``exclude`` are repo-relative path prefixes it skips (the
    shim/implementation files that *define* the guarded surface)."""

    name: str = ""
    description: str = ""
    roots: tuple[str, ...] = DEFAULT_ROOTS
    exclude: tuple[str, ...] = ()

    def applies_to(self, rel: str) -> bool:
        return not any(rel == e or rel.startswith(e.rstrip("/") + "/")
                       for e in self.exclude)

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        raise NotImplementedError


@dataclasses.dataclass
class Baseline:
    """Grandfathered findings.  JSON shape::

        {"findings": [{"rule": ..., "path": ..., "snippet": ...,
                       "justification": "<why this is allowed to stand>"}]}

    Matching is by fingerprint (rule, path, snippet).  ``load`` rejects
    entries with an empty justification: debt must be documented.
    """

    entries: dict[tuple[str, str, str], str] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def load(cls, path: pathlib.Path) -> "Baseline":
        data = json.loads(path.read_text())
        entries = {}
        for e in data.get("findings", []):
            just = e.get("justification", "").strip()
            if not just:
                raise ValueError(
                    f"baseline entry without justification: {e!r} "
                    f"(every grandfathered finding needs a reason)")
            entries[(e["rule"], e["path"], e["snippet"])] = just
        return cls(entries=entries)

    def contains(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    @staticmethod
    def write(path: pathlib.Path, findings: Sequence[Finding],
              old: Optional["Baseline"] = None) -> None:
        """Serialize ``findings`` as the new baseline, carrying forward the
        justification of entries that were already grandfathered (new ones
        get a TODO the loader will refuse until a human fills it in)."""
        out = []
        for f in findings:
            just = (old.entries.get(f.fingerprint, "") if old else "")
            out.append({"rule": f.rule, "path": f.path, "snippet": f.snippet,
                        "justification": just or
                        "TODO: justify or fix (loader rejects empty)"})
        path.write_text(json.dumps({"findings": out}, indent=2) + "\n")


def iter_python_files(root: pathlib.Path,
                      roots: Sequence[str]) -> Iterable[pathlib.Path]:
    """All ``*.py`` under ``root/<r>`` for each repo-relative ``r``, sorted;
    ``r == "."`` scans the root itself."""
    seen = set()
    for r in roots:
        base = root if r in (".", "") else root / r
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if any(part in SKIP_DIRS for part in path.parts):
                continue
            if path not in seen:
                seen.add(path)
                yield path


@dataclasses.dataclass
class AnalysisConfig:
    root: pathlib.Path                       # repo root all paths are relative to
    rules: Sequence[Rule] = ()
    baseline: Optional[Baseline] = None
    paths: Optional[Sequence[pathlib.Path]] = None   # explicit file list


def run_analysis(cfg: AnalysisConfig) -> tuple[list[Finding], list[Finding]]:
    """Run every rule over its files.

    Returns ``(new, grandfathered)``: findings not in / in the baseline.
    Suppressed findings are dropped entirely.  A file that fails to parse
    yields a single ``parse-error`` finding (attributed to every rule run
    would be noise; one record is enough to fail the lint job).
    """
    cache: dict[pathlib.Path, ParsedModule] = {}
    parse_failures: dict[pathlib.Path, Finding] = {}
    root = cfg.root

    def parsed(path: pathlib.Path) -> Optional[ParsedModule]:
        if path in parse_failures:
            return None
        if path not in cache:
            try:
                cache[path] = ParsedModule.parse(path, root)
            except SyntaxError as e:
                rel = path.resolve().relative_to(root.resolve()).as_posix()
                parse_failures[path] = Finding(
                    rule="parse-error", path=rel, line=e.lineno or 1,
                    col=e.offset or 0, message=f"syntax error: {e.msg}",
                    snippet=(e.text or "").strip())
                return None
        return cache[path]

    findings: list[Finding] = []
    for rule in cfg.rules:
        if cfg.paths is not None:
            files = list(cfg.paths)
        else:
            files = list(iter_python_files(root, rule.roots))
        for path in files:
            mod = parsed(path)
            if mod is None or not rule.applies_to(mod.rel):
                continue
            for f in rule.check_module(mod):
                sup = mod.suppressed_rules(f.line)
                if "all" in sup or f.rule in sup:
                    continue
                findings.append(f)
    findings.extend(parse_failures.values())
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    if cfg.baseline is None:
        return findings, []
    new = [f for f in findings if not cfg.baseline.contains(f)]
    old = [f for f in findings if cfg.baseline.contains(f)]
    return new, old


# ---------------------------------------------------------------------------
# Shared AST helpers (used by several rules)
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def str_constants(node: ast.AST) -> list[ast.Constant]:
    """Every string-literal node in the subtree."""
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)]
