"""pallas-budget: every pallas_call fits its declared VMEM budget.

The paper's eq. 5-8 memory model sizes every kernel's working set against
a declared fast-memory capacity; ROADMAP items 1-2 (degree-binned layouts,
approximate-computing variants) will churn exactly these tile shapes.
This rule makes the contract static: each ``pl.pallas_call`` /
``compat.pallas_call`` site must belong to a wrapper function with an
entry in ``repro.kernels.budgets.BUDGETS``, its BlockSpec / out-spec /
scratch shapes must resolve against the entry's declared ``dim_bounds``
(symbolic dims with no declared bound are themselves findings — an
undeclared dim is an unbounded dim), and the estimated footprint::

    2 * (in blocks + out blocks) + scratch        (see budgets.py docstring)

must stay under the entry's ``vmem_limit``.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.engine import (Finding, ParsedModule, Rule, dotted_name,
                                   keyword_arg)

DTYPE_BYTES = {
    "float64": 8, "f64": 8, "int64": 8,
    "float32": 4, "f32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool_": 1,
}
DEFAULT_ITEMSIZE = 4      # streamed blocks in this repo are f32


class _Unresolved(Exception):
    def __init__(self, what: str):
        super().__init__(what)
        self.what = what


def _eval_dim(node: ast.expr, bounds: dict) -> int:
    """Evaluate a block-shape dim against the declared bounds."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in bounds:
            return int(bounds[node.id])
        raise _Unresolved(node.id)
    if isinstance(node, ast.BinOp):
        lhs, rhs = _eval_dim(node.left, bounds), _eval_dim(node.right, bounds)
        if isinstance(node.op, ast.Add):
            return lhs + rhs
        if isinstance(node.op, ast.Sub):
            return lhs - rhs
        if isinstance(node.op, ast.Mult):
            return lhs * rhs
        if isinstance(node.op, ast.FloorDiv):
            return lhs // rhs
        raise _Unresolved(ast.dump(node.op))
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_eval_dim(node.operand, bounds)
    raise _Unresolved(ast.unparse(node) if hasattr(ast, "unparse")
                      else repr(node))


def _shape_elts(node: ast.expr) -> Optional[list[ast.expr]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


def _dtype_bytes(node: Optional[ast.expr]) -> int:
    """Itemsize of a ``jnp.float32``-style dtype expression."""
    if node is None:
        return DEFAULT_ITEMSIZE
    dotted = dotted_name(node)
    if dotted:
        leaf = dotted.split(".")[-1]
        if leaf in DTYPE_BYTES:
            return DTYPE_BYTES[leaf]
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return DTYPE_BYTES.get(node.value, DEFAULT_ITEMSIZE)
    return DEFAULT_ITEMSIZE


def _spec_list(node: Optional[ast.expr]) -> list[ast.expr]:
    """in_specs/out_specs value -> list of spec expressions."""
    if node is None:
        return []
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return [node]


class PallasBudgetRule(Rule):
    name = "pallas-budget"
    description = ("pallas_call block/scratch shapes must resolve against "
                   "declared tile bounds and fit the kernel's declared "
                   "VMEM budget (repro/kernels/budgets.py)")
    roots = ("src",)

    def __init__(self, budgets=None, pipeline_factor: int = 2):
        if budgets is None:
            from repro.kernels.budgets import BUDGETS
            budgets = BUDGETS
        self.budgets = budgets
        self.pipeline_factor = pipeline_factor

    # -- per-site accounting -------------------------------------------
    def _block_bytes(self, spec: ast.expr, bounds: dict,
                     flag, what: str) -> int:
        """Bytes of one BlockSpec/vmem block; 0 if shapeless or flagged."""
        if not isinstance(spec, ast.Call):
            return 0       # e.g. a Name forwarded from elsewhere: unknown
        fn = (dotted_name(spec.func) or "").split(".")[-1]
        if fn in ("BlockSpec",):
            shape = _shape_elts(spec.args[0]) if spec.args else None
            dtype = DEFAULT_ITEMSIZE
        elif fn in ("vmem", "VMEM", "MemoryRef"):
            shape = _shape_elts(spec.args[0]) if spec.args else None
            dtype = _dtype_bytes(spec.args[1] if len(spec.args) > 1 else None)
        else:
            return 0
        if shape is None:
            flag(spec, f"{what}: block shape is not a literal tuple; "
                       "the budget checker cannot size it")
            return 0
        n = 1
        for elt in shape:
            try:
                n *= _eval_dim(elt, bounds)
            except _Unresolved as e:
                flag(elt, f"{what}: dim '{e.what}' has no declared bound in "
                          "the kernel's budgets.py entry (an undeclared dim "
                          "is an unbounded dim)")
                return 0
        return n * dtype

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        out: list[Finding] = []

        def flag(node: ast.AST, msg: str) -> None:
            out.append(mod.finding(self.name, node, msg))

        # map pallas_call sites to their enclosing function name
        func_stack: list[str] = []

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                func_stack.append(node.name)
                for child in ast.iter_child_nodes(node):
                    visit(child)
                func_stack.pop()
                return
            if isinstance(node, ast.Call):
                dotted = dotted_name(node.func) or ""
                if dotted.split(".")[-1] == "pallas_call":
                    self._check_site(node, func_stack, flag)
            for child in ast.iter_child_nodes(node):
                visit(child)

        visit(mod.tree)
        return out

    def _check_site(self, call: ast.Call, func_stack: list[str],
                    flag) -> None:
        owner = func_stack[-1] if func_stack else "<module>"
        # compat.py's pass-through is the shim, not a kernel
        if owner == "pallas_call":
            return
        budget = self.budgets.get(owner)
        if budget is None:
            flag(call, f"pallas_call in '{owner}' has no declared budget; "
                       "add an entry to repro/kernels/budgets.py (declare "
                       "the tile bounds and a VMEM limit)")
            return
        bounds = budget.dim_bounds
        in_b = sum(self._block_bytes(s, bounds, flag, f"{owner} in_specs")
                   for s in _spec_list(keyword_arg(call, "in_specs")))
        out_b = sum(self._block_bytes(s, bounds, flag, f"{owner} out_specs")
                    for s in _spec_list(keyword_arg(call, "out_specs")))
        scratch = sum(
            self._block_bytes(s, bounds, flag, f"{owner} scratch_shapes")
            for s in _spec_list(keyword_arg(call, "scratch_shapes")))
        total = self.pipeline_factor * (in_b + out_b) + scratch
        if total > budget.vmem_limit:
            flag(call, f"'{owner}' estimated VMEM footprint {total} B "
                       f"({self.pipeline_factor}*(in {in_b} + out {out_b}) "
                       f"+ scratch {scratch}) exceeds its declared limit "
                       f"{budget.vmem_limit} B under bounds {bounds}")
