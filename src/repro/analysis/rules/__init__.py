"""Rule registry.

``ALL_RULES`` is the ordered list of rule *instances* the CLI and tests
run; ``get_rules(names)`` resolves a ``--rule`` selection and fails loudly
on unknown names (the same contract as ``benchmarks/run.py --only``:
typos must not silently match nothing).
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.engine import Rule
from repro.analysis.rules.bin_shape import BinShapeRule
from repro.analysis.rules.checkpoint_aliasing import CheckpointAliasingRule
from repro.analysis.rules.compat_routing import CompatRoutingRule
from repro.analysis.rules.obs_routing import ObsRoutingRule
from repro.analysis.rules.pallas_budget import PallasBudgetRule
from repro.analysis.rules.precision_drift import PrecisionDriftRule
from repro.analysis.rules.shard_safety import ShardSafetyRule

ALL_RULES: tuple[Rule, ...] = (
    CompatRoutingRule(),
    PallasBudgetRule(),
    PrecisionDriftRule(),
    ShardSafetyRule(),
    CheckpointAliasingRule(),
    ObsRoutingRule(),
    BinShapeRule(),
)


def rule_names() -> list[str]:
    return [r.name for r in ALL_RULES]


def get_rules(names: Optional[Sequence[str]] = None) -> list[Rule]:
    """Resolve a ``--rule`` selection; unknown names raise ValueError with
    the full catalog (mirrors benchmarks/run.py's ``--only`` validation)."""
    if not names:
        return list(ALL_RULES)
    known = {r.name: r for r in ALL_RULES}
    unknown = [n for n in names if n not in known]
    if unknown:
        raise ValueError(
            f"unknown rule name(s) {unknown}; choose from {sorted(known)}")
    return [known[n] for n in names]


__all__ = ["ALL_RULES", "BinShapeRule", "CheckpointAliasingRule",
           "CompatRoutingRule", "ObsRoutingRule", "PallasBudgetRule",
           "PrecisionDriftRule", "ShardSafetyRule", "get_rules",
           "rule_names"]
