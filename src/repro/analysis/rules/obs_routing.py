"""obs-routing: raw wall-clock reads in ``src/repro/`` route through obs.

ISSUE 7's tentpole moved all driver timing onto the ``repro.obs`` layer:
``obs.trace.phase`` (span + always-on per-phase metrics from one clock
pair) and the ``Tracer`` span API are the sanctioned ways to time code.  A
bare ``time.perf_counter()`` / ``time.time()`` call re-opens the split
world this PR closed — wall-clock numbers that exist next to, and drift
from, the telemetry the registry reports (``StreamTelemetry.wall_seconds``
was exactly such a duplicate before).

The rule flags calls to ``time.time``, ``time.perf_counter``,
``time.monotonic`` (and their ``_ns`` variants) anywhere under
``src/repro/`` except ``obs/`` itself — the one place allowed to read the
clock, since every sanctioned timer is built there.  Scope is deliberately
``src`` only: tests, benches and examples time things ad hoc by design
(bench harness wall-clocks ARE the measurement).  Deliberate holdouts are
grandfathered in ``reprolint_baseline.json`` with justifications, e.g. the
launch dry-run's compile-latency probes, which measure jit/compile wall
time standalone rather than a streaming phase.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ParsedModule, Rule, call_name

#: clock-reading callables that must not be spelled directly
BANNED_CLOCKS = ("time", "perf_counter", "monotonic",
                 "time_ns", "perf_counter_ns", "monotonic_ns")


class ObsRoutingRule(Rule):
    name = "obs-routing"
    description = ("bare time.time()/time.perf_counter() in src/repro/ "
                   "outside obs/; time code with obs.trace.phase or a "
                   "Tracer span")
    roots = ("src",)
    exclude = (
        "src/repro/obs",             # the layer that implements the timers
    )

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        # which local names are the time module / its clock functions?
        # (`import time`, `import time as t`, `from time import perf_counter`)
        time_aliases: set[str] = set()
        clock_names: dict[str, str] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "time":
                        time_aliases.add(alias.asname or "time")
            elif isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in BANNED_CLOCKS:
                        clock_names[alias.asname or alias.name] = alias.name

        out: list[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = call_name(node)
            if dotted is None:
                continue
            parts = dotted.split(".")
            offender = None
            if (len(parts) == 2 and parts[0] in time_aliases
                    and parts[1] in BANNED_CLOCKS):
                offender = f"time.{parts[1]}"
            elif len(parts) == 1 and parts[0] in clock_names:
                offender = f"time.{clock_names[parts[0]]}"
            if offender is not None:
                out.append(mod.finding(
                    self.name, node,
                    f"bare {offender}() in src/repro/ — time phases with "
                    f"obs.trace.phase(cat=...) (always-on metrics + "
                    f"opt-in span) or tracer.span(); only repro.obs may "
                    f"read the clock directly"))
        return out
