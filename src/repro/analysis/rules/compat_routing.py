"""compat-routing: version-sensitive JAX surfaces only in ``repro.compat``.

PR 1's lesson: a renamed JAX symbol at module scope silently drops whole
test modules at collection.  The fix was to route every version-sensitive
surface through ``src/repro/compat.py`` — and a string grep-ban in
tests/test_import_sweep.py to keep it that way.  This rule is that ban as
a real AST check (strings and comments no longer trip it; imports,
attribute chains and call vocabulary do):

- the banned *names* ``AxisType`` / ``CompilerParams`` /
  ``TPUCompilerParams`` may not be referenced (as imports, names, or
  attributes) outside the shim;
- ``shard_map`` must be spelled ``compat.shard_map`` — direct
  ``jax.shard_map`` / ``jax.experimental.shard_map`` imports or attribute
  chains are flagged, as is the legacy ``check_rep=`` vocabulary;
- ``pallas_call`` must be spelled ``compat.pallas_call`` (that is where
  the off-TPU ``interpret=`` degrade lives) — ``pl.pallas_call`` and
  ``from jax.experimental.pallas import pallas_call`` are flagged, and an
  ``interpret=`` keyword on such a direct call is flagged on its own line
  so the fix is obvious.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import (Finding, ParsedModule, Rule, dotted_name)

BANNED_NAMES = ("AxisType", "CompilerParams", "TPUCompilerParams")

#: modules whose import is itself a routing violation
BANNED_IMPORT_MODULES = ("jax.experimental.shard_map",)

#: function names that must only ever be reached through ``compat.``
ROUTED_FUNCS = ("shard_map", "pallas_call")


def _base_is_compat(dotted: str) -> bool:
    """True for ``compat.shard_map`` / ``repro.compat.pallas_call``."""
    parts = dotted.split(".")
    return len(parts) >= 2 and parts[-2] == "compat"


class CompatRoutingRule(Rule):
    name = "compat-routing"
    description = ("version-sensitive JAX surfaces (AxisType, CompilerParams, "
                   "shard_map, pallas_call/interpret=) must route through "
                   "repro.compat")
    # the one rule that also covers tests/benches/examples, like the grep
    # ban it replaces
    roots = ("src", "tests", "benchmarks", "examples")
    exclude = (
        "src/repro/compat.py",       # the shim itself
        "tests/test_compat.py",      # spells both branches via monkeypatch
        "tests/test_import_sweep.py",
    )

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        out: list[Finding] = []

        def flag(node: ast.AST, msg: str) -> None:
            out.append(mod.finding(self.name, node, msg))

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ImportFrom):
                module = node.module or ""
                if module in BANNED_IMPORT_MODULES:
                    flag(node, f"import of '{module}' outside compat.py; "
                               "use repro.compat.shard_map")
                for alias in node.names:
                    if alias.name in BANNED_NAMES:
                        flag(node, f"import of version-sensitive name "
                                   f"'{alias.name}' outside compat.py")
                    if alias.name in ROUTED_FUNCS and module.startswith("jax"):
                        flag(node, f"direct import of '{alias.name}' from "
                                   f"'{module}'; use repro.compat."
                                   f"{alias.name}")
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name in BANNED_IMPORT_MODULES:
                        flag(node, f"import of '{alias.name}' outside "
                                   "compat.py; use repro.compat.shard_map")
            elif isinstance(node, ast.Name):
                if node.id in BANNED_NAMES:
                    flag(node, f"version-sensitive name '{node.id}' outside "
                               "compat.py (route through the compat shim)")
            elif isinstance(node, ast.Attribute):
                if node.attr in BANNED_NAMES:
                    flag(node, f"version-sensitive attribute '.{node.attr}' "
                               "outside compat.py (route through the compat "
                               "shim)")
                elif node.attr in ROUTED_FUNCS:
                    dotted = dotted_name(node)
                    if dotted and not _base_is_compat(dotted):
                        flag(node, f"'{dotted}' bypasses the compat shim; "
                                   f"use compat.{node.attr} (off-TPU "
                                   "interpret fallback / vocabulary "
                                   "translation live there)")
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "check_rep":
                        flag(kw.value, "legacy shard_map vocabulary "
                                       "'check_rep='; compat.shard_map "
                                       "accepts the new 'check_vma='")
                    elif kw.arg == "interpret":
                        dotted = dotted_name(node.func) or ""
                        if (dotted.split(".")[-1] == "pallas_call"
                                and not _base_is_compat(dotted)):
                            flag(kw.value, "'interpret=' on a direct "
                                           "pallas_call; route through "
                                           "compat.pallas_call")
        return out
