"""precision-drift: reduction-bound accumulators must stay float64.

PR 5's bit-exactness contract: the per-data-shard Hermitian partials that
``distributed.reduce.topology_reduce`` combines are accumulated in float64
on the host.  An f64 sum of f32 summands is exact, hence association-free,
which is the *only* reason the topology-aware schedule can promise
bit-identity with the flat all-reduce oracle (and why mesh kill/resume is
bit-exact).  One stray ``astype(np.float32)`` on that dataflow silently
re-introduces association order into the result — the tests would only
catch it probabilistically.

The rule runs per module, intraprocedurally with one level of in-file
call propagation:

1. every variable passed (possibly through ``list(x)`` / ``x[i]``) to a
   ``topology_reduce`` call is *reduction-bound*;
2. if a function's parameters are reduction-bound, the argument variables
   at that function's in-file call sites become reduction-bound too (this
   is how ``driver._reduce_and_solve``'s callers are covered);
3. any assignment / aug-assignment to a reduction-bound variable whose
   right-hand side mentions a narrower dtype (float32/float16/bfloat16 in
   any spelling), and any ``<var>.astype(...narrow...)`` call, is flagged;
   so is a narrow dtype inside the ``topology_reduce`` argument itself.

Downstream casts of the *result* (solving in f32 after the reduce) are
deliberately fine — the contract covers the summands, not the solve.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ParsedModule, Rule, dotted_name

NARROW = {"float32", "float16", "bfloat16", "f32", "half"}
REDUCE_FUNCS = ("topology_reduce",)


def _base_var(node: ast.expr) -> str | None:
    """The variable a reduce argument ultimately reads: unwrap list()/
    slices/indexing; attributes and other calls are opaque."""
    while True:
        if isinstance(node, ast.Call):
            fn = dotted_name(node.func)
            if fn in ("list", "tuple") and node.args:
                node = node.args[0]
                continue
            return None
        if isinstance(node, ast.Subscript):
            node = node.value
            continue
        if isinstance(node, ast.Name):
            return node.id
        return None


def _narrow_mentions(node: ast.AST) -> list[ast.AST]:
    """dtype-narrowing spellings anywhere in the subtree."""
    hits = []
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in NARROW:
            hits.append(n)
        elif isinstance(n, ast.Name) and n.id in NARROW:
            hits.append(n)
        elif (isinstance(n, ast.Constant) and isinstance(n.value, str)
              and n.value in NARROW):
            hits.append(n)
    return hits


class _FuncInfo:
    def __init__(self, node):
        self.node = node
        self.params = [a.arg for a in node.args.args]
        self.bound: set[str] = set()           # reduction-bound names


class PrecisionDriftRule(Rule):
    name = "precision-drift"
    description = ("accumulators feeding distributed.reduce.topology_reduce "
                   "must be created and kept float64; narrowing casts on "
                   "that dataflow break the bit-exact reduction contract")
    roots = ("src",)
    # the reduction implementation converts internally by design
    exclude = ("src/repro/distributed/reduce.py",)

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        out: list[Finding] = []

        def flag(node: ast.AST, msg: str) -> None:
            out.append(mod.finding(self.name, node, msg))

        funcs: list[_FuncInfo] = []

        def collect(node: ast.AST) -> None:
            for child in ast.walk(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    funcs.append(_FuncInfo(child))

        collect(mod.tree)

        # pass 1: direct topology_reduce arguments (+ narrow dtypes inline)
        def reduce_calls(scope: ast.AST):
            for n in ast.walk(scope):
                if (isinstance(n, ast.Call)
                        and (dotted_name(n.func) or "").split(".")[-1]
                        in REDUCE_FUNCS):
                    yield n

        def mark_direct(info: _FuncInfo) -> None:
            for call in reduce_calls(info.node):
                for arg in call.args[:1]:      # parts argument
                    for hit in _narrow_mentions(arg):
                        flag(hit, "narrow dtype inside a topology_reduce "
                                  "argument; the summands must be float64 "
                                  "for the staged reduction to be bit-exact")
                    var = _base_var(arg)
                    if var:
                        info.bound.add(var)

        for info in funcs:
            mark_direct(info)

        # pass 2: one level of in-file propagation — if f's params are
        # bound, the caller's argument variables are bound too
        bound_params = {
            info.node.name: {info.params.index(v) for v in info.bound
                             if v in info.params}
            for info in funcs if info.bound
        }
        if bound_params:
            for info in funcs:
                for n in ast.walk(info.node):
                    if not isinstance(n, ast.Call):
                        continue
                    callee = (dotted_name(n.func) or "").split(".")[-1]
                    idxs = bound_params.get(callee)
                    if not idxs:
                        continue
                    for i, arg in enumerate(n.args):
                        if i in idxs:
                            var = _base_var(arg)
                            if var:
                                info.bound.add(var)

        # pass 3: check every assignment/cast touching a bound variable.
        # Nested defs appear in both their own _FuncInfo and the enclosing
        # function's walk; dedupe findings by (node identity).
        seen: set[int] = set()

        def check_scope(info: _FuncInfo) -> None:
            for n in ast.walk(info.node):
                if isinstance(n, ast.Assign):
                    names = {t.id for t in n.targets
                             if isinstance(t, ast.Name)}
                    if names & info.bound:
                        for hit in _narrow_mentions(n.value):
                            if id(hit) not in seen:
                                seen.add(id(hit))
                                flag(hit, f"reduction-bound accumulator "
                                          f"{sorted(names & info.bound)} "
                                          "assigned from a narrow-dtype "
                                          "expression; keep it float64 up "
                                          "to topology_reduce")
                elif isinstance(n, ast.AugAssign):
                    if (isinstance(n.target, ast.Name)
                            and n.target.id in info.bound):
                        for hit in _narrow_mentions(n.value):
                            if id(hit) not in seen:
                                seen.add(id(hit))
                                flag(hit, f"narrow-dtype term accumulated "
                                          f"into reduction-bound "
                                          f"'{n.target.id}'; partial sums "
                                          "must stay float64")
                elif isinstance(n, ast.Call):
                    f = n.func
                    if (isinstance(f, ast.Attribute) and f.attr == "astype"
                            and isinstance(f.value, ast.Name)
                            and f.value.id in info.bound):
                        for hit in _narrow_mentions(n):
                            if id(hit) not in seen:
                                seen.add(id(hit))
                                flag(hit, f"'{f.value.id}.astype' narrows a "
                                          "reduction-bound accumulator; "
                                          "cast after the reduce, not "
                                          "before")

        for info in funcs:
            if info.bound:
                check_scope(info)
        return out
