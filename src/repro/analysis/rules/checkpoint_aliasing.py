"""checkpoint-aliasing: commit materialized copies, not live arrays.

The PR 2 / PR 5 bug class: ``CheckpointManager.save`` commits on a
background thread, so anything reachable from the committed tree that a
later wave mutates in place (a live numpy accumulator, a ``row_slice``
view, a donated device buffer) races the writer and corrupts the
checkpoint silently — resume then diverges in ways only the bit-exactness
tests catch, sometimes.  The repo's contract is that every value passed
to a commit path is a *materialized host copy*.

The rule tracks, per function, variables bound to
``CheckpointManager(...)`` / ``WaveCheckpointer(...)`` and inspects every
``<mgr>.save(step, tree)`` call site:

- dict literals are checked value by value;
- a ``Name`` argument is resolved one level through local assignments;
- a function/lambda passed as the tree thunk (the ``WaveCheckpointer``
  protocol) is analyzed through its returned dict *and* any
  ``tree[key] = ...`` mutations on the returned variable;
- **OK**: ``x.copy()``, ``np.array(...)`` (always copies), fresh
  allocations (``np.zeros/ones/full/empty/stack/concatenate``), scalar
  wrappers, constants, and containers thereof;
- **flagged**: ``np.asarray(...)`` (returns the input itself when dtype
  already matches — the exact PR 5 mesh-accumulator race),
  ``jnp.asarray(...)`` and other ``jnp.*`` results (live device arrays),
  bare attribute reads (``state.x``), and subscripts/slices (numpy
  views);
- anything unresolvable is left alone — the rule prefers silence to
  noise.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.engine import Finding, ParsedModule, Rule, dotted_name

MANAGER_TYPES = {"CheckpointManager", "WaveCheckpointer"}

#: allocation calls that always return fresh arrays
FRESH_CALLS = {"array", "zeros", "ones", "full", "empty", "eye", "stack",
               "concatenate", "copy", "deepcopy", "float", "int", "bool",
               "str"}

BAD_CALL_MSG = {
    "asarray": ("np.asarray aliases its input when the dtype already "
                "matches; use np.array (always copies) on a commit path"),
    "ascontiguousarray": ("np.ascontiguousarray aliases already-contiguous "
                          "input (the PR 2 bug); use np.array on a commit "
                          "path"),
    "atleast_1d": "may alias its input; use np.array on a commit path",
}


class CheckpointAliasingRule(Rule):
    name = "checkpoint-aliasing"
    description = ("values committed through CheckpointManager/"
                   "WaveCheckpointer must be materialized copies, not live "
                   "device arrays or numpy views")
    roots = ("src",)
    # the manager/checkpointer implementations themselves snapshot via
    # jax.device_get / thunk indirection by design
    exclude = ("src/repro/checkpoint/", "src/repro/outofcore/runtime.py")

    # -- expression classification -------------------------------------
    def _check_value(self, node: ast.expr, scope: ast.AST, flag,
                     depth: int = 0) -> None:
        """Flag ``node`` if it is provably a live/aliasing commit value."""
        if depth > 4:
            return
        if isinstance(node, ast.Constant):
            return
        if isinstance(node, ast.Dict):
            for v in node.values:
                self._check_value(v, scope, flag, depth + 1)
            return
        if isinstance(node, (ast.Tuple, ast.List)):
            for v in node.elts:
                self._check_value(v, scope, flag, depth + 1)
            return
        if isinstance(node, ast.Call):
            dotted = dotted_name(node.func) or ""
            leaf = dotted.split(".")[-1]
            base = dotted.split(".")[0] if "." in dotted else ""
            if leaf in BAD_CALL_MSG:
                flag(node, BAD_CALL_MSG[leaf])
                return
            if base == "jnp" or dotted.startswith("jax.numpy"):
                flag(node, f"'{dotted}' produces a live device array; "
                           "commit a host copy (np.array) instead")
                return
            if leaf in FRESH_CALLS:
                return                      # fresh allocation / real copy
            return                          # unknown call: stay silent
        if isinstance(node, ast.Name):
            resolved = self._resolve_local(node.id, scope)
            if resolved is not None:
                self._check_value(resolved, scope, flag, depth + 1)
            return
        if isinstance(node, ast.Attribute):
            flag(node, f"live array reference "
                       f"'{dotted_name(node) or node.attr}' committed; the "
                       "async writer races later in-place updates — pass a "
                       "materialized copy (.copy() / np.array)")
            return
        if isinstance(node, ast.Subscript):
            flag(node, "subscript/slice committed; numpy slices are views "
                       "of the live array — pass a materialized copy")
            return

    @staticmethod
    def _resolve_local(name: str, scope: ast.AST) -> Optional[ast.expr]:
        """Last single-target assignment to ``name`` in ``scope``."""
        found = None
        for n in ast.walk(scope):
            if isinstance(n, ast.Assign) and len(n.targets) == 1:
                t = n.targets[0]
                if isinstance(t, ast.Name) and t.id == name:
                    found = n.value
        return found

    def _check_tree_fn(self, fn: ast.AST, flag) -> None:
        """Analyze a tree thunk: returned dicts + tree[key] mutations."""
        if isinstance(fn, ast.Lambda):
            self._check_value(fn.body, fn, flag)
            return
        ret_names = set()
        for n in ast.walk(fn):
            if isinstance(n, ast.Return) and n.value is not None:
                if isinstance(n.value, ast.Name):
                    ret_names.add(n.value.id)
                else:
                    self._check_value(n.value, fn, flag)
        # `tree[...] = value` mutations on the returned dict variable
        for n in ast.walk(fn):
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    targets = [t]
                    if isinstance(t, ast.Tuple):
                        targets = list(t.elts)
                    for tt in targets:
                        if (isinstance(tt, ast.Subscript)
                                and isinstance(tt.value, ast.Name)
                                and tt.value.id in ret_names):
                            vals = [n.value]
                            if (isinstance(t, ast.Tuple)
                                    and isinstance(n.value, ast.Tuple)
                                    and len(t.elts) == len(n.value.elts)):
                                vals = [n.value.elts[t.elts.index(tt)]]
                            for v in vals:
                                self._check_value(v, fn, flag)

    # -- module walk ----------------------------------------------------
    def check_module(self, mod: ParsedModule) -> list[Finding]:
        out: list[Finding] = []

        def flag(node: ast.AST, msg: str) -> None:
            out.append(mod.finding(self.name, node, msg))

        def visit_scope(scope: ast.AST, managers: set[str]) -> None:
            local_mgrs = set(managers)
            for n in ast.walk(scope):
                if isinstance(n, ast.Assign) and len(n.targets) == 1:
                    t, v = n.targets[0], n.value
                    if isinstance(v, ast.IfExp):      # mgr = X if c else None
                        v = v.body
                    if (isinstance(t, ast.Name) and isinstance(v, ast.Call)
                            and (dotted_name(v.func) or "").split(".")[-1]
                            in MANAGER_TYPES):
                        local_mgrs.add(t.id)
            for n in ast.walk(scope):
                if not isinstance(n, ast.Call):
                    continue
                f = n.func
                if not (isinstance(f, ast.Attribute) and f.attr == "save"):
                    continue
                if not (isinstance(f.value, ast.Name)
                        and f.value.id in local_mgrs):
                    continue
                if len(n.args) < 2:
                    continue
                tree = n.args[1]
                if isinstance(tree, ast.Name):
                    fn = self._resolve_fn(tree.id, scope)
                    if fn is not None:
                        self._check_tree_fn(fn, flag)
                        continue
                    resolved = self._resolve_local(tree.id, scope)
                    if resolved is not None:
                        self._check_value(resolved, scope, flag)
                    continue
                if isinstance(tree, ast.Lambda):
                    self._check_tree_fn(tree, flag)
                    continue
                self._check_value(tree, scope, flag)

        visit_scope(mod.tree, set())
        return out

    @staticmethod
    def _resolve_fn(name: str, scope: ast.AST) -> Optional[ast.AST]:
        for n in ast.walk(scope):
            if (isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and n.name == name):
                return n
            if isinstance(n, ast.Assign) and len(n.targets) == 1:
                t = n.targets[0]
                if (isinstance(t, ast.Name) and t.id == name
                        and isinstance(n.value, ast.Lambda)):
                    return n.value
        return None
