"""shard-safety: shard_map specs agree with the mesh builders' axes.

Two failure modes this catches statically:

- **axis-name typos**: a ``P("modle")`` or ``lax.psum(y, "modell")`` only
  fails at trace time, on a mesh, in whichever lane happens to exercise
  that path.  The mesh builders in ``src/repro/launch/mesh.py`` are the
  single source of the axis vocabulary ("data" / "model" / "pod"); every
  string axis used in a PartitionSpec, a ``shard_map(axis_names=...)``
  set, or a named collective must be declared there.
- **spec arity drift**: ``shard_map(f, in_specs=..., out_specs=...)``
  where the spec count disagrees with ``f``'s signature (or its returned
  tuple) — the error XLA eventually raises is far from the edit that
  caused it.  Checked whenever both sides are statically known (literal
  spec tuples, in-file def or lambda).

The vocabulary is parsed from the mesh-builder module's AST (string
elements of tuple literals — the axes tuples), so adding an axis to the
builders automatically widens the checker.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Optional

from repro.analysis.engine import (Finding, ParsedModule, Rule, dotted_name,
                                   keyword_arg)

#: fallback vocabulary if the mesh-builder module cannot be parsed
DEFAULT_AXES = frozenset({"data", "model", "pod"})

#: repo-relative module the axis vocabulary is declared in
MESH_BUILDER = "src/repro/launch/mesh.py"

#: lax collectives whose string args name mesh axes
COLLECTIVES = {"psum", "pmax", "pmin", "pmean", "psum_scatter",
               "all_gather", "all_to_all", "axis_index", "ppermute"}

SPEC_FUNCS = {"P", "PartitionSpec"}


def axes_from_mesh_builder(path: pathlib.Path) -> frozenset[str]:
    """Axis names declared by the mesh builders: every string element of a
    tuple literal in the module (the ``axes`` tuples; shape tuples are
    ints and contribute nothing)."""
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return DEFAULT_AXES
    axes = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Tuple):
            for elt in node.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    axes.add(elt.value)
    return frozenset(axes) or DEFAULT_AXES


def _resolve_mapped_fn(name: str, stack: list[ast.AST]) -> Optional[ast.AST]:
    """Find ``def name`` / ``name = lambda`` in the enclosing scopes."""
    for scope in reversed(stack):
        for child in ast.walk(scope):
            if (isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and child.name == name):
                return child
            if isinstance(child, ast.Assign):
                for t in child.targets:
                    if (isinstance(t, ast.Name) and t.id == name
                            and isinstance(child.value, ast.Lambda)):
                        return child.value
    return None


def _positional_arity(fn: ast.AST) -> Optional[int]:
    args = fn.args
    if args.vararg is not None:
        return None                      # *args: arity unknowable
    return len(args.posonlyargs) + len(args.args)


def _return_arities(fn: ast.AST) -> set[int]:
    """Sizes of literal tuple returns; non-literal returns add nothing."""
    if isinstance(fn, ast.Lambda):
        body = fn.body
        return {len(body.elts)} if isinstance(body, ast.Tuple) else set()
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Tuple):
            out.add(len(node.value.elts))
    return out


class ShardSafetyRule(Rule):
    name = "shard-safety"
    description = ("shard_map axis names must come from the mesh builders' "
                   "declared vocabulary and in_specs/out_specs arity must "
                   "match the mapped function")
    roots = ("src",)

    def __init__(self, axes: Optional[frozenset[str]] = None,
                 mesh_builder: str = MESH_BUILDER):
        self._axes = axes
        self.mesh_builder = mesh_builder

    def axes(self, repo_root: pathlib.Path) -> frozenset[str]:
        if self._axes is not None:
            return self._axes
        return axes_from_mesh_builder(repo_root / self.mesh_builder)

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        out: list[Finding] = []
        # repo root = the path minus the rel suffix
        root = pathlib.Path(
            str(mod.path.resolve())[: -len(mod.rel) - 1] or "/")
        vocab = self.axes(root)

        def flag(node: ast.AST, msg: str) -> None:
            out.append(mod.finding(self.name, node, msg))

        def check_axis_strings(node: ast.AST, what: str) -> None:
            for n in ast.walk(node):
                if (isinstance(n, ast.Constant) and isinstance(n.value, str)
                        and n.value not in vocab):
                    flag(n, f"{what} names axis '{n.value}', which no mesh "
                            f"builder declares (known: {sorted(vocab)})")

        def check_specs(node: ast.AST, what: str) -> None:
            """Validate axis strings inside P(...)/PartitionSpec(...)."""
            for n in ast.walk(node):
                if isinstance(n, ast.Call):
                    leaf = (dotted_name(n.func) or "").split(".")[-1]
                    if leaf in SPEC_FUNCS:
                        for arg in list(n.args) + [k.value for k in n.keywords]:
                            check_axis_strings(arg, what)

        stack: list[ast.AST] = [mod.tree]

        def visit(node: ast.AST) -> None:
            is_scope = isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef))
            if is_scope:
                stack.append(node)
            if isinstance(node, ast.Call):
                leaf = (dotted_name(node.func) or "").split(".")[-1]
                if leaf == "shard_map":
                    self._check_shard_map(node, stack, vocab, flag,
                                          check_specs, check_axis_strings)
                elif leaf in COLLECTIVES and node.args:
                    # axis argument: arg 1 for collectives, arg 0 for
                    # axis_index
                    i = 0 if leaf == "axis_index" else 1
                    if len(node.args) > i:
                        check_axis_strings(node.args[i],
                                           f"lax.{leaf} axis argument")
            for child in ast.iter_child_nodes(node):
                visit(child)
            if is_scope:
                stack.pop()

        visit(mod.tree)
        return out

    def _check_shard_map(self, call: ast.Call, stack: list[ast.AST],
                         vocab, flag, check_specs, check_axis_strings) -> None:
        in_specs = keyword_arg(call, "in_specs")
        out_specs = keyword_arg(call, "out_specs")
        axis_names = keyword_arg(call, "axis_names")
        if in_specs is not None:
            check_specs(in_specs, "shard_map in_specs")
        if out_specs is not None:
            check_specs(out_specs, "shard_map out_specs")
        if axis_names is not None and isinstance(axis_names, (ast.Set,
                                                              ast.Tuple,
                                                              ast.List)):
            for elt in axis_names.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    check_axis_strings(elt, "shard_map axis_names")

        # arity: only when the mapped fn and the spec tuple are both known
        if not call.args:
            return
        target = call.args[0]
        fn: Optional[ast.AST] = None
        if isinstance(target, ast.Lambda):
            fn = target
        elif isinstance(target, ast.Name):
            fn = _resolve_mapped_fn(target.id, stack)
        if fn is None:
            return
        arity = _positional_arity(fn)
        if (arity is not None and isinstance(in_specs, (ast.Tuple, ast.List))
                and len(in_specs.elts) != arity):
            flag(call, f"shard_map in_specs has {len(in_specs.elts)} "
                       f"entries but the mapped function takes {arity} "
                       "positional arguments")
        if isinstance(out_specs, (ast.Tuple, ast.List)):
            rets = _return_arities(fn)
            if rets and len(out_specs.elts) not in rets:
                flag(call, f"shard_map out_specs has "
                           f"{len(out_specs.elts)} entries but the mapped "
                           f"function returns tuple(s) of size "
                           f"{sorted(rets)}")
