"""bin-shape: per-bin kernel dispatches must use the bin's own K.

The degree-binned layout (``sparse.padded.BinnedELL``, per-tile ``tile_K``
grids) exists to dispatch each bin/group at its *own* tight K.  The bug
this rule catches statically: code inside a bin loop that shapes a kernel
argument with the grid-wide ``.K`` of an enclosing object —

    for b, rows in zip(binned.bins, binned.rows):
        xb = solve(fixed, ell.idx[..., :ell.K], ...)   # <- grid-wide K

which silently re-pads every bin back to the global maximum, erasing the
entire fill win while staying numerically correct (masked padding slots
are exact zeros), so no test catches it.  Inside a bin-scoped loop or
comprehension the only legitimate ``.K`` is the one hanging off a
loop-bound name (``b.K``) — any other root is the enclosing layout's
grid-wide K and gets flagged.

Bin scope is syntactic: a ``for`` loop or comprehension whose iterable
mentions a ``.bins`` attribute or calls a ``*_k_groups`` helper.  False
positives (e.g. deliberately comparing against the grid K) carry a
``# reprolint: disable=bin-shape`` suppression with the reason.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import Finding, ParsedModule, Rule, dotted_name


def _iter_is_bin_scoped(it: ast.AST) -> bool:
    """True when the iterable walks degree bins: references a ``.bins``
    attribute anywhere (``binned.bins``, ``zip(x.bins, x.rows)``) or calls
    a ``*_k_groups`` grouping helper."""
    for n in ast.walk(it):
        if isinstance(n, ast.Attribute) and n.attr == "bins":
            return True
        if isinstance(n, ast.Call):
            name = (dotted_name(n.func) or "").split(".")[-1]
            if name.endswith("_k_groups"):
                return True
    return False


def _target_names(target: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(target) if isinstance(n, ast.Name)}


def _root_name(node: ast.AST) -> str | None:
    """Leftmost Name of an attribute/subscript chain (``a.b[c].K`` -> a)."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


class BinShapeRule(Rule):
    name = "bin-shape"
    description = ("inside a bin loop, kernel shapes must come from the "
                   "loop-bound bin's K, never the enclosing grid-wide .K")
    roots = ("src",)

    def check_module(self, mod: ParsedModule) -> list[Finding]:
        out: list[Finding] = []

        def check_body(body_nodes: list[ast.AST], bound: set[str]) -> None:
            for stmt in body_nodes:
                for n in ast.walk(stmt):
                    # names (re)bound inside the loop body count as local
                    if isinstance(n, (ast.Assign, ast.AugAssign,
                                      ast.AnnAssign)):
                        tgts = n.targets if isinstance(n, ast.Assign) \
                            else [n.target]
                        for t in tgts:
                            bound |= _target_names(t)
                for n in ast.walk(stmt):
                    if (isinstance(n, ast.Attribute) and n.attr == "K"
                            and _root_name(n) not in bound):
                        out.append(mod.finding(
                            self.name, n,
                            f"'{ast.unparse(n)}' is the grid-wide K but a "
                            "per-bin K is in scope here — shape this "
                            "dispatch with the loop-bound bin's own K"))

        class V(ast.NodeVisitor):
            def visit_For(self, node: ast.For) -> None:
                if _iter_is_bin_scoped(node.iter):
                    check_body(node.body, _target_names(node.target))
                self.generic_visit(node)

            def _comp(self, node) -> None:
                bound: set[str] = set()
                scoped = False
                for gen in node.generators:
                    bound |= _target_names(gen.target)
                    scoped = scoped or _iter_is_bin_scoped(gen.iter)
                if scoped:
                    elts = [node.elt] if not isinstance(node, ast.DictComp) \
                        else [node.key, node.value]
                    check_body(elts, bound)
                self.generic_visit(node)

            visit_GeneratorExp = _comp
            visit_ListComp = _comp
            visit_SetComp = _comp
            visit_DictComp = _comp

        V().visit(mod.tree)
        return out
